"""Benchmark entry point: one section per paper table/claim.

  speedup      — SI S2 analytic speedup model, 3 use cases (Eqs. 1-13)
  overhead     — §3.1 exchange-loop overhead vs committee inference
  scaling      — §2 oracle/generator pool scaling
  committee_uq — fused single-dispatch exchange path vs sequential members
  budget       — cross-round oracle-rate controller: budget tracking under
                 std drift + hot-path overhead vs the default rule
  serving      — queue-batched + mesh-sharded committee serving vs
                 per-call CommitteeServer.predict at request size 1
  train        — fused one-dispatch K-member retraining vs sequential
                 per-member training + weight-refresh host bytes
  memory       — big-committee memory diet: stacked TrainState bytes +
                 step time across K x MemoryPolicy (fp32/bf16/int8)
  fault        — labeled-throughput retention + recovery time under the
                 standard chaos FaultPlan (supervised runtime)
  fleet        — device-resident exploration fleet (one fused
                 advance+score+select dispatch) vs N host generators
  mesh         — production-mesh scale-out: fused score on a real 8-device
                 emulated mesh vs the sequential legacy path, weak-scaling
                 curves, and bit-identity parity flags (subprocess: the
                 device count must be set before jax initializes)
  kernels      — Pallas-path microbenchmarks (XLA schedule, host timing)

``python -m benchmarks.run`` runs everything; ``--only <name>`` filters.
The roofline/dry-run tables (launch/roofline.py) are separate because they
need the 512-device XLA_FLAGS subprocess.

``bench_meta()`` is the shared provenance stamp: every BENCH_*.json
writer records the resolved platform / device kind / device count /
process info under a ``"meta"`` key, so a report is interpretable after
the machine that produced it is gone.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time


def bench_meta(**extra):
    """Provenance block for BENCH_*.json reports (platform, device kind,
    device/process counts, emulated-device request) plus any benchmark-
    specific extras such as ``mesh_shape``.  Initializes the jax backend —
    writers call it at report time, never at module import."""
    from repro.launch import platform as _platform

    meta = _platform.describe()
    meta["mesh_shape"] = str(extra.pop("mesh_shape", ""))
    meta.update(extra)
    return meta


def _section(title: str):
    print(f"\n{'=' * 70}\n# {title}\n{'=' * 70}", flush=True)


def bench_speedup(simulate: bool):
    from benchmarks import speedup_usecases
    _section("SI S2 speedup model (3 use cases)")
    sys.argv = ["x"] + (["--simulate"] if simulate else [])
    speedup_usecases.main()


def bench_overhead():
    from benchmarks import overhead
    _section("Exchange-loop overhead vs committee inference (paper §3.1)")
    overhead.main()


def bench_scaling():
    from benchmarks import scaling
    _section("Oracle / generator pool scaling (paper §2)")
    scaling.main()


def bench_committee_uq(smoke: bool):
    from benchmarks import committee_uq
    _section("Fused committee-UQ exchange hot path (single dispatch)")
    committee_uq.main(["--smoke"] if smoke else [])


def bench_budget(smoke: bool):
    from benchmarks import budget_controller
    _section("Cross-round budgeted acquisition (oracle-rate controller)")
    budget_controller.main(["--smoke"] if smoke else [])


def bench_serving(smoke: bool):
    from benchmarks import serving_queue
    _section("Queue-batched, mesh-sharded committee serving")
    serving_queue.main(["--smoke"] if smoke else [])


def bench_train(smoke: bool):
    from benchmarks import committee_train
    _section("Fused one-dispatch K-member retraining")
    committee_train.main(["--smoke"] if smoke else [])


def bench_memory(smoke: bool):
    from benchmarks import committee_memory
    _section("Big-committee memory diet (K x MemoryPolicy)")
    committee_memory.main(["--smoke"] if smoke else [])


def bench_fault(smoke: bool):
    from benchmarks import fault_recovery
    _section("Fault recovery: throughput retention under the standard plan")
    fault_recovery.main(["--smoke"] if smoke else [])


def bench_fleet(smoke: bool):
    from benchmarks import exploration_fleet
    _section("Device-resident exploration fleet vs N host generators")
    exploration_fleet.main(["--smoke"] if smoke else [])


def bench_mesh(smoke: bool):
    _section("Production-mesh scale-out (8 emulated devices, subprocess)")
    # the emulated-device count locks on first jax backend init, and any
    # section above may already have initialized it — so the mesh
    # benchmark always runs in a fresh interpreter (same pattern as the
    # roofline's 512-device tables)
    script = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "mesh_scaleout.py")
    subprocess.run([sys.executable, script]
                   + (["--smoke"] if smoke else []), check=True)


def bench_kernels():
    _section("Kernel microbenchmarks (XLA schedule on host)")
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops

    rng = jax.random.PRNGKey(0)

    def timeit(fn, *args, iters=5):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
            (out[0] if isinstance(out, tuple) else out).block_until_ready()
        return (time.perf_counter() - t0) / iters

    print("name,ms_per_call,notes")
    # f32 on host: CPU has no native bf16 — these timings are schedule
    # sanity only; real numbers come from the roofline (TPU target).
    B, T, H, KV, D = 1, 2048, 16, 4, 128
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, T, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, KV, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, KV, D), jnp.float32)
    att = jax.jit(lambda q, k, v: ops.attention(q, k, v, causal=True))
    print(f"attention_2k_gqa,{timeit(att, q, k, v) * 1e3:.2f},"
          f"B{B} T{T} H{H}/{KV} D{D}")

    Hn, N = 8, 64
    r = jax.random.normal(ks[0], (B, T, Hn, N))
    w = jax.random.uniform(ks[1], (B, T, Hn, N), minval=0.5, maxval=0.99)
    u = jax.random.normal(ks[2], (Hn, N))
    wkv = jax.jit(lambda r, w: ops.wkv6(r, r, r, w, u))
    print(f"wkv6_2k,{timeit(wkv, r, w) * 1e3:.2f},chunked linear attention")

    P, Ns = 64, 16
    x = jax.random.normal(ks[0], (B, T, Hn, P))
    a = jax.random.uniform(ks[1], (B, T, Hn), minval=0.5, maxval=0.999)
    Bm = jax.random.normal(ks[2], (B, T, Hn, Ns))
    ssd = jax.jit(lambda x, a, Bm: ops.ssd(x, a, Bm, Bm))
    print(f"ssd_2k,{timeit(ssd, x, a, Bm) * 1e3:.2f},chunked SSD scan")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["speedup", "overhead", "scaling", "kernels",
                             "committee_uq", "budget", "serving", "train",
                             "memory", "fault", "fleet", "mesh"])
    ap.add_argument("--simulate", action="store_true",
                    help="run the measured PAL-runtime speedup simulation")
    ap.add_argument("--smoke", action="store_true",
                    help="few iterations (CI)")
    args = ap.parse_args()

    t0 = time.time()
    if args.only in (None, "speedup"):
        bench_speedup(args.simulate)
    if args.only in (None, "overhead"):
        bench_overhead()
    if args.only in (None, "scaling"):
        bench_scaling()
    if args.only in (None, "committee_uq"):
        bench_committee_uq(args.smoke)
    if args.only in (None, "budget"):
        bench_budget(args.smoke)
    if args.only in (None, "serving"):
        bench_serving(args.smoke)
    if args.only in (None, "train"):
        bench_train(args.smoke)
    if args.only in (None, "memory"):
        bench_memory(args.smoke)
    if args.only in (None, "fault"):
        bench_fault(args.smoke)
    if args.only in (None, "fleet"):
        bench_fleet(args.smoke)
    if args.only in (None, "mesh"):
        bench_mesh(args.smoke)
    if args.only in (None, "kernels"):
        bench_kernels()
    print(f"\n# total benchmark wall time: {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
