"""Benchmark: fused one-dispatch K-member retraining vs sequential
per-member training, plus trainer->engine weight-refresh host traffic.

The legacy training path runs one Python trainer object per committee
member: K separate jitted train steps per optimization step (K dispatches,
K schedule/optimizer evaluations, K host loops).  The fused
``training/committee_trainer.CommitteeTrainer`` advances ALL K members in
ONE vmapped dispatch per step — per-member ``TrainState`` stacked on a
leading committee axis, per-member bootstrap minibatches gathered on
device from the ``ReplayTrainingBuffer`` ring.

Metrics written to ``BENCH_committee_train.json``:

* wall-clock for one full retrain round (K members x STEPS steps),
  sequential vs fused (median over rounds) -> ``speedup_fused_retrain``
  (acceptance: >= 3x at K=8 on CPU);
* trainer->engine weight-refresh host bytes: the WeightStore path packs
  1-D float32 arrays through host memory every publish; the
  ``FusedEngine.refresh_from_device`` path moves ZERO packed host bytes
  -> ``refresh_device_zero_host_bytes``;
* both paths train the same data order (the fused trainer's own
  ``minibatch_indices`` replayed into the sequential baseline), and the
  resulting member params must agree within vmap-reduction tolerance.

Usage:  PYTHONPATH=src python benchmarks/committee_train.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.core.weight_sync import WeightStore
from repro.training.committee_trainer import (
    CommitteeTrainer, default_train_config,
)
from repro.training.train_step import make_train_state, make_train_step

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


K = 8               # committee members (acceptance: >=3x at K=8, CPU)
IN_DIM = 16
HIDDEN = 64
OUT_DIM = 4
N_DATA = 512
BATCH = 32
LR = 1e-3


def _mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, batch):
    pred = _mlp_apply(p, batch["x"])
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _make_members(rng):
    return [{
        "w1": jnp.asarray(rng.randn(IN_DIM, HIDDEN).astype(np.float32) * 0.3),
        "b1": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * 0.1),
        "w2": jnp.asarray(rng.randn(HIDDEN, OUT_DIM).astype(np.float32) * 0.3),
        "b2": jnp.asarray(rng.randn(OUT_DIM).astype(np.float32) * 0.1),
    } for _ in range(K)]


def bench_sequential(members, xs, ys, idx_per_step, rounds):
    """Legacy path: K per-member jitted train steps per optimization step.
    Data order is the FUSED trainer's own bootstrap draw (replayed), so
    both paths do identical numerical work."""
    tcfg = default_train_config(LR)
    step = jax.jit(make_train_step(_loss, tcfg))
    times, final_states = [], None
    for _ in range(rounds):
        states = [make_train_state(m, tcfg) for m in members]
        t0 = time.perf_counter()
        for idx in idx_per_step:                     # (K, B) per step
            for i in range(K):
                batch = {"x": xs[idx[i]], "y": ys[idx[i]]}
                states[i], _ = step(states[i], batch)
        jax.tree.map(lambda a: a.block_until_ready(), states[-1].params)
        times.append(time.perf_counter() - t0)
        final_states = states
    return times, final_states


def bench_fused(trainer, steps, rounds):
    """Fused path: one CommitteeTrainer.train round (all K members advance
    per dispatch).  The trainer's initial snapshot is restored between
    rounds so every round starts from the same optimizer state the
    sequential baseline does, without rebuilding the jit cache."""
    init_sd = trainer.state_dict()
    times = []
    for _ in range(rounds):
        trainer.load_state_dict(init_sd)
        t0 = time.perf_counter()
        trainer.train(steps=steps)
        jax.tree.map(lambda a: a.block_until_ready(), trainer.cparams)
        times.append(time.perf_counter() - t0)
    return times, trainer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true",
                    help="few iterations (CI smoke)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_committee_train.json")
    args = ap.parse_args(argv)
    steps = args.steps or (20 if args.smoke else 60)
    rounds = args.rounds or (3 if args.smoke else 7)

    rng = np.random.RandomState(0)
    members = _make_members(rng)
    cparams = cmte.stack_members(members)
    xs_h = rng.randn(N_DATA, IN_DIM).astype(np.float32)
    ys_h = rng.randn(N_DATA, OUT_DIM).astype(np.float32)
    xs, ys = jnp.asarray(xs_h), jnp.asarray(ys_h)

    trainer = CommitteeTrainer(_loss, cparams, steps=steps, batch=BATCH,
                               lr=LR, bootstrap=True,
                               replay_capacity=N_DATA, seed=0)
    trainer.add_blocks(list(zip(xs_h, ys_h)))

    # replay the fused trainer's exact bootstrap draws into the baseline
    idx_per_step = [trainer.minibatch_indices(t, N_DATA)
                    for t in range(steps)]

    # warmup compiles for both paths (one extra round each)
    seq_t, seq_states = bench_sequential(members, xs, ys, idx_per_step,
                                         rounds + 1)
    fus_t, fus_trainer = bench_fused(trainer, steps, rounds + 1)
    seq_ms = statistics.median(seq_t[1:]) * 1e3
    fus_ms = statistics.median(fus_t[1:]) * 1e3

    # numerical parity: same data order => same members (vmap tolerance)
    for i in (0, K - 1):
        a = np.asarray(seq_states[i].params["w1"])
        b = np.asarray(cmte.member(fus_trainer.cparams, i)["w1"])
        err = float(np.max(np.abs(a - b)))
        assert err < 1e-4, f"fused/sequential member {i} diverged: {err}"

    # --- trainer -> engine weight refresh: host bytes per publish ---------
    engine = acq.FusedEngine(_mlp_apply, cparams, 0.5, impl="xla")
    engine.refresh_host_bytes = 0
    engine.refresh_from_device(fus_trainer.snapshot_cparams())
    device_bytes = engine.refresh_host_bytes            # must stay 0

    store = WeightStore(K)
    engine_store = acq.FusedEngine(_mlp_apply, cparams, 0.5, impl="xla")
    for i in range(K):
        store.publish_packed(
            i, cmte.get_weight(cmte.member(fus_trainer.cparams, i)))
    engine_store.refresh_from(store)
    store_bytes = engine_store.refresh_host_bytes
    # the publish side packs the same bytes again into the store's
    # ping-pong buffers: count both directions of the host round trip
    store_bytes += sum(
        store.pull_packed(i)[0].nbytes for i in range(K))

    report = {
        "meta": bench_meta(),
        "config": {"K": K, "in_dim": IN_DIM, "hidden": HIDDEN,
                   "out_dim": OUT_DIM, "n_data": N_DATA, "batch": BATCH,
                   "steps_per_round": steps, "rounds": rounds,
                   "backend": jax.default_backend()},
        "sequential": {"ms_per_retrain_round": seq_ms,
                       "dispatches_per_step": K},
        "fused": {"ms_per_retrain_round": fus_ms,
                  "dispatches_per_step": 1,
                  "replay_bytes_to_device":
                      fus_trainer.replay.bytes_to_device,
                  "replay_append_blocks": fus_trainer.replay.append_blocks},
        "speedup_fused_retrain": seq_ms / fus_ms,
        "refresh_host_bytes_device_path": device_bytes,
        "refresh_host_bytes_store_path": store_bytes,
        "refresh_device_zero_host_bytes": device_bytes == 0,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"sequential:  {seq_ms:.1f} ms/retrain round "
          f"(K={K} x {steps} steps, {K} dispatches/step)")
    print(f"fused:       {fus_ms:.1f} ms/retrain round (1 dispatch/step)")
    print(f"speedup {report['speedup_fused_retrain']:.2f}x")
    print(f"weight refresh host bytes: device path {device_bytes}, "
          f"WeightStore path {store_bytes}")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
