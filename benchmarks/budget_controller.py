"""Benchmark: cross-round budgeted acquisition (core/budget.BudgetRule).

Two claims, measured over a multi-round run with a drifting committee-std
distribution (input scale ramps 4x, so a static threshold's selection rate
drifts with it):

* BUDGET TRACKING — the realized oracle rate (selected fraction per
  exchange round) of the budgeted pipeline stays within +-10% of the
  configured ``oracle_budget`` once the controller settles (second half of
  the run), while the static-threshold baseline drifts across the whole
  [0, 1] range.
* NO HOT-PATH REGRESSION — the budgeted fused dispatch (threshold compare
  + PI update + state threading, all compiled into the same single device
  program) stays within ~10% wall-clock of the default-rule fused path
  (compare against BENCH_committee_uq.json's ``fused`` row: same K /
  n_gen / MLP configuration).

Also measures the re-weighted pipeline (RollingReweightRule + BudgetRule)
and checks the carried state stays DEVICE-RESIDENT: after the run every
rule-state leaf must still be a jax.Array (a host round trip would have
left numpy behind), and the UQ transfer volume per iteration must equal
the default engine's (the four small arrays — state adds nothing).

Writes ``BENCH_budget_controller.json``.

Usage:  PYTHONPATH=src python benchmarks/budget_controller.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import numpy as np

from repro.core import acquisition as acq
from repro.core import budget as bud
from repro.core import committee as cmte

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


try:        # `python -m benchmarks.run` (package) vs direct script run
    from benchmarks.committee_uq import (
        K, N_GEN, IN_DIM, HIDDEN, OUT_DIM, _inputs, _make_members,
        _mlp_apply,
    )
except ImportError:
    from committee_uq import (
        K, N_GEN, IN_DIM, HIDDEN, OUT_DIM, _inputs, _make_members,
        _mlp_apply,
    )

TARGET = 0.2          # oracle-selected fraction per round
HORIZON = 16


def _calibrate_threshold(members) -> float:
    """Median committee std of a scale-1.0 probe batch: a static threshold
    that starts mid-distribution, so the baseline's realized rate visibly
    sweeps as the input scale drifts (and the controller seed is fair)."""
    import jax.numpy as jnp

    x = jnp.asarray(np.stack(_inputs(np.random.RandomState(2), 256)))
    preds = np.stack([np.asarray(_mlp_apply(m, x)) for m in members])
    sstd = preds.std(axis=0, ddof=1).max(axis=-1)
    return float(np.median(sstd))


def _drift_batches(rng, rounds, n):
    """Input scale ramps 0.5x -> 2x: committee std of the random MLP grows
    with |x|, so the std distribution the rules see drifts ~4x."""
    out = []
    for r in range(rounds):
        s = 0.5 + 1.5 * r / max(rounds - 1, 1)
        out.append([x * s for x in _inputs(rng, n)])
    return out


def _run(engine, batches):
    times, rates = [], []
    engine.bytes_to_device = engine.bytes_to_host = 0
    for inputs in batches:
        t0 = time.perf_counter()
        uq = engine.score(inputs)
        times.append(time.perf_counter() - t0)
        rates.append(float(uq.mask.mean()))
    n = len(batches)
    return times, rates, engine.bytes_to_device / n, engine.bytes_to_host / n


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_budget_controller.json")
    args = ap.parse_args(argv)
    rounds = args.rounds or (60 if args.smoke else 300)
    warmup = 3 if args.smoke else 10

    rng = np.random.RandomState(0)
    members = _make_members(rng)
    cparams = cmte.stack_members(members)
    threshold = _calibrate_threshold(members)
    batches = _drift_batches(np.random.RandomState(1), warmup + rounds,
                             N_GEN)

    engines = {
        "default_threshold": acq.FusedEngine(
            _mlp_apply, cparams, threshold, impl="xla"),
        "budgeted": acq.FusedEngine(
            _mlp_apply, cparams, threshold,
            rules=(bud.BudgetRule(target=TARGET, thr_init=threshold,
                                  horizon=HORIZON),),
            impl="xla"),
        "budgeted_reweighted": acq.FusedEngine(
            _mlp_apply, cparams, threshold,
            rules=(bud.RollingReweightRule(n_buckets=64, decay=0.9,
                                           boost=0.5),
                   bud.BudgetRule(target=TARGET, thr_init=threshold,
                                  horizon=HORIZON)),
            impl="xla"),
    }

    results = {}
    for name, eng in engines.items():
        times, rates, up, down = _run(eng, batches)
        ms = statistics.median(times[warmup:]) * 1e3
        settled = rates[warmup + rounds // 2:]
        results[name] = {
            "ms_per_iteration": ms,
            "bytes_host_to_device": up,
            "bytes_device_to_host": down,
            "realized_rate_mean": float(np.mean(rates[warmup:])),
            "realized_rate_settled": float(np.mean(settled)),
            "rate_min": float(np.min(rates[warmup:])),
            "rate_max": float(np.max(rates[warmup:])),
        }

    bud_res = results["budgeted"]
    dflt = results["default_threshold"]
    rate_err = abs(bud_res["realized_rate_settled"] - TARGET) / TARGET
    overhead = bud_res["ms_per_iteration"] / dflt["ms_per_iteration"]
    # direct residency check: a host round trip of the carried state
    # anywhere in the hot loop would leave numpy leaves here
    state_device_resident = all(
        isinstance(leaf, jax.Array)
        for e in (engines["budgeted"], engines["budgeted_reweighted"])
        for leaf in jax.tree.leaves(e.rule_state))
    ctrl_state = jax.tree.map(
        float, jax.tree.map(np.asarray, engines["budgeted"].rule_state))

    report = {
        "meta": bench_meta(),
        "config": {"K": K, "n_gen": N_GEN, "in_dim": IN_DIM,
                   "hidden": HIDDEN, "out_dim": OUT_DIM,
                   "target_rate": TARGET, "horizon": HORIZON,
                   "seed_threshold": threshold, "rounds": rounds,
                   "backend": jax.default_backend()},
        **results,
        "budget_rate_rel_error": rate_err,
        "budget_within_10pct": bool(rate_err <= 0.10),
        "budget_overhead_vs_default": overhead,
        "state_device_resident": bool(state_device_resident),
        "uq_bytes_identical_to_default": bool(
            bud_res["bytes_device_to_host"] == dflt["bytes_device_to_host"]
            and bud_res["bytes_host_to_device"]
            == dflt["bytes_host_to_device"]),
        "controller_final_state": ctrl_state,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"target oracle rate: {TARGET:.3f}  (drifting std, "
          f"{rounds} rounds)")
    print(f"static threshold : rate {dflt['rate_min']:.3f}.."
          f"{dflt['rate_max']:.3f} (drifts)   "
          f"{dflt['ms_per_iteration']:.3f} ms/iter")
    print(f"budgeted         : settled rate "
          f"{bud_res['realized_rate_settled']:.3f} "
          f"(rel err {rate_err * 100:.1f}%)   "
          f"{bud_res['ms_per_iteration']:.3f} ms/iter "
          f"({(overhead - 1) * 100:+.1f}% vs default)")
    rw = results["budgeted_reweighted"]
    print(f"budget+reweight  : settled rate "
          f"{rw['realized_rate_settled']:.3f}   "
          f"{rw['ms_per_iteration']:.3f} ms/iter")
    print(f"state on device  : leaves jax.Array="
          f"{report['state_device_resident']}, same UQ bytes as "
          f"default={report['uq_bytes_identical_to_default']}")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
