"""Benchmark: Exchange-loop overhead vs committee inference (paper §3.1).

The paper reports, for 89 parallel MD trajectories with a 4-NN committee:
51.5 ms committee forward vs 4.27 ms MPI communication + propagation, and
that removing the oracle+training kernels does NOT change the rate-limiting
step.  This benchmark reproduces the *structure* of that claim on this host:

  1. time the committee forward for 89 stacked inputs,
  2. time one full Exchange iteration (gather -> predict -> check -> scatter),
  3. overhead = exchange_iteration - predict_time,
  4. repeat with oracle/training kernels enabled vs disabled.
"""
from __future__ import annotations

import csv
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.pal_potential import PALRunConfig, PotentialConfig
from repro.core.buffers import OracleInputBuffer
from repro.core.controller import (Exchange, ExchangeConfig, PredictionPool)
from repro.core.monitor import Monitor
from repro.core import UserGene, UserModel
from repro.models import potential as pot

N_GEN = 89          # paper: 89 parallel trajectories
COMMITTEE = 4       # paper: 4 NNs
STEPS = 200


class MDGene(UserGene):
    """A cheap MD-like generator: perturb coordinates by predicted forces."""

    def __init__(self, rank, rd, n_atoms=8):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)
        self.x = self.rng.randn(n_atoms * 3).astype(np.float32)

    def generate_new_data(self, data_to_gene):
        if data_to_gene is not None:
            self.x = self.x - 0.001 * data_to_gene[:self.x.size]
        self.x = self.x + self.rng.randn(self.x.size).astype(np.float32) * .01
        return False, self.x


class CommitteePredictor(UserModel):
    """One vmapped committee = the whole prediction kernel (DESIGN.md §2)."""

    def __init__(self, rank, rd, dev, mode, cfg: PotentialConfig):
        super().__init__(rank, rd, dev, mode)
        self.cfg = cfg
        self.cparams = pot.init_committee(cfg, jax.random.PRNGKey(rank))

        def forces_flat(cp, flat_coords):
            coords = flat_coords.reshape(-1, cfg.n_atoms, 3)
            _, f = pot.batched_committee_energy_forces(cp, coords, cfg)
            return f.reshape(coords.shape[0], cfg.committee_size, -1)

        self._fn = jax.jit(forces_flat)

    def predict(self, list_data):
        x = jnp.asarray(np.stack(list_data))
        out = np.asarray(self._fn(self.cparams, x))   # (n_gen, K, 3A)
        return out

    def update(self, arr):
        pass

    def get_weight(self):
        return np.zeros(1, np.float32)

    def get_weight_size(self):
        return 1


def committee_check(inputs, preds):
    """predict_all returns (1, n_gen, K, out) -> committee std over K."""
    from repro.core import selection as sel
    p = np.asarray(preds)[0]                      # (n_gen, K, out)
    p = np.moveaxis(p, 1, 0)                      # (K, n_gen, out)
    return sel.prediction_check(inputs, p, threshold=1e9)


def run(with_oracle_queue: bool) -> dict:
    cfg = PotentialConfig(n_atoms=8, committee_size=COMMITTEE)
    monitor = Monitor()
    gens = [MDGene(i, "/tmp") for i in range(N_GEN)]
    predictor = CommitteePredictor(0, "/tmp", 0, "predict", cfg)
    pool = PredictionPool([predictor], store=None, monitor=monitor)
    buf = OracleInputBuffer(max_size=1000 if with_oracle_queue else 1)
    exch = Exchange(gens, pool, buf,
                    ExchangeConfig(std_threshold=1e9 if not with_oracle_queue
                                   else 0.0, patience=10 ** 9,
                                   progress_save_interval=1e9),
                    monitor, prediction_check=committee_check)
    # warmup (jit compile is NOT part of the steady-state claim)
    for _ in range(5):
        exch.step()
    pt = monitor.timer("exchange.predict")
    ct = monitor.timer("exchange.comm")
    p0, p0n = pt.total, pt.count
    c0 = ct.total
    t0 = time.perf_counter()
    for _ in range(STEPS):
        exch.step()
    total = (time.perf_counter() - t0) / STEPS
    predict = (pt.total - p0) / (pt.count - p0n)
    comm = (ct.total - c0) / STEPS
    return {
        "oracle_training_enabled": with_oracle_queue,
        "committee_forward_ms": round(predict * 1e3, 3),
        "comm_plus_propagation_ms": round(comm * 1e3, 3),
        "exchange_iteration_ms": round(total * 1e3, 3),
        "overhead_fraction": round((total - predict) / total, 3),
        "rate_limiting": "inference" if predict > total - predict
        else "comm",
    }


def main():
    rows = [run(with_oracle_queue=False), run(with_oracle_queue=True)]
    wr = csv.DictWriter(sys.stdout, fieldnames=rows[0].keys())
    wr.writeheader()
    for r in rows:
        wr.writerow(r)
    same = rows[0]["rate_limiting"] == rows[1]["rate_limiting"]
    print(f"# rate-limiting step unchanged by oracle/training kernels: "
          f"{same} (paper §3.1 claim)")


if __name__ == "__main__":
    main()
