"""Benchmark: Exchange-loop overhead vs committee inference (paper §3.1).

The paper reports, for 89 parallel MD trajectories with a 4-NN committee:
51.5 ms committee forward vs 4.27 ms MPI communication + propagation, and
that removing the oracle+training kernels does NOT change the rate-limiting
step.  This benchmark reproduces the *structure* of that claim on this host:

  1. time the committee forward for 89 stacked inputs,
  2. time one full Exchange iteration (gather -> predict -> check -> scatter),
  3. overhead = exchange_iteration - predict_time,
  4. repeat with oracle/training kernels enabled vs disabled.
"""
from __future__ import annotations

import csv
import sys
import tempfile
import time

import jax
import numpy as np

from repro.configs.pal_potential import PALRunConfig, PotentialConfig
from repro.core import acquisition as acq
from repro.core.buffers import OracleInputBuffer
from repro.core.controller import (Exchange, ExchangeConfig, PredictionPool)
from repro.core.monitor import Monitor
from repro.core import UserGene
from repro.models import potential as pot

N_GEN = 89          # paper: 89 parallel trajectories
COMMITTEE = 4       # paper: 4 NNs
STEPS = 200


class MDGene(UserGene):
    """A cheap MD-like generator: perturb coordinates by predicted forces."""

    def __init__(self, rank, rd, n_atoms=8):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)
        self.x = self.rng.randn(n_atoms * 3).astype(np.float32)

    def generate_new_data(self, data_to_gene):
        if data_to_gene is not None:
            self.x = self.x - 0.001 * data_to_gene[:self.x.size]
        self.x = self.x + self.rng.randn(self.x.size).astype(np.float32) * .01
        return False, self.x


def make_engine(cfg: PotentialConfig, threshold: float) -> acq.FusedEngine:
    """The unified acquisition engine over the MLP-potential committee."""

    def member_forces(p, flat_coords):            # (n, 3A) -> (n, 3A)
        def one(flat):
            _, f = pot.energy_forces(p, flat.reshape(cfg.n_atoms, 3), cfg)
            return f.reshape(-1)
        return jax.vmap(one)(flat_coords)

    cparams = pot.init_committee(cfg, jax.random.PRNGKey(0))
    return acq.FusedEngine(member_forces, cparams, threshold, impl="xla",
                           min_bucket=N_GEN)


def run(with_oracle_queue: bool) -> dict:
    cfg = PotentialConfig(n_atoms=8, committee_size=COMMITTEE)
    monitor = Monitor()
    gens = [MDGene(i, "/tmp") for i in range(N_GEN)]
    threshold = 0.0 if with_oracle_queue else 1e9
    pool = PredictionPool([], store=None, monitor=monitor,
                          engine=make_engine(cfg, threshold))
    buf = OracleInputBuffer(max_size=1000 if with_oracle_queue else 1)
    exch = Exchange(gens, pool, buf,
                    ExchangeConfig(std_threshold=threshold, patience=10 ** 9,
                                   progress_save_interval=1e9),
                    monitor)
    # warmup (jit compile is NOT part of the steady-state claim)
    for _ in range(5):
        exch.step()
    pt = monitor.timer("exchange.predict")
    ct = monitor.timer("exchange.comm")
    p0, p0n = pt.total, pt.count
    c0 = ct.total
    t0 = time.perf_counter()
    for _ in range(STEPS):
        exch.step()
    total = (time.perf_counter() - t0) / STEPS
    predict = (pt.total - p0) / (pt.count - p0n)
    comm = (ct.total - c0) / STEPS
    return {
        "oracle_training_enabled": with_oracle_queue,
        "committee_forward_ms": round(predict * 1e3, 3),
        "comm_plus_propagation_ms": round(comm * 1e3, 3),
        "exchange_iteration_ms": round(total * 1e3, 3),
        "overhead_fraction": round((total - predict) / total, 3),
        "rate_limiting": "inference" if predict > total - predict
        else "comm",
    }


def main():
    rows = [run(with_oracle_queue=False), run(with_oracle_queue=True)]
    wr = csv.DictWriter(sys.stdout, fieldnames=rows[0].keys())
    wr.writeheader()
    for r in rows:
        wr.writerow(r)
    same = rows[0]["rate_limiting"] == rows[1]["rate_limiting"]
    print(f"# rate-limiting step unchanged by oracle/training kernels: "
          f"{same} (paper §3.1 claim)")


if __name__ == "__main__":
    main()
