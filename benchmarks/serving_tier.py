"""Benchmark: the multi-tenant serving tier under a Zipf-skewed
8-tenant load (ISSUE 9 acceptance) vs the PR-4 single-tenant queue.

Workload: 8 closed-loop tenants with Zipf-proportional concurrency
(tenant t keeps ``~1/(t+1)^1.1`` of the heaviest tenant's requests
outstanding) against a paper-scale committee (K=8 three-layer MLPs,
hidden 1024 — a 64-row fused dispatch costs ~15 ms on one CPU core,
the regime where the paper's 51.5 ms committee inference lives).
Requests draw from a shared pool of distinct operating points, so
traffic is repetitive the way production surrogate serving is.

Phases (duration-paced, all through ONE shared fused engine so compile
time is paid once):

* **baseline_pr4** — the PR-4 queue (FIFO, static deadline, no cache)
  under the full Zipf load: the reference requests/s.
* **tier** — the same load through the tier (DRR fairness + LSH answer
  cache): sustained requests/s.  ``requests_per_s_ratio_vs_pr4`` is the
  headline — the tier must serve AT LEAST what the PR-4 queue does
  (floor 1.0 in check_bench); repeats short-circuit at the cache, so it
  normally serves a multiple.
* **fairness** — per-tenant UNIQUE rows (no cache assist), Zipf-skewed
  outstanding demand deep enough that every tenant stays backlogged.
  FIFO serves proportional to demand (min/max ~ 0.1); DRR gives every
  backlogged tenant its share of each microbatch —
  ``fairness_min_over_max`` must stay >= 0.5 (``fairness_bound_ok``).
* **latency_control** — deadline-paced light load with a 15 ms p99
  target, starting from a deliberate 40 ms deadline overshoot: the PI
  controller steers the effective deadline until observed p99 holds the
  target; ``p99_target_rel_error`` (p99 of the last-half requests vs
  target) must stay within 0.25.  This phase runs a LIGHT committee
  (sub-ms dispatches) so the plant floor sits well under the target —
  it measures the controller, not the committee; with the paper-scale
  committee the floor itself exceeds 15 ms on one core and no deadline
  policy could hold the target.

Usage:  PYTHONPATH=src python benchmarks/serving_tier.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.serving import (
    CommitteeServer, LSHAnswerCache, QueueConfig, ServingQueue,
)

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


TENANTS = 8
ZIPF_S = 1.1
MAX_BATCH = 64          # = one engine shape bucket
MAX_WAIT_MS = 5.0       # PR-4 static deadline
POOL = 256              # distinct operating points in the shared pool
LATENCY_TARGET_MS = 15.0
THRESHOLD = 1e9         # nothing rule-selected: every answer cacheable

# paper-scale committee: fused dispatch cost comparable to the paper's
# committee inference, so cache hits vs device dispatches is a real trade
K = 8
IN_DIM = 32
HIDDEN = 1024
OUT_DIM = 4


def _mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    h = jnp.tanh(h @ p["w2"] + p["b2"])
    return h @ p["w3"] + p["b3"]


def _light_apply(p, x):
    return jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w3"] + p["b3"]


def _make_light_members(rng, hidden=64):
    members = []
    for _ in range(K):
        members.append({
            "w1": jnp.asarray(rng.randn(IN_DIM, hidden)
                              .astype(np.float32) * 0.3),
            "b1": jnp.asarray(rng.randn(hidden).astype(np.float32) * 0.1),
            "w3": jnp.asarray(rng.randn(hidden, OUT_DIM)
                              .astype(np.float32) * 0.3),
            "b3": jnp.asarray(rng.randn(OUT_DIM).astype(np.float32) * 0.1),
        })
    return members


def _make_members(rng):
    members = []
    for _ in range(K):
        members.append({
            "w1": jnp.asarray(rng.randn(IN_DIM, HIDDEN)
                              .astype(np.float32) * 0.3),
            "b1": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * 0.1),
            "w2": jnp.asarray(rng.randn(HIDDEN, HIDDEN)
                              .astype(np.float32) * 0.05),
            "b2": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * 0.1),
            "w3": jnp.asarray(rng.randn(HIDDEN, OUT_DIM)
                              .astype(np.float32) * 0.3),
            "b3": jnp.asarray(rng.randn(OUT_DIM).astype(np.float32) * 0.1),
        })
    return members


def _inputs(rng, n):
    return [rng.randn(IN_DIM).astype(np.float32) for _ in range(n)]


def _zipf_windows(heaviest, floor):
    """Outstanding-request window per tenant, Zipf-proportional with a
    floor so every tenant can keep its DRR share of a microbatch
    backlogged."""
    return [max(floor, int(heaviest / (t + 1) ** ZIPF_S))
            for t in range(TENANTS)]


def _drive(queue, duration, row_fn, windows, *, tag_clients=True):
    """Closed-loop Zipf load: tenant t keeps ``windows[t]`` requests
    outstanding for ``duration`` seconds.  Returns per-tenant served
    counts and all request latencies (seconds, submit -> resolve)."""
    counts = [0] * TENANTS
    lats = [[] for _ in range(TENANTS)]
    start_gate = threading.Barrier(TENANTS + 1)
    t_end = [0.0]

    def client(t):
        gate = threading.Semaphore(windows[t])
        futs = []
        i = 0

        def done(t1, fut):
            lats[t].append(time.perf_counter() - t1)
            counts[t] += 1
            gate.release()
            fut.result()          # surface dispatch errors

        start_gate.wait()
        while time.perf_counter() < t_end[0]:
            gate.acquire()
            t1 = time.perf_counter()
            fut = queue.submit([row_fn(t, i)],
                               client=f"t{t}" if tag_clients else "")
            fut.add_done_callback(lambda f, t1=t1: done(t1, f))
            futs.append(fut)
            i += 1
        for f in futs:
            f.result()

    threads = [threading.Thread(target=client, args=(t,))
               for t in range(TENANTS)]
    for th in threads:
        th.start()
    t0 = time.perf_counter()
    t_end[0] = t0 + duration
    start_gate.wait()
    for th in threads:
        th.join()
    wall = time.perf_counter() - t0
    return counts, [v for l in lats for v in l], wall


def _drive_paced(queue, duration, row_fn, burst):
    """Deadline-paced load from ONE driver thread: submit a burst of
    single-row requests, wait for all, repeat.  Keeps the process at two
    threads (driver + dispatcher) so measured latencies reflect the
    queue's deadline policy, not GIL scheduling tails across a dozen
    client threads."""
    lats = []
    t_stop = time.perf_counter() + duration
    i = 0
    while time.perf_counter() < t_stop:
        t1 = time.perf_counter()
        futs = [queue.submit([row_fn(t % TENANTS, i)],
                             client=f"t{t % TENANTS}")
                for t in range(burst)]
        for f in futs:
            f.result()
        lats.append(time.perf_counter() - t1)
        i += 1
    return lats


def _percentiles(lat_s):
    lat_ms = np.asarray(lat_s) * 1e3
    return (float(np.percentile(lat_ms, 50)),
            float(np.percentile(lat_ms, 99)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true")
    ap.add_argument("--duration", type=float, default=None,
                    help="seconds per load phase")
    ap.add_argument("--out", default="BENCH_serving_tier.json")
    args = ap.parse_args(argv)
    dur = args.duration or (1.5 if args.smoke else 4.0)
    ctl_dur = dur * 2           # the controller needs settle time

    rng = np.random.RandomState(0)
    cparams = cmte.stack_members(_make_members(rng))
    pool = _inputs(rng, POOL)
    # ONE engine for every phase: compile each bucket once up front so
    # measured phases are steady-state serving
    eng = acq.FusedEngine(_mlp_apply, cparams, THRESHOLD, impl="xla")
    server = CommitteeServer(eng, None)
    b = 8
    while b <= MAX_BATCH:
        server.predict(_inputs(np.random.RandomState(99), b))
        b *= 2

    windows = _zipf_windows(64, MAX_BATCH // TENANTS)

    def pooled_row(t, i):       # repetitive production traffic
        return pool[(t * 17 + i) % POOL]

    uniq_rngs = [np.random.RandomState(1000 + t) for t in range(TENANTS)]

    def unique_row(t, i):       # adversarial-for-cache traffic
        return uniq_rngs[t].randn(IN_DIM).astype(np.float32)

    # --- phase 1: PR-4 baseline (FIFO, static deadline, no cache) ---------
    with ServingQueue(server, QueueConfig(max_batch=MAX_BATCH,
                                          max_wait_ms=MAX_WAIT_MS)) as q:
        counts, lat, wall = _drive(q, dur, pooled_row, windows,
                                   tag_clients=False)
    base_rps = sum(counts) / wall
    base_p50, base_p99 = _percentiles(lat)

    # --- phase 2: tier throughput (DRR + answer cache), same load ---------
    cache = LSHAnswerCache(4096, std_max=1e9)
    with ServingQueue(server, QueueConfig(max_batch=MAX_BATCH,
                                          max_wait_ms=MAX_WAIT_MS),
                      cache=cache) as q:
        counts, lat, wall = _drive(q, dur, pooled_row, windows)
        tier_health = q.health()
    tier_rps = sum(counts) / wall
    tier_p50, tier_p99 = _percentiles(lat)
    rps_ratio = tier_rps / base_rps
    cs = cache.stats()
    hit_rate = cs["hits"] / max(cs["hits"] + cs["misses"], 1)

    # --- phase 3: fairness under skewed demand, no cache assist -----------
    # 4x-deep windows: every tenant holds several DRR shares of backlog,
    # so measured rates reflect the scheduler, not refill races
    fair_windows = _zipf_windows(256, 4 * (MAX_BATCH // TENANTS))
    with ServingQueue(server, QueueConfig(max_batch=MAX_BATCH,
                                          max_wait_ms=MAX_WAIT_MS)) as q:
        counts, _, wall = _drive(q, dur, unique_row, fair_windows)
    tenant_rps = [c / wall for c in counts]
    fairness = min(tenant_rps) / max(tenant_rps)

    # --- phase 4: p99 controller holds the latency target -----------------
    # deadline-paced regime (light committee, light load): p99 tracks the
    # effective deadline, which the controller steers from a deliberate
    # 40 ms overshoot down onto the target
    light_eng = acq.FusedEngine(
        _light_apply, cmte.stack_members(_make_light_members(rng)),
        THRESHOLD, impl="xla")
    light_server = CommitteeServer(light_eng, None)
    b = 8
    while b <= MAX_BATCH:
        light_server.predict(_inputs(np.random.RandomState(98), b))
        b *= 2
    with ServingQueue(light_server, QueueConfig(
            max_batch=MAX_BATCH, max_wait_ms=40.0,
            latency_target_ms=LATENCY_TARGET_MS,
            wait_min_ms=0.05, wait_max_ms=50.0,
            latency_window=32)) as q:
        lat = _drive_paced(q, ctl_dur, unique_row, TENANTS * 2)
        ctl_health = q.health()
    settled = lat[len(lat) // 2:]             # last half: converged regime
    _, ctl_p99 = _percentiles(settled)
    rel_err = abs(ctl_p99 - LATENCY_TARGET_MS) / LATENCY_TARGET_MS

    report = {
        "meta": bench_meta(),
        "config": {"K": K, "in_dim": IN_DIM, "hidden": HIDDEN,
                   "out_dim": OUT_DIM, "tenants": TENANTS,
                   "zipf_s": ZIPF_S, "windows": windows,
                   "fair_windows": fair_windows, "pool": POOL,
                   "max_batch": MAX_BATCH, "max_wait_ms": MAX_WAIT_MS,
                   "latency_target_ms": LATENCY_TARGET_MS,
                   "duration_s": dur, "backend": jax.default_backend()},
        "baseline_pr4": {"requests_per_s": base_rps, "p50_ms": base_p50,
                         "p99_ms": base_p99},
        "tier": {"requests_per_s": tier_rps, "p50_ms": tier_p50,
                 "p99_ms": tier_p99,
                 "dispatches": tier_health["dispatches"],
                 "cache_hit_rate": hit_rate, "cache": cs},
        "requests_per_s_ratio_vs_pr4": rps_ratio,
        "fairness": {"per_tenant_rps": tenant_rps,
                     "min_over_max": fairness},
        "fairness_min_over_max": fairness,
        "fairness_bound_ok": bool(fairness >= 0.5),
        "latency_control": {"target_ms": LATENCY_TARGET_MS,
                            "settled_p99_ms": ctl_p99,
                            "effective_wait_ms":
                                ctl_health["effective_wait_ms"],
                            "controller_p99_ms": ctl_health["p99_ms"]},
        "p99_target_rel_error": rel_err,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"baseline PR-4 : {base_rps:8.0f} req/s   "
          f"p50 {base_p50:.2f} ms  p99 {base_p99:.2f} ms")
    print(f"tier          : {tier_rps:8.0f} req/s   "
          f"p50 {tier_p50:.2f} ms  p99 {tier_p99:.2f} ms   "
          f"cache hit rate {hit_rate:.0%}")
    print(f"ratio vs PR-4 : {rps_ratio:.2f}x  (acceptance >= 1.0)")
    print(f"fairness      : min/max {fairness:.2f}  (acceptance >= 0.5)  "
          f"per-tenant {[f'{r:.0f}' for r in tenant_rps]}")
    print(f"p99 control   : settled p99 {ctl_p99:.2f} ms vs target "
          f"{LATENCY_TARGET_MS:.0f} ms  rel err {rel_err:.1%} "
          f"(acceptance <= 25%)  effective wait "
          f"{ctl_health['effective_wait_ms']:.2f} ms")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
