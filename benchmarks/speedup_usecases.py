"""Benchmark: the paper's SI S2 speedup model (Eqs. 1-13) — analytic table
AND a measured simulation that runs the three use cases through the real PAL
runtime with sleep-calibrated kernels, comparing measured speedup to the
model's lower bound.

Reproduces: SI S2.2 (Use Case 1: S -> 1+P/N = 2; Use Case 2: S -> 1;
Use Case 3: S -> 3).
"""
from __future__ import annotations

import argparse
import csv
import io
import sys
import tempfile
import time
from typing import Dict

import numpy as np

from repro.configs.pal_potential import PALRunConfig
from repro.core import PAL, UserGene, UserModel, UserOracle
from repro.core import speedup as sp


def analytic_table() -> list:
    rows = []
    expected = sp.expected_speedups()
    for name, w in sp.USE_CASES.items():
        rows.append({
            "use_case": name,
            "t_oracle_s": w.t_oracle, "t_train_s": w.t_train,
            "t_gen_s": w.t_gen, "N": w.n_samples, "P": w.n_workers,
            "T_serial_s": round(sp.t_serial(w), 1),
            "T_parallel_s": round(sp.t_parallel(w), 1),
            "speedup": round(sp.speedup(w), 3),
            "paper_expected": expected[name],
            "bottleneck": sp.bottleneck(w),
        })
    return rows


# ---------------------------------------------------------------------------
# measured simulation (scaled-down seconds, same ratios)
# ---------------------------------------------------------------------------

SCALE = 2500.0   # 1 paper-second = 0.4 ms simulated


class SimGene(UserGene):
    # SI S2 defines t_gen as ONE ROUND of generation producing the round's
    # N candidates -> per-proposal cost is t_gen / N.
    t_gen_per_sample = 0.0
    limit = 10 ** 9

    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.counter = 0
        self.rng = np.random.RandomState(rank)

    def generate_new_data(self, data_to_gene):
        self.counter += 1
        time.sleep(self.t_gen_per_sample / SCALE)
        if self.counter > self.limit:
            return True, np.zeros(2, np.float32)
        return False, self.rng.randn(2).astype(np.float32)


class SimModel(UserModel):
    t_train = 0.0

    def __init__(self, rank, rd, dev, mode):
        super().__init__(rank, rd, dev, mode)
        self.w = np.random.RandomState(rank + (9 if mode == "train" else 0)
                                       ).randn(2, 2)

    def predict(self, ld):
        return [np.asarray(x) @ self.w for x in ld]

    def update(self, arr):
        self.w = arr.reshape(2, 2)

    def get_weight(self):
        return self.w.reshape(-1).astype(np.float32)

    def get_weight_size(self):
        return 4

    def add_trainingset(self, dps):
        pass

    def retrain(self, req):
        deadline = time.time() + self.t_train / SCALE
        while time.time() < deadline:
            if req.test():
                break
            time.sleep(0.001)
        return False


class SimOracle(UserOracle):
    t_oracle = 0.0

    def run_calc(self, inp):
        time.sleep(self.t_oracle / SCALE)
        return inp, (np.asarray(inp) * 2).astype(np.float32)


def measured_speedup(name: str, w: sp.WorkloadParams,
                     al_rounds: int = 4) -> Dict[str, float]:
    """Run serial then parallel versions of `al_rounds` AL iterations; each
    iteration labels N samples, trains once, generates once."""
    n, p = w.n_samples, w.n_workers

    # ---- serial: (N/P)*t_oracle + t_train + t_gen per round, directly
    t0 = time.perf_counter()
    for _ in range(al_rounds):
        for _ in range(int(np.ceil(n / p))):
            time.sleep(w.t_oracle / SCALE)      # P workers in lockstep
        time.sleep(w.t_train / SCALE)
        time.sleep(w.t_gen / SCALE)
    t_serial = time.perf_counter() - t0

    # ---- parallel: PAL with everything overlapped
    gene_cls = type("G", (SimGene,),
                    {"t_gen_per_sample": w.t_gen / w.n_samples})
    model_cls = type("M", (SimModel,), {"t_train": w.t_train})
    orcl_cls = type("O", (SimOracle,), {"t_oracle": w.t_oracle})

    cfg = PALRunConfig(
        result_dir=tempfile.mkdtemp(), gene_process=1, orcl_process=p,
        pred_process=1, ml_process=1, retrain_size=n,
        std_threshold=-1.0,        # every sample goes to the oracle
        weight_sync_every=1, dynamic_oracle_list=False,
        exchange_min_interval=0.0,  # the sim's own sleeps pace the loop
        oracle_timeout=10 ** 6)
    pal = PAL(cfg, make_generator=gene_cls, make_model=model_cls,
              make_oracle=orcl_cls)
    pal.start()
    # run until al_rounds * n samples are labeled
    target = al_rounds * n
    t0 = time.perf_counter()
    while pal.train_buffer.total_labeled < target:
        time.sleep(0.001)
        if time.perf_counter() - t0 > 120:
            break
    t_parallel = time.perf_counter() - t0
    pal.shutdown()

    model_lb = sp.speedup(w)
    return {
        "use_case": name,
        "t_serial_s": round(t_serial, 3),
        "t_parallel_s": round(t_parallel, 3),
        "measured_speedup": round(t_serial / t_parallel, 2),
        "model_speedup_lower_bound": round(model_lb, 2),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--simulate", action="store_true")
    args = ap.parse_args()

    rows = analytic_table()
    wr = csv.DictWriter(sys.stdout, fieldnames=rows[0].keys())
    wr.writeheader()
    for r in rows:
        wr.writerow(r)

    if args.simulate:
        print("\n# measured (scaled-time simulation through the real "
              "PAL runtime)")
        out = []
        for name, w in sp.USE_CASES.items():
            out.append(measured_speedup(name, w))
        wr = csv.DictWriter(sys.stdout, fieldnames=out[0].keys())
        wr.writeheader()
        for r in out:
            wr.writerow(r)


if __name__ == "__main__":
    main()
