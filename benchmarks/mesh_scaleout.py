"""Benchmark: production-mesh scale-out of the fused committee paths.

Runs on a REAL 8-device mesh emulated on the host CPU
(``--xla_force_host_platform_device_count=8`` via
``launch/platform.ensure_host_devices`` — set before the first jax import,
so every sharding/collective/donation path executes exactly as on
hardware).  Four claims, written to ``BENCH_mesh_scaleout.json``:

* **headline** ``speedup_mesh8_vs_legacy_1dev`` — fused single-dispatch
  scoring on the (8 data x 1 model) mesh vs the seed's per-member
  sequential LegacyEngine on one device, at the production batch size.
  This is the same fused-vs-sequential framing every other gate in this
  repo uses, and it genuinely exercises the 8-device SPMD path.
* **weak scaling** — fixed rows-per-device, throughput ratio at 1/2/4/8
  devices.  On a single physical core the emulated devices time-slice, so
  the ratio is dispatch-overhead bound (~1x-1.4x here); on real multi-chip
  hardware it tracks device count.  Recorded as a tolerance-gated curve,
  no absolute floor.
* **committee-axis curve** — the (1 x 8) model-axis mesh that shards the
  K=8 committee one member per device (the PAL paper's "prediction
  processes" laid out across a mesh axis).
* **parity flags** — score / score_after (exploration fleet) / train /
  serving must be BIT-IDENTICAL between the unsharded engine and the
  (8, 1) mesh, including stateful-rule state and the fleet carry.  Any
  False here means a resharding path silently changed numerics.

Usage:  PYTHONPATH=src python benchmarks/mesh_scaleout.py [--quick] [--out F]
(Needs a fresh process — raises if a jax backend with <8 devices already
initialized; ``benchmarks/run.py --only mesh`` handles the subprocess.)
"""
from __future__ import annotations

import sys

from repro.launch.platform import ensure_host_devices

ensure_host_devices(8)

import argparse                  # noqa: E402
import json                      # noqa: E402
import statistics                # noqa: E402
import time                      # noqa: E402

import jax                       # noqa: E402
import jax.numpy as jnp          # noqa: E402
import numpy as np               # noqa: E402

from repro.core import acquisition as acq          # noqa: E402
from repro.core import committee as cmte           # noqa: E402
from repro.launch.mesh import make_scaleout_mesh   # noqa: E402

try:
    from benchmarks.run import bench_meta
except ImportError:              # running as a script from benchmarks/
    from run import bench_meta

K = 8
D = 6
HIDDEN = 64
THRESHOLD = 0.35
ROWS_HEADLINE = 4096     # fused-mesh advantage grows with rows; 4096 sits
ROWS_COMMITTEE = 512     # comfortably past the 2x gate on a 1-core host
ROWS_PER_DEVICE = 64


def _init_member(seed):
    r = np.random.RandomState(seed)
    return {"w1": jnp.asarray(r.randn(D, HIDDEN).astype(np.float32) * 0.3),
            "w2": jnp.asarray(r.randn(HIDDEN, D).astype(np.float32) * 0.3)}


def _apply(p, x):
    return jnp.tanh(x @ p["w1"]) @ p["w2"]


def _make_legacy(cparams):
    """Seed path: K per-member jitted predicts + float64 host statistics."""
    members = [cmte.member(cparams, i) for i in range(K)]
    fns = [jax.jit(lambda x, p=m: _apply(p, x)) for m in members]

    def predict_all(list_data):
        x = np.asarray(list_data, dtype=np.float32)
        # one host->device upload and one device->host download PER
        # member — the seed exchange loop's K separate predict calls
        # (same accounting as committee_uq.bench_sequential)
        return np.stack([np.asarray(f(jnp.asarray(x))) for f in fns])

    return acq.LegacyEngine(predict_all, THRESHOLD)


def _tput(engine, rows, reps, warmup, as_list=False):
    rng = np.random.RandomState(0)
    x = rng.randn(rows, D).astype(np.float32)
    data = list(x) if as_list else x
    for _ in range(warmup):
        engine.score(data, advance=False)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        engine.score(data, advance=False)
        ts.append(time.perf_counter() - t0)
    med = statistics.median(ts)
    return rows / med, med


def _fused(cparams, mesh):
    return acq.FusedEngine(_apply, cparams, THRESHOLD, impl="xla", mesh=mesh)


def _uq_equal(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("mean", "scalar_std", "component_std", "mask"))


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def parity_score(cparams, mesh8, rng):
    """Bit-identity of score() incl. stateful-rule advancement."""
    from repro.configs.pal_potential import PALRunConfig
    from repro.core.budget import rules_from_config

    cfg = PALRunConfig(std_threshold=THRESHOLD, oracle_budget=0.3,
                       reweight_buckets=32)

    def mk(mesh):
        return acq.FusedEngine(_apply, cparams, THRESHOLD,
                               rules=rules_from_config(cfg), impl="xla",
                               mesh=mesh)

    e0, e8 = mk(None), mk(mesh8)
    ok = True
    for _ in range(3):
        xs = rng.randn(61, D).astype(np.float32)
        ok &= _uq_equal(e0.score(list(xs)), e8.score(list(xs)))
    return ok and _tree_equal(e0.state_dict(), e8.state_dict())


def parity_score_after(cparams, mesh8, rng):
    """Fleet advance+score+select: outputs + carry bit-identical."""
    from repro.exploration.fleet import FleetConfig, WalkerFleet

    fc = FleetConfig(sampler="langevin", dt=0.002, noise=0.01, clip=20.0,
                     friction=0.1, patience=3, seed=7)
    x0 = rng.randn(24, D).astype(np.float32)
    fl0 = WalkerFleet(_fused(cparams, None), x0, fc)
    fl8 = WalkerFleet(_fused(cparams, mesh8), x0, fc)
    ok = True
    for _ in range(4):
        o0, o8 = fl0.step(), fl8.step()
        ok &= o0.n_selected == o8.n_selected
        ok &= np.array_equal(o0.selected, o8.selected)
        ok &= np.array_equal(np.asarray(o0.mean), np.asarray(o8.mean))
    c0, c8 = fl0.state_dict(), fl8.state_dict()
    return ok and all(np.array_equal(c0[k], c8[k]) for k in c0)


def parity_train(cparams, mesh8, rng):
    """Fused K-member training step: losses + params bit-identical."""
    from repro.training.committee_trainer import CommitteeTrainer

    def loss_fn(params, batch):
        pred = _apply(params, batch["x"])
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"loss": loss}

    xs = rng.randn(64, D).astype(np.float32)
    ys = rng.randn(64, D).astype(np.float32)

    def mk(mesh):
        tr = CommitteeTrainer(loss_fn, cparams, steps=3, batch=16, lr=1e-3,
                              bootstrap=True, replay_capacity=128, mesh=mesh,
                              seed=3)
        tr.add_blocks(list(zip(xs, ys)))
        return tr

    t0, t8 = mk(None), mk(mesh8)
    m0, m8 = t0.train(), t8.train()
    return (np.array_equal(m0["loss"], m8["loss"])
            and _tree_equal(jax.tree.map(np.asarray, t0.snapshot_cparams()),
                            jax.tree.map(np.asarray, t8.snapshot_cparams())))


def parity_serving(cparams, mesh8, rng):
    """Queue-batched serving on the mesh answers bit-identically."""
    from repro.serving.engine import CommitteeServer
    from repro.serving.queue import QueueConfig, ServingQueue

    qc = QueueConfig(max_batch=32, max_wait_ms=20.0)
    q0 = ServingQueue(CommitteeServer(_fused(cparams, None)), qc)
    q8 = ServingQueue(CommitteeServer(_fused(cparams, mesh8)), qc)
    try:
        reqs = [rng.randn(3, D).astype(np.float32) for _ in range(8)]
        f0 = [q0.submit(list(r)) for r in reqs]
        f8 = [q8.submit(list(r)) for r in reqs]
        ok = True
        for a, b in zip(f0, f8):
            ua, ub = a.result(timeout=60), b.result(timeout=60)
            ok &= np.array_equal(np.asarray(ua[0]), np.asarray(ub[0]))
        return ok
    finally:
        q0.close()
        q8.close()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true",
                    help="few timing reps (CI smoke); same shapes")
    ap.add_argument("--out", default="BENCH_mesh_scaleout.json")
    args = ap.parse_args(argv)
    if jax.device_count() < 8:
        raise RuntimeError(
            f"mesh_scaleout needs 8 devices, found {jax.device_count()} — "
            "run in a fresh process (benchmarks/run.py --only mesh does)")
    reps = 10 if args.smoke else 40
    warmup = 3 if args.smoke else 8

    rng = np.random.RandomState(0)
    cparams = cmte.stack_members([_init_member(i) for i in range(K)])
    mesh8 = make_scaleout_mesh(8, 1)

    # --- headline: fused 8-device mesh vs sequential legacy on 1 device
    tp_leg, t_leg = _tput(_make_legacy(cparams), ROWS_HEADLINE, reps,
                          warmup, as_list=True)
    tp_f1, t_f1 = _tput(_fused(cparams, None), ROWS_HEADLINE, reps, warmup)
    tp_m8, t_m8 = _tput(_fused(cparams, mesh8), ROWS_HEADLINE, reps, warmup)
    headline = tp_m8 / tp_leg
    print(f"headline rows={ROWS_HEADLINE}: legacy {t_leg * 1e3:.2f} ms, "
          f"fused(1dev) {t_f1 * 1e3:.2f} ms, fused(8x1 mesh) "
          f"{t_m8 * 1e3:.2f} ms -> mesh8/legacy {headline:.2f}x "
          f"(mesh8/fused1 {tp_m8 / tp_f1:.2f}x)", flush=True)

    # --- weak scaling: fixed rows/device, data axis 1 -> 8
    weak = {}
    tp_base = None
    for nd in (1, 2, 4, 8):
        mesh = None if nd == 1 else make_scaleout_mesh(nd, 1)
        tp, med = _tput(_fused(cparams, mesh), ROWS_PER_DEVICE * nd,
                        reps, warmup)
        tp_base = tp_base or tp
        weak[str(nd)] = {"rows": ROWS_PER_DEVICE * nd,
                         "ms": med * 1e3, "rows_per_s": tp,
                         "ratio_vs_1dev": tp / tp_base}
        print(f"weak scaling {nd} dev: rows={ROWS_PER_DEVICE * nd} "
              f"{med * 1e3:.2f} ms  ratio {tp / tp_base:.2f}x", flush=True)

    # --- committee axis: one member per device on the (1, 8) mesh
    tp_c1, t_c1 = _tput(_fused(cparams, None), ROWS_COMMITTEE, reps, warmup)
    tp_c8, t_c8 = _tput(_fused(cparams, make_scaleout_mesh(1, 8)),
                        ROWS_COMMITTEE, reps, warmup)
    print(f"committee axis rows={ROWS_COMMITTEE}: 1dev {t_c1 * 1e3:.2f} ms, "
          f"(1x8) mesh {t_c8 * 1e3:.2f} ms  ratio {tp_c8 / tp_c1:.2f}x",
          flush=True)

    # --- parity flags (bit-identity vs the unsharded engine)
    flags = {
        "parity_score": bool(parity_score(cparams, mesh8, rng)),
        "parity_score_after": bool(parity_score_after(cparams, mesh8, rng)),
        "parity_train": bool(parity_train(cparams, mesh8, rng)),
        "parity_serving": bool(parity_serving(cparams, mesh8, rng)),
    }
    print("parity:", " ".join(f"{k.split('_', 1)[1]}={v}"
                              for k, v in flags.items()), flush=True)

    report = {
        "meta": bench_meta(mesh_shape="8x1"),
        "config": {"K": K, "in_dim": D, "hidden": HIDDEN,
                   "threshold": THRESHOLD, "rows_headline": ROWS_HEADLINE,
                   "rows_per_device": ROWS_PER_DEVICE,
                   "rows_committee_axis": ROWS_COMMITTEE, "reps": reps},
        "legacy_1dev": {"ms": t_leg * 1e3, "rows_per_s": tp_leg},
        "fused_1dev": {"ms": t_f1 * 1e3, "rows_per_s": tp_f1},
        "fused_mesh8_data": {"ms": t_m8 * 1e3, "rows_per_s": tp_m8},
        "speedup_mesh8_vs_legacy_1dev": headline,
        "speedup_mesh8_vs_fused_1dev": tp_m8 / tp_f1,
        "weak_scaling": {"curve": weak,
                         "ratio_8dev": weak["8"]["ratio_vs_1dev"]},
        "committee_axis": {"mesh": "1x8", "ms": t_c8 * 1e3,
                           "rows_per_s": tp_c8,
                           "ratio_vs_1dev": tp_c8 / tp_c1},
        **flags,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    if not all(flags.values()):
        print("PARITY FAILURE — a mesh path changed numerics",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
