"""Benchmark: queue-batched, mesh-sharded committee serving at request
scale (serving/queue.ServingQueue + the sharded FusedEngine) vs per-call
``CommitteeServer.predict``.

The request-scale workload the ROADMAP north-star names: many concurrent
clients, each asking for ONE committee prediction + UQ.  Per-call serving
pays a full engine dispatch (pad to bucket, launch, sync) per request;
the queue accumulates requests into microbatches on a size-or-deadline
trigger and pays one dispatch per ``max_batch`` requests, through the
SAME fused acquisition dispatch — and, with ``mesh=``, the same dispatch
laid out over the device mesh (committee over 'model', requests over
'data'; degenerate on a 1-device host, where sharded parity is what's
being exercised).

Metrics, written to ``BENCH_serving_queue.json``:

* requests/s — per-call baseline (serial caller loop at request size 1)
  vs queued (N submitter threads driving the microbatcher);
* per-request latency p50/p99 (submit -> result) for both paths;
* ``queued_vs_percall_speedup`` — the headline ratio
  (acceptance: >= 3x on CPU at request size 1);
* amortization — requests per dispatch the queue realized.

Usage:  PYTHONPATH=src python benchmarks/serving_queue.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import numpy as np

from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.launch.mesh import make_host_mesh
from repro.serving import CommitteeServer, QueueConfig, ServingQueue

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


try:        # `python -m benchmarks.run` (package) vs direct script run
    from benchmarks.committee_uq import (
        K, N_GEN, IN_DIM, HIDDEN, OUT_DIM, THRESHOLD, _inputs, _make_members,
        _mlp_apply,
    )
except ImportError:
    from committee_uq import (
        K, N_GEN, IN_DIM, HIDDEN, OUT_DIM, THRESHOLD, _inputs, _make_members,
        _mlp_apply,
    )

MAX_BATCH = 64          # = one engine shape bucket: queue adds no traces
MAX_WAIT_MS = 5.0
SUBMITTERS = 8          # client threads
WINDOW = 16             # outstanding requests per client (bounded pipeline):
                        # 8 x 16 = 128 in flight keeps full microbatches
                        # reachable without unbounded backlog latency


def _percentiles(lat_s):
    lat_ms = np.asarray(lat_s) * 1e3
    return (float(np.percentile(lat_ms, 50)),
            float(np.percentile(lat_ms, 99)))


def bench_percall(server, requests):
    """Baseline: one CommitteeServer.predict per size-1 request."""
    lat = []
    t0 = time.perf_counter()
    for row in requests:
        t1 = time.perf_counter()
        server.predict([row])
        lat.append(time.perf_counter() - t1)
    wall = time.perf_counter() - t0
    return wall, lat


def bench_queued(queue, requests, submitters=SUBMITTERS, window=WINDOW):
    """N client threads each drive a bounded pipeline of size-1 requests:
    up to ``window`` outstanding futures per client (requests keep arriving
    while earlier ones are in flight — the many-tiny-clients shape), with
    per-request latency stamped submit -> resolve."""
    chunks = [requests[i::submitters] for i in range(submitters)]
    lat_chunks = [[] for _ in range(submitters)]

    def client(rows, lat):
        gate = threading.Semaphore(window)

        def done(t1, fut):
            lat.append(time.perf_counter() - t1)
            gate.release()
            fut.result()        # surface dispatch errors

        futs = []
        for row in rows:
            gate.acquire()
            t1 = time.perf_counter()
            fut = queue.submit([row])
            fut.add_done_callback(lambda f, t1=t1: done(t1, f))
            futs.append(fut)
        for f in futs:
            f.result()

    threads = [threading.Thread(target=client, args=(c, l))
               for c, l in zip(chunks, lat_chunks)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return wall, [v for l in lat_chunks for v in l]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--out", default="BENCH_serving_queue.json")
    args = ap.parse_args(argv)
    # smoke still needs a few hundred ms of steady state: thread startup
    # and the first deadline-paced dispatches dominate shorter runs
    n_requests = args.requests or (1024 if args.smoke else 4096)

    rng = np.random.RandomState(0)
    members = _make_members(rng)
    cparams = cmte.stack_members(members)
    requests = _inputs(rng, n_requests)

    # --- per-call baseline: unsharded engine, one dispatch per request ----
    eng_base = acq.FusedEngine(_mlp_apply, cparams, THRESHOLD, impl="xla")
    server_base = CommitteeServer(eng_base, None)
    server_base.predict([requests[0]])          # warm the size-1 bucket
    pc_wall, pc_lat = bench_percall(server_base, requests)
    pc_rps = n_requests / pc_wall
    pc_p50, pc_p99 = _percentiles(pc_lat)

    # --- queued + sharded: mesh-parallel engine behind the microbatcher ---
    eng_mesh = acq.FusedEngine(_mlp_apply, cparams, THRESHOLD, impl="xla",
                               mesh=make_host_mesh())
    server_mesh = CommitteeServer(eng_mesh, None)
    # warm every bucket a partial microbatch can land in, so measured
    # latency is steady-state serving, not first-call compiles
    b = 8
    while b <= MAX_BATCH:
        server_mesh.predict(requests[:b])
        b *= 2
    with ServingQueue(server_mesh,
                      QueueConfig(max_batch=MAX_BATCH,
                                  max_wait_ms=MAX_WAIT_MS)) as queue:
        q_wall, q_lat = bench_queued(queue, requests)
        dispatches = queue.dispatches
        batched = queue.batched_requests
    q_rps = n_requests / q_wall
    q_p50, q_p99 = _percentiles(q_lat)
    speedup = q_rps / pc_rps
    amortization = batched / max(dispatches, 1)

    # queue must reuse the engine's power-of-two buckets: traces only at
    # bucket sizes, never one per microbatch size
    trace_buckets = sorted(eng_mesh.trace_counts)
    traces_ok = all(c == 1 for c in eng_mesh.trace_counts.values())

    report = {
        "meta": bench_meta(),
        "config": {"K": K, "in_dim": IN_DIM, "hidden": HIDDEN,
                   "out_dim": OUT_DIM, "threshold": THRESHOLD,
                   "n_requests": n_requests, "request_size": 1,
                   "max_batch": MAX_BATCH, "max_wait_ms": MAX_WAIT_MS,
                   "submitters": SUBMITTERS, "mesh": "host (1x1)",
                   "backend": jax.default_backend()},
        "percall": {"requests_per_s": pc_rps, "p50_ms": pc_p50,
                    "p99_ms": pc_p99},
        "queued_sharded": {"requests_per_s": q_rps, "p50_ms": q_p50,
                           "p99_ms": q_p99, "dispatches": dispatches,
                           "requests_per_dispatch": amortization},
        "queued_vs_percall_speedup": speedup,
        "queue_reuses_engine_buckets": bool(traces_ok),
        "trace_buckets": [int(b) for b in trace_buckets],
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"per-call     : {pc_rps:8.0f} req/s   "
          f"p50 {pc_p50:.2f} ms  p99 {pc_p99:.2f} ms")
    print(f"queued+shard : {q_rps:8.0f} req/s   "
          f"p50 {q_p50:.2f} ms  p99 {q_p99:.2f} ms   "
          f"({amortization:.1f} req/dispatch)")
    print(f"speedup {speedup:.2f}x  (acceptance >= 3x)   "
          f"bucket traces once: {traces_ok} {trace_buckets}")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
