"""Benchmark: oracle/generator pool scaling (paper §2, Fig. 2).

Measures labeled-samples-per-second as the oracle pool grows (strong
scaling of the labeling stage) and exchange iterations/s as the generator
pool grows — the two pools the paper parallelizes.
"""
from __future__ import annotations

import csv
import sys
import tempfile
import time

import numpy as np

from repro.configs.pal_potential import PALRunConfig
from repro.core import PAL, UserGene, UserModel, UserOracle

T_ORACLE = 0.01


class Gene(UserGene):
    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)

    def generate_new_data(self, d):
        time.sleep(0.0005)   # yield: keep the exchange thread from starving
        return False, self.rng.randn(4).astype(np.float32)  # oracle workers


class Model(UserModel):
    def __init__(self, rank, rd, dev, mode):
        super().__init__(rank, rd, dev, mode)
        self.w = np.eye(4)

    def predict(self, ld):
        return [np.asarray(x) @ self.w for x in ld]

    def update(self, a):
        pass

    def get_weight(self):
        return self.w.reshape(-1).astype(np.float32)

    def get_weight_size(self):
        return 16

    def add_trainingset(self, d):
        pass

    def retrain(self, req):
        time.sleep(0.01)
        return False


class Oracle(UserOracle):
    def run_calc(self, inp):
        time.sleep(T_ORACLE)
        return inp, np.asarray(inp) * 2


def oracle_scaling(pool_sizes=(1, 2, 4, 8), seconds=3.0):
    rows = []
    for p in pool_sizes:
        cfg = PALRunConfig(result_dir=tempfile.mkdtemp(), gene_process=8,
                           orcl_process=p, pred_process=1, ml_process=1,
                           retrain_size=10 ** 9, std_threshold=-1.0,
                           dynamic_oracle_list=False, oracle_timeout=1e6)
        pal = PAL(cfg, make_generator=Gene, make_model=Model,
                  make_oracle=Oracle)
        pal.start()
        time.sleep(0.5)                      # warmup
        n0 = pal.train_buffer.total_labeled
        t0 = time.perf_counter()
        time.sleep(seconds)
        rate = (pal.train_buffer.total_labeled - n0) / (
            time.perf_counter() - t0)
        pal.shutdown()
        ideal = p / T_ORACLE
        rows.append({"oracle_workers": p,
                     "labels_per_s": round(rate, 1),
                     "ideal_labels_per_s": round(ideal, 1),
                     "efficiency": round(rate / ideal, 3)})
    return rows


def generator_scaling(pool_sizes=(1, 4, 16, 64), seconds=2.0):
    rows = []
    for g in pool_sizes:
        cfg = PALRunConfig(result_dir=tempfile.mkdtemp(), gene_process=g,
                           orcl_process=1, pred_process=1, ml_process=1,
                           retrain_size=10 ** 9, std_threshold=1e9,
                           dynamic_oracle_list=False, oracle_timeout=1e6)
        pal = PAL(cfg, make_generator=Gene, make_model=Model,
                  make_oracle=Oracle)
        pal.start()
        time.sleep(0.3)
        n0 = pal.exchange.iteration
        t0 = time.perf_counter()
        time.sleep(seconds)
        it_rate = (pal.exchange.iteration - n0) / (time.perf_counter() - t0)
        pal.shutdown()
        rows.append({"generators": g,
                     "exchange_iters_per_s": round(it_rate, 1),
                     "proposals_per_s": round(it_rate * g, 1)})
    return rows


def main():
    rows = oracle_scaling()
    wr = csv.DictWriter(sys.stdout, fieldnames=rows[0].keys())
    wr.writeheader()
    for r in rows:
        wr.writerow(r)
    print()
    rows = generator_scaling()
    wr = csv.DictWriter(sys.stdout, fieldnames=rows[0].keys())
    wr.writeheader()
    for r in rows:
        wr.writerow(r)


if __name__ == "__main__":
    main()
