"""Benchmark: device-resident exploration fleet vs N host generators.

The legacy exploration path runs one host walker per generator rank: every
exchange iteration pays N Python ``generate_new_data`` calls (numpy
integrate), a host gather, one fused scoring dispatch WITH an (N, d)
upload, and a full (N, d) mean download scattered back to the walkers.
The ``exploration/fleet.WalkerFleet`` keeps all N walker states on device
and fuses the sampler advance with committee forward + Welford UQ +
selection into ONE compiled program per step
(``FusedEngine.score_after``), so the only per-iteration host traffic is
the selected oracle candidates plus one int32 count.

Metrics written to ``BENCH_exploration_fleet.json``:

* proposals/second through the Exchange loop, host-generator path vs
  fleet path at N=64 walkers -> ``speedup_proposals_per_s``
  (reference full run: ~8.4x on the CPU CI host, ~10.7x at the smoke
  budget; the CI gate's absolute floor is >= 5x);
* per-iteration engine host traffic on the fleet path with nothing
  selected: uploads must be ZERO bytes and downloads exactly the 4-byte
  selected count -> ``fleet_zero_upload_bytes`` /
  ``fleet_host_bytes_per_iter``.

Both paths run the SAME committee, the same euler update constants, and
the same (all-certain) selection outcome, so the ratio isolates the
dispatch/transfer structure, not the workload.

Usage:  PYTHONPATH=src python benchmarks/exploration_fleet.py
            [--smoke] [--walkers 64] [--out F]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.core.buffers import OracleInputBuffer
from repro.core.controller import Exchange, ExchangeConfig, PredictionPool
from repro.exploration.fleet import FleetConfig, WalkerFleet

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


D = 24              # walker dimension (8 atoms x 3, flattened)
K = 4               # committee members (paper §3.1)
HIDDEN = 64
DT, CLIP, NOISE = 0.002, 20.0, 0.01
PATIENCE = 1000     # keep both paths restart-free: measure steady state


def _mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _committee(rng):
    members = [{
        "w1": jnp.asarray(rng.randn(D, HIDDEN).astype(np.float32) * 0.1),
        "b1": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * 0.05),
        "w2": jnp.asarray(rng.randn(HIDDEN, D).astype(np.float32) * 0.1),
        "b2": jnp.asarray(rng.randn(D).astype(np.float32) * 0.05),
    } for _ in range(K)]
    return cmte.stack_members(members)


class HostWalker:
    """The host baseline: the ``examples/quickstart.MDGenerator`` update
    (euler + clip + thermal noise) as one numpy walker per rank."""

    def __init__(self, rank, x0):
        self.x0 = np.asarray(x0, np.float32)
        self.x = self.x0.copy()
        self.rng = np.random.RandomState(rank)
        self.steps = 0

    def generate_new_data(self, data_to_gene):
        self.steps += 1
        if data_to_gene is None and self.steps > 1:
            self.x = self.x0.copy()
        elif data_to_gene is not None:
            f = np.clip(np.asarray(data_to_gene, np.float32), -CLIP, CLIP)
            self.x = (self.x + np.float32(DT) * f
                      + self.rng.randn(D).astype(np.float32)
                      * np.float32(NOISE)).astype(np.float32)
        return False, self.x

    def save_progress(self):
        pass

    def stop_run(self):
        pass


def _drive(ex, iters):
    t0 = time.perf_counter()
    for _ in range(iters):
        assert ex.step() is None
    return time.perf_counter() - t0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true",
                    help="few iterations (CI smoke)")
    ap.add_argument("--walkers", type=int, default=64)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_exploration_fleet.json")
    args = ap.parse_args(argv)
    n = args.walkers
    iters = args.iters or (40 if args.smoke else 200)
    rounds = args.rounds or (3 if args.smoke else 5)

    rng = np.random.RandomState(0)
    cparams = _committee(rng)
    x0 = (rng.randn(n, D) * 0.3).astype(np.float32)
    # threshold above any committee disagreement here: the measured loop is
    # the all-certain steady state (zero selected rows on both paths), so
    # the ratio is pure dispatch/transfer structure
    threshold = 1e6

    # --- host path: N generator objects through the legacy Exchange -------
    host_times = []
    for _ in range(rounds + 1):                    # first round warms the jit
        eng = acq.FusedEngine(_mlp_apply, cparams, threshold, impl="xla",
                              min_bucket=8)
        gens = [HostWalker(i, x0[i]) for i in range(n)]
        ex = Exchange(gens, PredictionPool([], None, engine=eng),
                      OracleInputBuffer(),
                      ExchangeConfig(std_threshold=threshold,
                                     patience=PATIENCE, min_interval=0.0))
        host_times.append(_drive(ex, iters))
    host_s = statistics.median(host_times[1:])

    # --- fleet path: one device-resident WalkerFleet ----------------------
    fleet_times, fleet_eng, fleet_obj = [], None, None
    for _ in range(rounds + 1):
        eng = acq.FusedEngine(_mlp_apply, cparams, threshold, impl="xla",
                              min_bucket=8)
        fleet = WalkerFleet(eng, x0, FleetConfig(
            dt=DT, clip=CLIP, noise=NOISE, patience=PATIENCE))
        ex = Exchange([], PredictionPool([], None, engine=eng),
                      OracleInputBuffer(),
                      ExchangeConfig(min_interval=0.0), fleet=fleet)
        ex.step()                                  # compile outside the clock
        b2d0, b2h0 = eng.bytes_to_device, eng.bytes_to_host
        fleet_times.append(_drive(ex, iters))
        fleet_eng, fleet_obj = eng, fleet
    fleet_s = statistics.median(fleet_times[1:])
    upload_per_iter = (fleet_eng.bytes_to_device - b2d0) / iters
    download_per_iter = (fleet_eng.bytes_to_host - b2h0) / iters

    host_pps = n * iters / host_s
    fleet_pps = n * iters / fleet_s
    report = {
        "meta": bench_meta(),
        "config": {"walkers": n, "dim": D, "K": K, "hidden": HIDDEN,
                   "iters": iters, "rounds": rounds,
                   "backend": jax.default_backend()},
        "host": {"proposals_per_s": host_pps,
                 "s_per_iter": host_s / iters,
                 "python_calls_per_iter": n},
        "fleet": {"proposals_per_s": fleet_pps,
                  "s_per_iter": fleet_s / iters,
                  "dispatches_per_iter": 1,
                  "bytes_to_device_per_iter": upload_per_iter,
                  "bytes_to_host_per_iter": download_per_iter,
                  "steps_done": fleet_obj.steps_done},
        "speedup_proposals_per_s": fleet_pps / host_pps,
        "fleet_zero_upload_bytes": upload_per_iter == 0,
        "fleet_host_bytes_per_iter": download_per_iter,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"host generators: {host_pps:,.0f} proposals/s "
          f"({n} python calls + 1 upload + 1 download per iter)")
    print(f"device fleet:    {fleet_pps:,.0f} proposals/s "
          f"(1 fused dispatch per iter)")
    print(f"speedup {report['speedup_proposals_per_s']:.2f}x")
    print(f"fleet host traffic/iter: {upload_per_iter:.0f} B up, "
          f"{download_per_iter:.0f} B down (unselected walkers: 0 B)")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
