"""Benchmark: labeled-throughput retention + recovery time under the
standard fault plan (core/chaos + core/supervisor, ISSUE 6 acceptance).

Two fixed-wall-clock PAL campaigns on the legacy toy kernels (no jax on
the hot path, so the numbers measure the RUNTIME, not compile noise):

* baseline — fault-free;
* chaos    — the standard plan: 3 transient oracle-task failures, one
  oracle-thread crash, one trainer crash mid-schedule (the legacy slice
  of ``FaultPlan.acceptance``; the nan_member event needs the fused
  committee trainer and is exercised in tests/test_chaos.py instead).

A sampler thread records ``(t, labeled_total, faults_fired)`` at ~5 ms so
recovery is measurable: for each loop-crash fault, ``recovery`` is the
time from the fault firing to the next labeled-count increase (how long
the supervised restart takes to resume useful work).

Metrics, written to ``BENCH_fault_recovery.json``:

* ``throughput_retention`` — chaos labels/s over baseline labels/s in the
  same wall-clock window (acceptance floor: >= 0.70);
* ``completed_without_stop`` — the chaos run reached the end of its
  window with ZERO supervisor escalations (no fault became a StopToken);
* ``recovery_time_s`` — worst per-crash recovery;
* restart/retry counters from the supervised runtime.

Usage:  PYTHONPATH=src python benchmarks/fault_recovery.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import tempfile
import threading
import time

import numpy as np

from repro.configs.pal_potential import PALRunConfig
from repro.core import PAL, UserGene, UserModel, UserOracle
from repro.core.chaos import ChaosInjector, FaultEvent, FaultPlan

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


STANDARD_PLAN = FaultPlan(events=(
    FaultEvent("oracle.task", 2, "raise", rank="oracle0"),
    FaultEvent("oracle.task", 4, "raise", rank="oracle1"),
    FaultEvent("oracle.task", 6, "raise", rank="oracle0"),
    FaultEvent("oracle.loop", 9, "crash", rank="oracle1"),
    FaultEvent("trainer.loop", 2, "crash"),
))


class _Gene(UserGene):
    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)

    def generate_new_data(self, data_to_gene):
        time.sleep(0.001)
        return False, self.rng.randn(4).astype(np.float32)


class _Model(UserModel):
    def __init__(self, rank, rd, dev, mode):
        super().__init__(rank, rd, dev, mode)
        self.w = np.random.RandomState(rank).randn(4, 4) * 0.5

    def predict(self, list_data):
        return [np.asarray(x) @ self.w for x in list_data]

    def update(self, warr):
        self.w = warr.reshape(4, 4)

    def get_weight(self):
        return self.w.reshape(-1).astype(np.float32)

    def get_weight_size(self):
        return 16

    def add_trainingset(self, dps):
        pass

    def retrain(self, req):
        for _ in range(10):
            if req.test():
                break
            time.sleep(0.002)
        self.w = self.w * 0.99
        return False


class _Oracle(UserOracle):
    def run_calc(self, inp):
        time.sleep(0.002)
        return inp, np.sin(2 * inp).astype(np.float32)


def _campaign(window_s: float, plan=None):
    """One fixed-window PAL run; returns (labeled_total, report, samples)
    where samples = [(t_rel, labeled_total, faults_fired)] at ~5 ms."""
    cfg = PALRunConfig(
        result_dir=tempfile.mkdtemp(), gene_process=4, orcl_process=3,
        pred_process=2, ml_process=2, retrain_size=8, std_threshold=0.05,
        patience=3, loop_restart_backoff_s=0.05, oracle_task_backoff_s=0.01)
    chaos = ChaosInjector(plan) if plan is not None else None
    pal = PAL(cfg, make_generator=_Gene, make_model=_Model,
              make_oracle=_Oracle, chaos=chaos)

    samples = []
    done = threading.Event()

    def sampler():
        t0 = time.perf_counter()
        while not done.is_set():
            samples.append((time.perf_counter() - t0,
                            pal.train_buffer.total_labeled,
                            len(chaos.fired) if chaos is not None else 0))
            done.wait(0.005)

    th = threading.Thread(target=sampler, daemon=True)
    th.start()
    tok = pal.run(timeout=window_s)
    done.set()
    th.join(timeout=5)
    rep = pal.report()
    rep["stop_token"] = repr(tok)
    rep["stop_origin"] = tok.origin if tok is not None else None
    return pal.train_buffer.total_labeled, rep, samples


def _recovery_times(samples):
    """For each fault firing observed by the sampler, the time until the
    labeled count next increases (supervised restart back to useful
    work).  Transient task faults barely dent throughput; the loop-crash
    recoveries dominate the max."""
    out = []
    for i in range(1, len(samples)):
        t_f, labeled_f, fired_f = samples[i]
        if fired_f <= samples[i - 1][2]:
            continue
        t_rec = None
        for t, labeled, _ in samples[i:]:
            if labeled > labeled_f:
                t_rec = t - t_f
                break
        out.append(t_rec if t_rec is not None else float("inf"))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true")
    ap.add_argument("--window", type=float, default=None,
                    help="seconds per campaign (default 4 quick / 10 full)")
    ap.add_argument("--out", default="BENCH_fault_recovery.json")
    args = ap.parse_args(argv)
    window = args.window or (4.0 if args.smoke else 10.0)

    base_labeled, base_rep, _ = _campaign(window)
    chaos_labeled, chaos_rep, samples = _campaign(window, STANDARD_PLAN)

    base_rate = base_labeled / window
    chaos_rate = chaos_labeled / window
    retention = chaos_rate / base_rate if base_rate else 0.0
    recoveries = _recovery_times(samples)
    recovery = max(recoveries) if recoveries else 0.0
    c = chaos_rep["counters"]
    completed = (c.get("supervisor.escalations", 0) == 0
                 and chaos_rep["stop_origin"] == "runtime")  # window timeout,
    #                                            not a fault-raised StopToken

    report = {
        "meta": bench_meta(),
        "config": {"window_s": window, "orcl_process": 3, "gene_process": 4,
                   "ml_process": 2, "plan_events": len(STANDARD_PLAN.events)},
        "baseline": {"labeled": base_labeled, "labels_per_s": base_rate},
        "chaos": {"labeled": chaos_labeled, "labels_per_s": chaos_rate,
                  "faults_injected": len(samples) and samples[-1][2],
                  "fired": chaos_rep.get("chaos_fired", []),
                  "thread_restarts": chaos_rep["thread_restarts"],
                  "task_retries": c.get("oracle.task_retries", 0),
                  "stop": chaos_rep["stop_token"]},
        "throughput_retention": retention,
        "completed_without_stop": bool(completed),
        "recovery_time_s": recovery,
        "recovery_times_s": recoveries,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"baseline : {base_labeled:5d} labels in {window:.0f}s "
          f"({base_rate:.0f}/s)")
    print(f"chaos    : {chaos_labeled:5d} labels in {window:.0f}s "
          f"({chaos_rate:.0f}/s)  faults={report['chaos']['faults_injected']} "
          f"restarts={chaos_rep['thread_restarts']}")
    print(f"retention {retention:.2f}  (acceptance >= 0.70)   "
          f"recovery {recovery * 1e3:.0f} ms   "
          f"completed_without_stop={completed}")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
