"""Benchmark: big-committee memory diet — stacked TrainState bytes and
fused step time across K x MemoryPolicy.

Committee size K is the UQ quality lever, and the stacked fp32 TrainState
is the memory wall that caps it.  ``optim/memory_policy.MemoryPolicy``
makes per-member storage a policy (fp32 | bf16 | int8 QTensor moments);
this benchmark demonstrates the ISSUE's acceptance claim: a K=64 committee
trains AND scores through the existing fused one-dispatch paths with int8
moments at a fraction of the fp32 optimizer-state bytes and near-K=8
per-member-normalized step time.

Metrics written to ``BENCH_committee_memory.json`` (one cell per
K x policy):

* measured stacked TrainState bytes (total + optimizer subtree) — and an
  exactness cross-check against ``launch/dryrun.committee_state_bytes``
  (the eval_shape estimator) -> ``estimate_matches_measured``;
* ms per fused train step (median over rounds) and per-member-normalized
  step time;
* HEADLINE ``opt_bytes_ratio_int8_vs_fp32_k64`` (gate: <= 0.40) and
  ``steptime_per_member_ratio_int8_k64_vs_fp32_k8`` (gate: <= 1.5x),
  enforced by ``tools/check_bench.py``;
* ``k64_scores_fused_all_backends`` — the K=64 int8-trained committee
  scores through ``FusedEngine`` on BOTH fused UQ backends ('xla' and
  'pallas_interpret') via the zero-copy device handoff.

Usage:  PYTHONPATH=src python benchmarks/committee_memory.py [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.optim.memory_policy import MemoryPolicy, stacked_state_nbytes
from repro.training.committee_trainer import CommitteeTrainer

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


K_LIST = (8, 32, 64)
POLICIES = ("fp32", "bf16", "int8")
IN_DIM = 16
HIDDEN = 64
OUT_DIM = 4
N_DATA = 512
BATCH = 32
LR = 1e-3
UQ_BACKENDS = ("xla", "pallas_interpret")


def _mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, batch):
    pred = _mlp_apply(p, batch["x"])
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _make_members(rng, k):
    return [{
        "w1": jnp.asarray(rng.randn(IN_DIM, HIDDEN).astype(np.float32) * 0.3),
        "b1": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * 0.1),
        "w2": jnp.asarray(rng.randn(HIDDEN, OUT_DIM).astype(np.float32) * 0.3),
        "b2": jnp.asarray(rng.randn(OUT_DIM).astype(np.float32) * 0.1),
    } for _ in range(k)]


def _tree_nbytes(tree) -> int:
    return sum(int(np.asarray(l).nbytes) for l in jax.tree.leaves(tree))


def bench_cell(k, policy_name, xs_h, ys_h, steps, rounds):
    """One K x policy cell: build, train, measure bytes + ms/step."""
    rng = np.random.RandomState(0)
    members = _make_members(rng, k)
    cparams = cmte.stack_members(members)
    policy = MemoryPolicy.named(policy_name)
    tr = CommitteeTrainer(_loss, cparams, steps=steps, batch=BATCH, lr=LR,
                          bootstrap=True, replay_capacity=N_DATA, seed=0,
                          memory_policy=policy)
    tr.add_blocks(list(zip(xs_h, ys_h)))

    tr.train(steps=2)                            # compile + warmup
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        tr.train(steps=steps)
        jax.tree.map(lambda a: a.block_until_ready(), tr.cparams)
        times.append((time.perf_counter() - t0) / steps)
    ms_per_step = statistics.median(times) * 1e3

    total = _tree_nbytes(tr.cstate)
    opt = _tree_nbytes(tr.cstate.opt)
    est = stacked_state_nbytes(members[0], k, policy)
    final_loss = tr._last_metrics["loss"] if tr._last_metrics else None
    return tr, {
        "K": k, "policy": policy_name,
        "state_bytes_total": total,
        "state_bytes_opt": opt,
        "state_bytes_estimated": est,
        "estimate_exact": est == total,
        "ms_per_step": ms_per_step,
        "ms_per_step_per_member": ms_per_step / k,
        "loss_finite": bool(np.all(np.isfinite(np.asarray(final_loss)))),
    }


def score_all_backends(trainer, xs_h):
    """K=64 committee through BOTH fused UQ backends via the zero-copy
    device handoff — finite stds, zero packed host bytes."""
    out = {}
    for impl in UQ_BACKENDS:
        eng = acq.FusedEngine(_mlp_apply, trainer.cparams, 0.5, impl=impl)
        eng.refresh_host_bytes = 0
        eng.refresh_from_device(trainer.snapshot_cparams())
        res = eng.score(xs_h[:32])
        out[impl] = {
            "std_finite": bool(np.all(np.isfinite(res.scalar_std))),
            "refresh_host_bytes": int(eng.refresh_host_bytes),
        }
        out[impl]["ok"] = (out[impl]["std_finite"]
                           and out[impl]["refresh_host_bytes"] == 0)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true",
                    help="few iterations (CI smoke)")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_committee_memory.json")
    args = ap.parse_args(argv)
    steps = args.steps or (10 if args.smoke else 40)
    rounds = args.rounds or (3 if args.smoke else 7)

    rng = np.random.RandomState(1)
    xs_h = rng.randn(N_DATA, IN_DIM).astype(np.float32)
    ys_h = rng.randn(N_DATA, OUT_DIM).astype(np.float32)

    cells = {}
    trainers = {}
    for k in K_LIST:
        for pol in POLICIES:
            tr, cell = bench_cell(k, pol, xs_h, ys_h, steps, rounds)
            cells[f"K{k}_{pol}"] = cell
            trainers[(k, pol)] = tr
            print(f"K={k:3d} {pol:5s}: "
                  f"state {cell['state_bytes_total']:>9d} B "
                  f"(opt {cell['state_bytes_opt']:>9d} B)  "
                  f"{cell['ms_per_step']:.2f} ms/step  "
                  f"{cell['ms_per_step_per_member'] * 1e3:.1f} us/member",
                  flush=True)

    kmax = K_LIST[-1]
    opt_ratio = (cells[f"K{kmax}_int8"]["state_bytes_opt"]
                 / cells[f"K{kmax}_fp32"]["state_bytes_opt"])
    step_ratio = (cells[f"K{kmax}_int8"]["ms_per_step_per_member"]
                  / cells[f"K{K_LIST[0]}_fp32"]["ms_per_step_per_member"])
    backends = score_all_backends(trainers[(kmax, "int8")], xs_h)

    report = {
        "meta": bench_meta(),
        "config": {"K_list": list(K_LIST), "policies": list(POLICIES),
                   "in_dim": IN_DIM, "hidden": HIDDEN, "out_dim": OUT_DIM,
                   "n_data": N_DATA, "batch": BATCH,
                   "steps_per_round": steps, "rounds": rounds,
                   "backend": jax.default_backend()},
        "cells": cells,
        "k64_uq_backends": backends,
        "opt_bytes_ratio_int8_vs_fp32_k64": opt_ratio,
        "steptime_per_member_ratio_int8_k64_vs_fp32_k8": step_ratio,
        "estimate_matches_measured": all(c["estimate_exact"]
                                         for c in cells.values()),
        "k64_scores_fused_all_backends": all(b["ok"]
                                             for b in backends.values()),
        "all_losses_finite": all(c["loss_finite"] for c in cells.values()),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"\nopt-state bytes  int8 K{kmax} / fp32 K{kmax}: "
          f"{opt_ratio:.3f}  (gate <= 0.40)")
    print(f"per-member step  int8 K{kmax} / fp32 K{K_LIST[0]}: "
          f"{step_ratio:.2f}x (gate <= 1.5x)")
    print(f"K{kmax} scores on fused backends {UQ_BACKENDS}: "
          f"{report['k64_scores_fused_all_backends']}")
    print(f"estimator exact on all {len(cells)} cells: "
          f"{report['estimate_matches_measured']}")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
