"""Benchmark: fused single-dispatch acquisition engine vs sequential members.

The seed exchange iteration dispatches K sequential ``model.predict`` calls,
round-trips the full (K, n_gen, out_dim) prediction tensor to host, and
recomputes committee std in float64 NumPy (core/selection.prediction_check).
The unified acquisition engine (core/acquisition.FusedEngine + kernels/ops
``committee_uq``) runs the vmapped committee forward, the UQ statistics
(mean / max-component std / mean-component std), AND the selection-rule
pipeline as ONE compiled device program and ships only
(mean, scalar_std, component_std, mask) back.

Metrics per configuration, written to ``BENCH_committee_uq.json``:

* wall-clock per exchange iteration (median), sequential vs fused — plus a
  fused run with a CUSTOM rule pipeline (threshold + top-fraction), which
  must stay on the single-dispatch path (no (K, n_gen, out_dim) transfer)
* host bytes per iteration — bytes crossing the host<->device boundary
  plus bytes the UQ step materializes in host memory (the float64
  (K, n_gen, out_dim) copy + std/mean intermediates of the seed check;
  zero for the fused path, whose UQ never leaves the device)

Also sweeps ``n_gen`` across iterations to demonstrate the power-of-two
shape-bucketed jit cache: compile counts per bucket are recorded and must
be 1.

Usage:  PYTHONPATH=src python benchmarks/committee_uq.py [--quick] [--out F]
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.core import selection as sel

try:
    from benchmarks.run import bench_meta
except ImportError:          # running as a script from benchmarks/
    from run import bench_meta


K = 8               # committee members (acceptance: >=2x at K=8, n_gen=64)
N_GEN = 64
IN_DIM = 16
HIDDEN = 64
OUT_DIM = 4
THRESHOLD = 0.5


def _mlp_apply(p, x):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _make_members(rng):
    members = []
    for _ in range(K):
        members.append({
            "w1": jnp.asarray(rng.randn(IN_DIM, HIDDEN).astype(np.float32)
                              * 0.3),
            "b1": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * 0.1),
            "w2": jnp.asarray(rng.randn(HIDDEN, OUT_DIM).astype(np.float32)
                              * 0.3),
            "b2": jnp.asarray(rng.randn(OUT_DIM).astype(np.float32) * 0.1),
        })
    return members


def _inputs(rng, n):
    return [rng.randn(IN_DIM).astype(np.float32) for _ in range(n)]


def bench_sequential(members, batches):
    """Seed path: K separate per-member dispatches + float64 host UQ."""
    fns = [jax.jit(_mlp_apply) for _ in members]     # one program per member
    times, up, down, host_uq = [], 0, 0, 0
    first = True
    for inputs in batches:
        t0 = time.perf_counter()
        x = np.stack(inputs)
        preds = []
        for fn, p in zip(fns, members):
            xd = jnp.asarray(x)                      # host -> device, per member
            preds.append(np.asarray(fn(p, xd)))      # device -> host, per member
        stacked = np.asarray(preds)
        res = sel.prediction_check(inputs, stacked, THRESHOLD)
        times.append(time.perf_counter() - t0)
        if first:       # byte accounting is shape-determined; count once
            n, d = x.shape[0], OUT_DIM
            up = len(members) * x.nbytes
            down = sum(p.nbytes for p in preds)
            # seed prediction_check materializes float64 preds + std + mean
            host_uq = (stacked.size + 2 * n * d) * 8
            first = False
        last = res
    return times, up, down, host_uq, last


def bench_fused(engine, batches):
    """Engine path: one dispatch, (mean, sstd, cstd, mask) back."""
    times = []
    engine.bytes_to_device = engine.bytes_to_host = 0
    n_iter = 0
    for inputs in batches:
        t0 = time.perf_counter()
        uq = engine.score(inputs)
        res = sel.selection_from_uq(inputs, uq)
        times.append(time.perf_counter() - t0)
        n_iter += 1
    return times, engine.bytes_to_device / n_iter, \
        engine.bytes_to_host / n_iter, res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", "--quick", dest="smoke", action="store_true",
                    help="few iterations (CI smoke)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default="BENCH_committee_uq.json")
    args = ap.parse_args(argv)
    iters = args.iters or (20 if args.smoke else 200)
    warmup = 3 if args.smoke else 10

    rng = np.random.RandomState(0)
    members = _make_members(rng)
    cparams = cmte.stack_members(members)
    engine = acq.FusedEngine(_mlp_apply, cparams, THRESHOLD, impl="xla")

    batches = [_inputs(rng, N_GEN) for _ in range(warmup + iters)]
    seq_t, sq_up, sq_down, sq_host, res_a = bench_sequential(members, batches)
    fus_t, fu_up, fu_down, res_b = bench_fused(engine, batches)
    seq_ms = statistics.median(seq_t[warmup:]) * 1e3
    fus_ms = statistics.median(fus_t[warmup:]) * 1e3

    # custom selection rules stay on the single-dispatch path: same engine
    # machinery, threshold + top-fraction compiled into the jit
    engine_rules = acq.FusedEngine(
        _mlp_apply, cparams, THRESHOLD, impl="xla",
        rules=(acq.ThresholdRule(THRESHOLD), acq.TopFractionRule(0.25)))
    rul_t, ru_up, ru_down, _ = bench_fused(engine_rules, batches)
    rul_ms = statistics.median(rul_t[warmup:]) * 1e3

    # selection agreement sanity (same inputs, same committee); a sample
    # whose fp32 device std lands within rounding of the threshold may
    # legitimately flip vs the float64 host path — only flag disagreement
    # away from the boundary
    diff = res_a.uncertain_mask != res_b.uncertain_mask
    near = np.abs(res_a.std - THRESHOLD) < 1e-4 * max(1.0, THRESHOLD)
    assert not (diff & ~near).any(), \
        "fused and sequential paths disagree on selection off-threshold"

    # bucketed jit cache: varying n_gen must compile once per bucket
    engine2 = acq.FusedEngine(_mlp_apply, cparams, THRESHOLD, impl="xla")
    for n in (64, 48, 33, 64, 100, 9, 128, 65):
        engine2.score(_inputs(rng, n))
    buckets_ok = all(c == 1 for c in engine2.trace_counts.values())

    seq_bytes = sq_up + sq_down + sq_host
    fus_bytes = fu_up + fu_down
    report = {
        "meta": bench_meta(),
        "config": {"K": K, "n_gen": N_GEN, "in_dim": IN_DIM,
                   "hidden": HIDDEN, "out_dim": OUT_DIM,
                   "threshold": THRESHOLD, "iters": iters,
                   "backend": jax.default_backend()},
        "sequential": {"ms_per_iteration": seq_ms,
                       "bytes_host_to_device": sq_up,
                       "bytes_device_to_host": sq_down,
                       "bytes_host_uq_materialized": sq_host,
                       "bytes_total": seq_bytes},
        "fused": {"ms_per_iteration": fus_ms,
                  "bytes_host_to_device": fu_up,
                  "bytes_device_to_host": fu_down,
                  "bytes_host_uq_materialized": 0,
                  "bytes_total": fus_bytes},
        "fused_custom_rules": {"ms_per_iteration": rul_ms,
                               "bytes_host_to_device": ru_up,
                               "bytes_device_to_host": ru_down,
                               "bytes_host_uq_materialized": 0,
                               "bytes_total": ru_up + ru_down},
        "speedup_wallclock": seq_ms / fus_ms,
        "speedup_wallclock_custom_rules": seq_ms / rul_ms,
        "bytes_reduction_factor": seq_bytes / fus_bytes,
        "bytes_reduction_transfers_only":
            (sq_up + sq_down) / fus_bytes,
        "bucket_trace_counts": {str(k): v for k, v
                                in engine2.trace_counts.items()},
        "buckets_compile_once": buckets_ok,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)

    print(f"sequential:   {seq_ms:.3f} ms/iter  "
          f"({seq_bytes / 1024:.1f} KiB host bytes)")
    print(f"fused:        {fus_ms:.3f} ms/iter  "
          f"({fus_bytes / 1024:.1f} KiB host bytes)")
    print(f"fused+rules:  {rul_ms:.3f} ms/iter  "
          f"({(ru_up + ru_down) / 1024:.1f} KiB host bytes, "
          f"threshold+top-fraction on-device)")
    print(f"speedup {report['speedup_wallclock']:.2f}x   "
          f"(custom rules: {report['speedup_wallclock_custom_rules']:.2f}x)  "
          f"host-bytes reduction {report['bytes_reduction_factor']:.1f}x "
          f"(transfers only: "
          f"{report['bytes_reduction_transfers_only']:.1f}x)")
    print(f"bucket trace counts: {engine2.trace_counts} "
          f"(compile-once: {buckets_ok})")
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
