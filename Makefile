# Tier-1 verification + fused-exchange benchmark smoke + docs checks.
# `make check` is what CI runs (see .github/workflows/ci.yml).

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test bench-smoke bench bench-check docs docs-check

# sequential by construction (recipe lines, not prerequisites): under
# `make -j` prerequisite targets run concurrently, and bench-check must
# not read BENCH_*.json while bench-smoke is still writing them
check:
	$(MAKE) test
	$(MAKE) bench-smoke
	$(MAKE) bench-check
	$(MAKE) docs-check

test:
	$(PY) -m pytest -x -q

# hot-path + example-rot smoke: quick fused-engine + budget-controller +
# serving-queue benchmarks (write BENCH_*.json, uploaded as CI artifacts)
# and a short-budget quickstart run through the full PAL loop
bench-smoke:
	$(PY) benchmarks/committee_uq.py --quick
	$(PY) benchmarks/budget_controller.py --quick
	$(PY) benchmarks/serving_queue.py --quick
	$(PY) benchmarks/serving_tier.py --quick
	$(PY) -m benchmarks.run --only train --smoke
	$(PY) -m benchmarks.run --only memory --smoke
	$(PY) benchmarks/fault_recovery.py --quick
	$(PY) benchmarks/exploration_fleet.py --smoke
	$(PY) benchmarks/mesh_scaleout.py --quick
	$(PY) examples/quickstart.py --timeout 20

# regression gate: headline BENCH_*.json metrics vs the committed
# benchmarks/baselines/ (fails CI when a speedup/ratio regresses)
bench-check:
	$(PY) tools/check_bench.py

# regenerate the generated docs (docs/config.md from the config
# dataclasses) — run after changing PALRunConfig / PotentialConfig
docs:
	$(PY) tools/gen_config_docs.py

# docs smoke: docs/config.md must be byte-identical to a fresh
# regeneration, every ```python snippet in README.md / docs/*.md must
# run, and intra-repo markdown links must resolve
docs-check:
	$(PY) tools/gen_config_docs.py --check
	$(PY) tools/check_docs.py

bench:
	$(PY) -m benchmarks.run
