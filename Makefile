# Tier-1 verification + fused-exchange benchmark smoke + docs checks.
# `make check` is what CI runs (see .github/workflows/ci.yml).

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test bench-smoke bench docs-check

check: test bench-smoke docs-check

test:
	$(PY) -m pytest -x -q

# hot-path + example-rot smoke: quick fused-engine + budget-controller
# benchmarks (write BENCH_*.json, uploaded as CI artifacts) and a
# short-budget quickstart run through the full PAL loop
bench-smoke:
	$(PY) benchmarks/committee_uq.py --quick
	$(PY) benchmarks/budget_controller.py --quick
	$(PY) examples/quickstart.py --timeout 20

# docs smoke: run every ```python snippet in README.md / docs/*.md and
# verify intra-repo markdown links resolve
docs-check:
	$(PY) tools/check_docs.py

bench:
	$(PY) -m benchmarks.run
