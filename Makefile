# Tier-1 verification + fused-exchange benchmark smoke.
# `make check` is what CI runs (see .github/workflows/ci.yml).

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test bench-smoke bench

check: test bench-smoke

test:
	$(PY) -m pytest -x -q

# hot-path + example-rot smoke: quick fused-engine benchmark (writes
# BENCH_committee_uq.json, uploaded as a CI artifact) and a short-budget
# quickstart run through the full PAL loop
bench-smoke:
	$(PY) benchmarks/committee_uq.py --quick
	$(PY) examples/quickstart.py --timeout 20

bench:
	$(PY) -m benchmarks.run
