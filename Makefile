# Tier-1 verification + fused-exchange benchmark smoke.
# `make check` is what CI runs (see .github/workflows/ci.yml).

PY := python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: check test bench-smoke bench

check: test bench-smoke

test:
	$(PY) -m pytest -x -q

bench-smoke:
	$(PY) benchmarks/committee_uq.py --smoke

bench:
	$(PY) -m benchmarks.run
