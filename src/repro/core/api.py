"""User-kernel interfaces — faithful to the paper's S4–S7 method surface.

Users implement these four classes (prediction+training share ``UserModel``
with a ``mode`` flag, exactly as in the paper) plus the two utils functions
(see core/selection.py defaults).  The controller/runtime only ever calls
the methods below, so any paper-style kernel drops in unchanged.
"""
from __future__ import annotations

import abc
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.transport import Request


class UserModel(abc.ABC):
    """Prediction (mode='predict') / Training (mode='train') kernel (S4/S5)."""

    def __init__(self, rank: int, result_dir: str, i_device: int, mode: str):
        self.rank = rank
        self.result_dir = result_dir
        self.i_device = i_device
        self.mode = mode

    # ---- prediction side ---------------------------------------------------
    def predict(self, list_data_to_pred: Sequence[np.ndarray]
                ) -> List[np.ndarray]:
        """Inputs gathered from all generators -> predictions per generator."""
        raise NotImplementedError

    def update(self, weight_array: np.ndarray) -> None:
        """Install packed 1-D weights published by the training kernel."""
        raise NotImplementedError

    def get_weight_size(self) -> int:
        raise NotImplementedError

    # ---- training side -----------------------------------------------------
    def get_weight(self) -> np.ndarray:
        raise NotImplementedError

    def add_trainingset(self, datapoints: Sequence[Tuple[np.ndarray,
                                                         np.ndarray]]) -> None:
        raise NotImplementedError

    def retrain(self, req_data: Request) -> bool:
        """Train until new data arrives (req_data.test()) or early stop.
        Returns stop_run: True shuts the whole PAL workflow down."""
        raise NotImplementedError

    def save_progress(self) -> None:
        pass

    def stop_run(self) -> None:
        pass


class UserGene(abc.ABC):
    """Generator kernel (S6)."""

    def __init__(self, rank: int, result_dir: str):
        self.rank = rank
        self.result_dir = result_dir

    @abc.abstractmethod
    def generate_new_data(self, data_to_gene: Optional[np.ndarray]
                          ) -> Tuple[bool, np.ndarray]:
        """data_to_gene: predictions from the controller (None on the first
        iteration).  Returns (stop_run, data_to_pred)."""

    def save_progress(self) -> None:
        pass

    def stop_run(self) -> None:
        pass


class UserOracle(abc.ABC):
    """Oracle kernel (S7)."""

    def __init__(self, rank: int, result_dir: str):
        self.rank = rank
        self.result_dir = result_dir

    @abc.abstractmethod
    def run_calc(self, input_for_orcl: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (input_for_orcl, orcl_calc_res) — echoing the input back
        with the label, as the paper's controller expects."""

    def stop_run(self) -> None:
        pass
