"""PAL runtime: wires the five kernels into a running, fault-tolerant,
checkpointable system (paper Fig. 2 + DESIGN.md §2).

Acquisition is config-driven: ``PAL.__init__`` builds ONE
``core/acquisition.UQEngine`` from ``PALRunConfig`` (``uq_impl`` /
``uq_block_n`` / ``uq_bucket`` / ``std_threshold``) via
``acquisition.make_engine`` and installs it on the PredictionPool; the
Exchange hot loop and the Manager's ``dynamic_oracle_list`` consume the
same engine's ``UQResult``.  Pass ``committee=CommitteeSpec(apply_fn,
cparams)`` to get the fused single-dispatch backends (custom selection via
``rules=`` stays fused — rules compile into the dispatch); omit it and the
engine falls back to per-member ``UserModel.predict`` (the paper's
structure) with identical selection semantics.

Training is config-driven the same way: pass ``loss_fn=`` alongside the
``CommitteeSpec`` and the per-member ``ml_process`` trainer threads collapse
into ONE ``training/committee_trainer.CommitteeTrainer`` loop — all K
members advance in a single vmapped dispatch per step
(``PALRunConfig.train_steps`` / ``train_batch`` / ``train_lr`` /
``train_bootstrap``), fed from a device-resident replay ring, with
refreshed weights handed to the acquisition engine device-to-device
(``FusedEngine.refresh_from_device`` — no packed host round trip).  Omit
``loss_fn`` and the per-member ``make_model(..., 'train')`` factories
remain the legacy path, publishing packed weights through ``WeightStore``.

In-process realization: each kernel pool runs on threads (JAX releases the
GIL inside compiled code, so committee inference / retraining / oracle calls
genuinely overlap); the transport layer is MPI-shaped so the controller
logic matches the paper's process-based structure.  The ``task_per_node`` /
``gpu_*`` placement knobs of the paper map to ``placement`` here (recorded,
applied as device hints where meaningful on this host).

Beyond the paper: whole-state checkpoint/restart (including requeue of
dispatched-but-unlabeled oracle work), oracle heartbeats with
timeout->requeue, elastic pool resize, and monitoring (see core/fault.py,
core/al_checkpoint.py, core/monitor.py).
"""
from __future__ import annotations

import logging
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

log = logging.getLogger(__name__)

import numpy as np

from repro.configs.pal_potential import PALRunConfig
from repro.core import acquisition as acq
from repro.core import transport
from repro.core.al_checkpoint import ALCheckpointer
from repro.core.buffers import OracleInputBuffer, TrainingDataBuffer
from repro.core.chaos import ChaosCrash, ChaosInjector, FaultPlan
from repro.core.controller import (
    Exchange, ExchangeConfig, Manager, ManagerConfig, OracleTaskFailure,
    PredictionPool,
)
from repro.core.fault import ElasticPool
from repro.core.monitor import Monitor
from repro.core.supervisor import Supervisor, policies_from_config
from repro.core.transport import Channel, StopToken
from repro.core.weight_sync import WeightStore, WeightSyncPolicy


class PAL:
    """The parallel active-learning workflow.

    Parameters mirror the paper's AL_SETTING (SI S3): user supplies
    generator / model / oracle factories plus optional utils functions.
    """

    def __init__(
        self,
        run_cfg: PALRunConfig,
        *,
        make_generator: Callable[[int, str], Any],        # rank, result_dir
        make_model: Optional[Callable[[int, str, int, str], Any]] = None,
        make_oracle: Callable[[int, str], Any],
        committee: Optional[acq.CommitteeSpec] = None,
        loss_fn: Optional[Callable] = None,
        rules: Optional[Sequence[acq.SelectionRule]] = None,
        adjust_input_for_oracle: Optional[Callable] = None,
        predict_all_override: Optional[Callable] = None,
        mesh=None,
        sharding_rules=None,
        resume: bool = False,
        chaos: Optional[Union[FaultPlan, ChaosInjector]] = None,
        fleet_init: Optional[np.ndarray] = None,
    ):
        self.cfg = run_cfg
        self.monitor = Monitor()
        rd = run_cfg.result_dir
        # deterministic fault injection (core/chaos.py): a FaultPlan makes
        # this run execute a scheduled fault sequence — tests and the
        # fault-recovery benchmark drive recovery behavior through it
        if chaos is not None and not isinstance(chaos, ChaosInjector):
            chaos = ChaosInjector(chaos, monitor=self.monitor)
        self.chaos: Optional[ChaosInjector] = chaos

        # fused committee training: one CommitteeTrainer loop instead of
        # ml_process per-member trainer threads (loss_fn needs the stacked
        # committee params, hence the CommitteeSpec requirement)
        if loss_fn is not None and committee is None:
            raise ValueError(
                "loss_fn= enables the fused committee trainer, which needs "
                "committee=CommitteeSpec(apply_fn, cparams) for the stacked "
                "member params; pass one or use per-member make_model "
                "trainers")
        fused_training = loss_fn is not None

        # --- kernel instances (paper: one object per MPI process) ----------
        # fleet_walkers > 0: the gene_process host generators are replaced
        # by ONE device-resident WalkerFleet (built below, after the
        # engine) — host generator instances are only touched to derive
        # the fleet's trusted initial states when no fleet_init= is given
        use_fleet = getattr(run_cfg, "fleet_walkers", 0) > 0
        self.generators = [] if use_fleet else \
            [make_generator(i, rd) for i in range(run_cfg.gene_process)]
        # per-member prediction models exist only for the legacy backend
        # without a predict_all_override; fused engines score the stacked
        # committee directly (and an override supplies raw predictions
        # itself), so pred_process full model instances would be dead weight
        need_models = (predict_all_override is None
                       and acq.wants_legacy(run_cfg, committee))
        if (need_models or not fused_training) and make_model is None:
            raise ValueError(
                "make_model= is required unless a CommitteeSpec supplies "
                "prediction (fused engine) and a loss_fn supplies training "
                "(fused committee trainer)")
        self.predictors = [make_model(i, rd, i, "predict")
                           for i in range(run_cfg.pred_process)] \
            if need_models else []
        self.trainers = [] if fused_training else \
            [make_model(i, rd, i, "train")
             for i in range(run_cfg.ml_process)]
        self._make_oracle = make_oracle
        self._oracle_instances: Dict[str, Any] = {}

        # --- controller state ----------------------------------------------
        # fused training: the store is demoted to the checkpoint wire format
        # / legacy-backend pull path, sized by committee members (K) rather
        # than trainer processes; the Manager broadcasts released blocks to
        # ONE trainer channel
        n_train_lanes = 1 if fused_training else run_cfg.ml_process
        n_store = acq.committee_size(committee.cparams) \
            if fused_training else run_cfg.ml_process
        self.store = WeightStore(n_store)
        self.oracle_buffer = OracleInputBuffer()
        self.train_buffer = TrainingDataBuffer(run_cfg.retrain_size)
        self.trainer_channels = [Channel(f"manager->trainer{i}")
                                 for i in range(n_train_lanes)]

        self.prediction_pool = PredictionPool(
            self.predictors, self.store, self.monitor,
            predict_all_override=predict_all_override)
        # ONE acquisition engine from config — exchange hot loop and
        # dynamic_oracle_list both consume its UQResult (a user
        # predict_all_override controls the raw predictions, so it forces
        # the legacy backend)
        self.engine = acq.make_engine(
            run_cfg, committee=committee, rules=rules,
            predict_all=self.prediction_pool.predict_all,
            force_legacy=predict_all_override is not None,
            mesh=mesh, sharding_rules=sharding_rules)
        self.prediction_pool.engine = self.engine

        # --- fused committee trainer (training/committee_trainer.py) -------
        # trains the SAME stacked layout the engine scores: the trainer
        # reuses the engine's resolved mesh so a production mesh trains and
        # scores the committee on one placement
        self.committee_trainer = None
        if fused_training:
            import dataclasses as _dc

            from repro.optim.memory_policy import MemoryPolicy
            from repro.training.committee_trainer import CommitteeTrainer

            policy = _dc.replace(
                MemoryPolicy.named(
                    getattr(run_cfg, "train_memory_policy", "fp32")),
                replay_dtype=getattr(run_cfg, "train_replay_dtype",
                                     "float32"))
            self.committee_trainer = CommitteeTrainer(
                loss_fn, committee.cparams,
                steps=run_cfg.train_steps,
                batch=run_cfg.train_batch,
                lr=run_cfg.train_lr,
                bootstrap=run_cfg.train_bootstrap,
                replay_capacity=run_cfg.train_replay_capacity,
                mesh=getattr(self.engine, "mesh", None),
                sharding_rules=sharding_rules,
                seed=run_cfg.seed,
                monitor=self.monitor,
                memory_policy=policy)
        # --- device-resident exploration fleet (exploration/fleet.py) ------
        # one stacked walker state on the engine's device, advanced +
        # scored + selected in a single fused dispatch per exchange
        # iteration; trusted initial states come from fleet_init= or the
        # first proposal of each make_generator(rank)
        self.fleet = None
        if use_fleet:
            from repro.exploration.fleet import FleetConfig, WalkerFleet

            if not hasattr(self.engine, "score_after"):
                raise ValueError(
                    "fleet_walkers > 0 needs a fused acquisition engine — "
                    "pass committee=CommitteeSpec(apply_fn, cparams) (the "
                    "legacy per-member backend cannot fuse the walker "
                    "advance with scoring)")
            if fleet_init is not None:
                x0 = np.asarray(fleet_init, np.float32)
            else:
                x0 = np.stack([
                    np.asarray(make_generator(i, rd).generate_new_data(
                        None)[1], np.float32).reshape(-1)
                    for i in range(run_cfg.fleet_walkers)])
            self.fleet = WalkerFleet(
                self.engine, x0,
                FleetConfig(
                    dt=run_cfg.fleet_dt,
                    clip=run_cfg.fleet_clip,
                    noise=run_cfg.fleet_noise,
                    friction=run_cfg.fleet_friction,
                    sampler=run_cfg.fleet_sampler,
                    patience=(run_cfg.fleet_patience
                              or run_cfg.patience),
                    max_steps=run_cfg.fleet_max_steps,
                    seed=run_cfg.seed,
                ),
                monitor=self.monitor, chaos=self.chaos)
        self.exchange = Exchange(
            self.generators, self.prediction_pool, self.oracle_buffer,
            ExchangeConfig(
                std_threshold=run_cfg.std_threshold,
                patience=run_cfg.patience,
                weight_pull_every=run_cfg.weight_sync_every,
                progress_save_interval=run_cfg.progress_save_interval,
                min_interval=run_cfg.exchange_min_interval,
            ),
            self.monitor,
            fleet=self.fleet,
        )

        def fresh_score(items):
            # own timer: buffer re-scoring (incl. first-time compiles of
            # buffer-sized shape buckets) must not pollute the exchange
            # hot-path metric.  advance=False: re-scoring the waiting
            # buffer is a read-only query — it must not advance the
            # cross-round budget controller / re-weighting state, or every
            # retrain completion would charge a phantom exchange round
            # against the oracle budget
            with self.monitor.timer("manager.fresh_score"):
                return self.engine.score([np.asarray(x) for x in items],
                                         advance=False)

        self.manager = Manager(
            self.oracle_buffer, self.train_buffer, self.trainer_channels,
            ManagerConfig(
                retrain_size=run_cfg.retrain_size,
                dynamic_oracle_list=run_cfg.dynamic_oracle_list,
                oracle_timeout=run_cfg.oracle_timeout,
                max_oracle_retries=run_cfg.max_oracle_retries,
                std_threshold=run_cfg.std_threshold,
            ),
            self.monitor,
            adjust_fn=adjust_input_for_oracle,
            fresh_score=fresh_score,
        )

        # --- serving (ROADMAP: batch-level UQ for served ensembles) --------
        # the SAME engine serves online requests: served batches get a
        # UQResult and high-uncertainty requests feed the oracle buffer
        # through the same budget controller as the exchange loop
        self.server = None
        self.serve_queue = None
        if getattr(run_cfg, "serve_uq", False):
            from repro.serving.engine import CommitteeServer

            self.server = CommitteeServer(
                self.engine, self.oracle_buffer, monitor=self.monitor)
            # queue-batched serving tier: many small requests -> one fused
            # dispatch (serving/queue.py), multi-tenant fairness + rate
            # limits + adaptive deadline + LSH answer cache (ISSUE 9)
            if getattr(run_cfg, "serve_max_batch", 0) > 0:
                from repro.serving.queue import QueueConfig, ServingQueue

                cache = None
                if int(getattr(run_cfg, "serve_cache_buckets", 0)) > 0:
                    from repro.serving.cache import LSHAnswerCache

                    cache = LSHAnswerCache(
                        int(run_cfg.serve_cache_buckets),
                        std_max=float(
                            getattr(run_cfg, "serve_cache_std_max", 0.0)
                            or run_cfg.std_threshold),
                        tol=float(getattr(run_cfg, "serve_cache_tol", 0.0)),
                        seed=int(run_cfg.seed))
                self.serve_queue = ServingQueue(
                    self.server,
                    QueueConfig(
                        max_batch=int(run_cfg.serve_max_batch),
                        max_wait_ms=float(getattr(
                            run_cfg, "serve_max_wait_ms", 2.0)),
                        shed_pending=int(getattr(
                            run_cfg, "serve_shed_pending", 0)),
                        breaker_failures=int(getattr(
                            run_cfg, "serve_breaker_failures", 0)),
                        breaker_reset_s=float(getattr(
                            run_cfg, "serve_breaker_reset_s", 5.0)),
                        rate_limit=float(getattr(
                            run_cfg, "serve_rate_limit", 0.0)),
                        rate_burst=float(getattr(
                            run_cfg, "serve_rate_burst", 0.0)),
                        latency_target_ms=float(getattr(
                            run_cfg, "serve_latency_target_ms", 0.0)),
                        wait_min_ms=float(getattr(
                            run_cfg, "serve_wait_min_ms", 0.05)),
                        wait_max_ms=float(getattr(
                            run_cfg, "serve_wait_max_ms", 50.0)),
                        latency_window=int(getattr(
                            run_cfg, "serve_latency_window", 64))),
                    monitor=self.monitor,
                    cache=cache)

        # --- runtime machinery ----------------------------------------------
        self.stop_event = threading.Event()
        self.stop_token: Optional[StopToken] = None
        self._threads: List[threading.Thread] = []
        # supervised execution (core/supervisor.py): kernel loops restart
        # with backoff on crash; escalation to StopToken only after a loop
        # burns through its FailurePolicy crash budget.  supervise=False
        # maps to max_crashes=1 — the seed's fail-stop through the same path
        self.supervisor = Supervisor(
            self.monitor,
            lambda name, reason: self._signal_stop(StopToken(name, reason)),
            self.stop_event,
            policies=policies_from_config(run_cfg),
            seed=run_cfg.seed)
        # the serving tier reports through the supervisor too: one
        # snapshot() is the whole degradation surface (docs/operations.md)
        if self.serve_queue is not None:
            self.supervisor.register_health(
                "serve_queue", self.serve_queue.health)
        # trainer crash recovery: the parked trainer-channel irecv and the
        # trained-round dirty flag live OUTSIDE the loop body, so a
        # supervised restart resumes the round (replay ring + TrainState are
        # device-resident and survive) instead of replaying or losing blocks
        self._trainer_pending: Dict[int, Any] = {}
        self._trainer_dirty: Dict[int, bool] = {}
        self._last_ckpt_iter = 0
        # retrain-completion counter: incremented by EVERY trainer thread on
        # the legacy path — the read-modify-write must be lock-guarded or
        # concurrent completions are lost and dynamic_oracle_list re-scoring
        # silently skips rounds
        self._retrain_completions = 0
        self._retrain_lock = threading.Lock()
        # manager wake: set whenever new work lands (oracle-buffer put,
        # oracle result, retrain completion) so the manager loop blocks on
        # an event-or-timeout wait instead of a fixed 2 ms sleep
        self._manager_wake = threading.Event()
        self.oracle_buffer.on_put = self._manager_wake.set
        self._sync_policies = [WeightSyncPolicy(run_cfg.weight_sync_every)
                               for _ in range(n_train_lanes)]
        self.checkpointer = ALCheckpointer(rd, run_cfg.checkpoint_every)
        self.oracle_pool = ElasticPool("oracle", self._oracle_worker)
        if resume:
            self._restore()

    # ------------------------------------------------------------------ stop
    def _signal_stop(self, token: StopToken):
        if not self.stop_event.is_set():
            self.stop_token = token
            self.stop_event.set()

    # ------------------------------------------------------------ oracle pool
    def _oracle_worker(self, rank: str, stop: threading.Event):
        """ElasticPool entry point: the worker loop runs SUPERVISED — a
        crash requeues the rank's in-flight ledger work and restarts the
        loop in this same thread (fresh oracle instance + endpoint), only
        escalating to a StopToken past the FailurePolicy crash budget."""
        self.supervisor.run(
            rank, "oracle", self._oracle_worker_inner, rank, stop,
            on_crash=lambda e: self.manager.requeue_crashed_worker(rank),
            should_stop=lambda: (stop.is_set()
                                 or self.oracle_pool.stop_all.is_set()))

    def _oracle_worker_inner(self, rank: str, stop: threading.Event):
        oracle = self._make_oracle(len(self._oracle_instances),
                                   self.cfg.result_dir)
        self._oracle_instances[rank] = oracle
        ep = self.manager.register_oracle(rank)
        try:
            while not (stop.is_set() or self.stop_event.is_set()
                       or self.oracle_pool.stop_all.is_set()):
                self.manager.heartbeat.beat(rank)
                if self.chaos is not None:
                    self.chaos.check("oracle.loop", rank=rank)
                try:
                    tid, payload = ep.jobs.recv(timeout=0.1)
                except TimeoutError:
                    continue
                ep.results.isend(
                    self._run_oracle_task(oracle, rank, tid, payload, stop))
                self._manager_wake.set()
        finally:
            oracle.stop_run()

    def _run_oracle_task(self, oracle, rank: str, tid: int, payload,
                         stop: threading.Event):
        """One labeling task with in-place retries (FailurePolicy.
        task_retries, exponential backoff + jitter).  Exhausted retries
        return an ``OracleTaskFailure`` sentinel — the task fails, the
        worker lives.  An injected ``ChaosCrash`` is NOT a task failure:
        it propagates to kill the loop so the supervisor's restart path is
        what gets exercised."""
        pol = self.supervisor.policy("oracle")
        attempt = 0
        while True:
            try:
                with self.monitor.timer("oracle.run_calc"):
                    if self.chaos is not None:
                        self.chaos.check("oracle.task", rank=rank)
                    inp, label = oracle.run_calc(np.asarray(payload))
                if self.chaos is not None:
                    label = self.chaos.corrupt_label(label, rank=rank)
                return (tid, inp, label)
            except ChaosCrash:
                raise
            except Exception as e:  # noqa: BLE001 — per-task boundary
                self.monitor.incr("oracle.task_failures")
                if (attempt >= pol.task_retries or stop.is_set()
                        or self.stop_event.is_set()):
                    log.warning("oracle %s task %d failed after %d "
                                "attempt(s): %r", rank, tid, attempt + 1, e)
                    return (tid, np.asarray(payload),
                            OracleTaskFailure(repr(e)))
                self.monitor.incr("oracle.task_retries")
                self.stop_event.wait(
                    self.supervisor.backoff_delay(pol, attempt))
                attempt += 1

    def add_oracles(self, n: int) -> List[str]:
        """Elastic scale-up of the oracle pool."""
        return self.oracle_pool.add(n)

    def remove_oracle(self, rank: str):
        """Elastic scale-down; in-flight work is requeued."""
        self.oracle_pool.remove(rank)
        self.manager.unregister_oracle(rank)

    # ------------------------------------------------------------- trainers
    def _recv_block(self, pending, timeout: float = 0.1):
        """Block on a posted trainer-channel receive — the Request wraps a
        condition-variable wait (``Channel.recv(timeout=)`` semantics on
        the already-posted irecv that doubled as the retrain interrupt), so
        an idle trainer thread sleeps until data actually arrives instead
        of poll-sleeping every 5 ms.  Returns the payload or None."""
        try:
            return pending.wait(timeout)
        except TimeoutError:
            return None

    def _note_retrain_completion(self):
        with self._retrain_lock:
            self._retrain_completions += 1
        self.monitor.incr("train.retrains")
        self._manager_wake.set()

    def _trainer_irecv(self, idx: int):
        """Post (or reuse) the parked trainer-channel receive for lane
        ``idx``.  The handle is stored on the runtime, not the loop frame:
        a supervised trainer restart must reuse the surviving request —
        re-posting would leak a parked irecv that silently swallows the
        next released block."""
        pending = self._trainer_pending.get(idx)
        if pending is None:
            pending = self.trainer_channels[idx].irecv()
            self._trainer_pending[idx] = pending
        return pending

    def _trainer_ingest(self, idx: int, add: Callable[[Any], None]) -> bool:
        """Wait for one released block on lane ``idx`` and absorb it (plus
        anything queued behind it).  Returns True when new data landed and
        a train round is owed — the dirty flag persists across a trainer
        crash so the restarted loop trains from the (device-resident)
        ingested data instead of waiting for the NEXT release."""
        block = self._recv_block(self._trainer_irecv(idx))
        if block is None:
            return False
        self._trainer_pending[idx] = None       # consumed — never replay it
        add(block)
        chan = self.trainer_channels[idx]
        while chan.poll():
            add(chan.recv())
        self._trainer_irecv(idx)                # re-post the interrupt handle
        self._trainer_dirty[idx] = True
        return True

    def _trainer_drain(self, idx: int, add: Callable[[Any], None]):
        """Shutdown path: a block delivered into the parked irecv between
        the last wait and shutdown bypasses the channel queue (transport
        completes parked requests directly) — absorb it and anything still
        queued, or post-run consolidation silently loses up to retrain_size
        labels."""
        pending = self._trainer_pending.get(idx)
        if pending is not None and pending.test():
            add(pending.value)
            self._trainer_pending[idx] = None
        chan = self.trainer_channels[idx]
        while chan.poll():
            add(chan.recv())

    def _trainer_loop(self, idx: int, stop: threading.Event):
        """Legacy path: one thread per user ``make_model(..., 'train')``."""
        trainer = self.trainers[idx]
        while not (stop.is_set() or self.stop_event.is_set()):
            if not self._trainer_dirty.get(idx):
                if not self._trainer_ingest(idx, trainer.add_trainingset):
                    continue
            if self.chaos is not None:
                self.chaos.check("trainer.loop")
            with self.monitor.timer("train.retrain"):
                stop_run = trainer.retrain(self._trainer_pending[idx])
            # publish BEFORE noting completion: the completion wakes the
            # manager, whose dynamic_oracle_list re-score must see the
            # freshly retrained weights, not the previous round's
            if self._sync_policies[idx].should_publish():
                self.store.publish_packed(idx, trainer.get_weight())
            self._trainer_dirty[idx] = False
            self._note_retrain_completion()
            trainer.save_progress()
            if stop_run:
                self._signal_stop(StopToken(f"trainer{idx}",
                                            "trainer stop criterion"))
        self._trainer_drain(idx, trainer.add_trainingset)

    def _committee_trainer_loop(self, stop: threading.Event):
        """Fused path: ONE loop advances all K members per dispatch.  The
        pending irecv doubles as the interrupt handle — training yields
        the moment the Manager releases the next labeled block.  A crash
        anywhere in the round leaves the dirty flag set, so the supervised
        restart resumes training immediately from the device-resident
        replay ring + last stacked TrainState."""
        trainer = self.committee_trainer
        while not (stop.is_set() or self.stop_event.is_set()):
            if not self._trainer_dirty.get(0):
                if not self._trainer_ingest(0, trainer.add_blocks):
                    continue
            if self.chaos is not None:
                self.chaos.check("trainer.loop")
                ev = self.chaos.take("trainer.nan_member")
                if ev is not None:
                    trainer.poison_member(int(ev.arg))
            with self.monitor.timer("train.retrain"):
                trainer.train(interrupt=self._trainer_pending[0])
            # publish BEFORE noting completion (see _trainer_loop): the
            # woken manager's re-score must run on the refreshed weights
            if self._sync_policies[0].should_publish():
                self._publish_committee()
            self._trainer_dirty[0] = False
            self._note_retrain_completion()
        self._trainer_drain(0, trainer.add_blocks)

    def _publish_committee(self):
        """Trainer -> engine weight handoff.  Fused engines take the
        stacked pytree device-to-device (zero packed host bytes); the
        legacy per-member backend still pulls packed 1-D arrays through
        the WeightStore (its models own their params)."""
        trainer = self.committee_trainer
        if hasattr(self.engine, "refresh_from_device"):
            self.engine.refresh_from_device(trainer.snapshot_cparams())
            self.monitor.incr("prediction.weight_refreshes")
        else:
            from repro.core import committee as cmte

            cparams = trainer.cparams
            for i in range(trainer.size):
                self.store.publish_packed(
                    i % self.store.n_members,
                    cmte.get_weight(cmte.member(cparams, i)))

    # ------------------------------------------------------------- threads
    def _exchange_loop(self, stop: threading.Event):
        while not (stop.is_set() or self.stop_event.is_set()):
            if self.chaos is not None:
                self.chaos.check("exchange.loop")
            token = self.exchange.step()
            if token is not None:
                self._signal_stop(token)

    def _autosave_due(self) -> bool:
        every = int(getattr(self.cfg, "checkpoint_every_iters", 0))
        if every <= 0:
            return False
        return (self.exchange.iteration - self._last_ckpt_iter) >= every

    def _manager_loop(self, stop: threading.Event):
        while not (stop.is_set() or self.stop_event.is_set()):
            self.manager.step(self._retrain_completions)
            # periodic autosave: wall-clock (checkpoint_every) OR exchange
            # progress (checkpoint_every_iters), whichever is configured
            if self.checkpointer.due() or self._autosave_due():
                self.checkpoint()
            # event-or-timeout: woken immediately by new work (oracle-buffer
            # put / oracle result / retrain completion), with a bounded
            # fallback so ledger timeouts and heartbeats are still serviced
            if self._manager_wake.wait(timeout=0.05):
                self._manager_wake.clear()

    # ------------------------------------------------------------------ run
    def start(self):
        if self.chaos is not None:
            transport.install_chaos(self.chaos)
        self.oracle_pool.add(self.cfg.orcl_process)
        if self.committee_trainer is not None:
            self._threads.append(self.supervisor.spawn(
                "committee_trainer", "trainer",
                self._committee_trainer_loop, self.stop_event))
        for i in range(len(self.trainers)):
            self._threads.append(self.supervisor.spawn(
                f"trainer{i}", "trainer",
                self._trainer_loop, i, self.stop_event))
        self._threads.append(self.supervisor.spawn(
            "exchange", "exchange", self._exchange_loop, self.stop_event))
        self._threads.append(self.supervisor.spawn(
            "manager", "manager", self._manager_loop, self.stop_event))

    def run(self, timeout: Optional[float] = None) -> Optional[StopToken]:
        """Start and block until a kernel signals stop (or timeout)."""
        self.start()
        self.stop_event.wait(timeout)
        if not self.stop_event.is_set():
            self._signal_stop(StopToken("runtime", "timeout"))
        self.shutdown()
        return self.stop_token

    def shutdown(self):
        self.stop_event.set()
        if self.serve_queue is not None:
            # flush pending served requests — bounded like every other
            # join here, so a wedged dispatch can't hang shutdown
            try:
                self.serve_queue.close(timeout=10.0)
            except Exception as e:  # noqa: BLE001 — shutdown must continue
                log.warning("serve queue close failed: %r", e)
        self.oracle_pool.shutdown()
        unjoined = []
        for th in self._threads:
            th.join(timeout=10.0)
            if th.is_alive():
                unjoined.append(th.name)
        if unjoined:
            # never silently leak threads: surface which loops failed to
            # exit (a wedged oracle call, a hung chaos delay) — the process
            # still shuts down because every loop thread is a daemon
            self.monitor.incr("runtime.unjoined_threads", len(unjoined))
            log.warning("threads not joined within timeout: %s", unjoined)
        if self.chaos is not None:
            transport.uninstall_chaos()
        # paper: every process's stop_run is called before quitting — one
        # kernel's failing stop_run must not rob the others of theirs
        for obj in (*self.generators, *self.predictors, *self.trainers):
            try:
                obj.stop_run()
            except Exception as e:  # noqa: BLE001
                log.warning("stop_run failed for %r: %r", obj, e)

    # ----------------------------------------------------------- checkpoint
    def checkpoint(self) -> str:
        # in-flight oracle tasks (dispatched, not yet labeled) are requeued
        # into the snapshot: a restore re-dispatches them instead of
        # silently losing selected inputs whose labels never arrived
        state = {
            "weights": {i: w for i, w in
                        [(i, self.store.pull_packed(i)) for i in
                         range(self.store.n_members)] if w is not None},
            "oracle_buffer": (self.oracle_buffer.snapshot()
                              + self.manager.ledger.inflight_payloads()),
            "train_buffer": self.train_buffer.snapshot(),
            "patience": self.exchange.patience.state_dict(),
            "iteration": self.exchange.iteration,
            "labeled_total": self.train_buffer.total_labeled,
            # cross-round acquisition state (budget controller threshold/
            # integral, rolling re-weight bucket scores) — without it a
            # restored run would re-converge from scratch and overshoot
            # the oracle budget for a whole horizon
            "engine_state": self.engine.state_dict(),
        }
        if self.committee_trainer is not None:
            # FULL TrainState (params + Adam moments + per-member step) +
            # RNG cursor + replay ring: a resumed run continues
            # mid-schedule instead of resetting its optimizer
            state["train_state"] = self.committee_trainer.state_dict()
        if self.fleet is not None:
            # full walker carry incl. per-walker RNG keys and step counter:
            # a restored fleet replays the exact trajectory (bit-identical
            # resume, tested)
            state["fleet"] = self.fleet.state_dict()
        self._last_ckpt_iter = self.exchange.iteration
        return self.checkpointer.save(self.exchange.iteration, state)

    def _restore(self):
        state = self.checkpointer.latest()
        if state is None:
            return
        for i, packed in state.get("weights", {}).items():
            arr, _ = packed
            self.store.publish_packed(int(i), arr)
        self.oracle_buffer.restore(state.get("oracle_buffer", []))
        self.train_buffer.restore(state.get("train_buffer", []))
        if "patience" in state:
            self.exchange.patience.load_state_dict(state["patience"])
        if state.get("engine_state"):
            self.engine.load_state_dict(state["engine_state"])
        if state.get("fleet") is not None and self.fleet is not None:
            self.fleet.load_state_dict(state["fleet"])
        if (state.get("train_state") is not None
                and self.committee_trainer is not None):
            self.committee_trainer.load_state_dict(state["train_state"])
            # prediction must resume on the restored weights too
            self._publish_committee()
        self.exchange.iteration = int(state.get("iteration", 0))
        self.monitor.incr("runtime.restores")

    # ------------------------------------------------------------- reports
    def report(self) -> Dict[str, Any]:
        r = self.monitor.report()
        r["oracle_pool_size"] = self.oracle_pool.size()
        r["oracle_buffer"] = len(self.oracle_buffer)
        r["train_buffer"] = len(self.train_buffer)
        r["labeled_total"] = self.train_buffer.total_labeled
        r["weight_publishes"] = self.store.publishes
        # fused-trainer path: weights reach the engine device-to-device,
        # so store publishes stay 0 — the refresh counters tell the story
        r["device_weight_refreshes"] = getattr(
            self.engine, "device_refreshes", 0)
        if self.committee_trainer is not None:
            r["train_fused_steps"] = self.committee_trainer.steps_done
            r["train_replay_rows"] = len(self.committee_trainer.replay)
        if self.fleet is not None:
            # fleet health: one device->host snapshot, off the hot path
            r["fleet"] = self.fleet.stats()
        # realized oracle rate: queued / scored over the whole run, the
        # quantity the budget controller steers toward oracle_budget.
        # Serving traffic counts too — with serve_uq the server shares the
        # controller (advance=True), so the metered demand is exchange
        # selections PLUS uncertain served requests routed to the buffer;
        # an exchange-only rate would read as under-spending whenever
        # serving consumes part of the budget
        c = r["counters"]
        ex_scored = c.get("exchange.proposals", 0)
        ex_queued = c.get("exchange.queued_to_oracle", 0)
        sv_scored = c.get("serve.requests", 0)
        sv_queued = c.get("serve.routed_to_oracle", 0)
        scored = ex_scored + sv_scored
        queued = ex_queued + sv_queued
        r["oracle_rate"] = queued / scored if scored else None
        # per-stream breakout: the controller is joint, but each stream's
        # realized rate is observable against its own target
        # (oracle_budget_exchange / oracle_budget_serve)
        r["oracle_rate_exchange"] = (ex_queued / ex_scored if ex_scored
                                     else None)
        r["oracle_rate_serve"] = sv_queued / sv_scored if sv_scored else None
        if self.serve_queue is not None:
            # ONE health() snapshot (taken under the queue's lock) feeds
            # every serve_queue_* key — dispatch counts can never be torn
            # against the breaker state / per-client counters they explain
            qh = self.serve_queue.health()
            r["serve_queue_dispatches"] = qh["dispatches"]
            r["serve_queue_batched_requests"] = qh["batched_requests"]
            r["serve_queue_health"] = qh
        # fault-tolerance observability (ISSUE 6): last crash + restart
        # tally from the supervisor, committee quarantine floor from the
        # engine (min finite members seen in any scored round), chaos
        # events fired so far when a FaultPlan is installed
        sup = self.supervisor.snapshot()
        r["last_fault"] = sup["last_fault"]
        r["supervisor"] = sup       # incl. registered component health
        r["thread_restarts"] = self.supervisor.total_restarts()
        r["uq_finite_members_min"] = getattr(
            self.engine, "last_finite_min", None)
        r["uq_quarantine_rounds"] = getattr(
            self.engine, "quarantine_rounds", 0)
        if self.chaos is not None:
            r["chaos_fired"] = self.chaos.summary()
        r["stop"] = repr(self.stop_token)
        return r
