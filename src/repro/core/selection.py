"""Centralized uncertainty-driven selection (paper §2.5 + SI Utilities).

``prediction_check`` is the paper's controller-side function deciding (a)
which generator proposals go to the oracle and (b) what each generator
receives back; on the unified path the acquisition engine
(core/acquisition.py) makes that decision and ``selection_from_uq`` routes
its ``UQResult`` into a ``SelectionResult``.  ``adjust_input_for_oracle``
(and its ``_uq`` variant consuming engine statistics) re-prioritizes the
oracle buffer with the freshest committee (``dynamic_oracle_list``).
``PatienceTracker`` implements
the generator-side "allow trajectories to propagate into regions of high
uncertainty for a given number of steps" policy (§2.2) — decision logic is
the generator's, UQ stays central, exactly as the paper splits it.

This module is the HOST-side realization layer: the selection decision
itself is made inside the acquisition engine (device-side rule pipeline —
``acquisition.ThresholdRule`` & friends, plus the cross-round stateful
rules in ``core/budget.py``); the functions here turn the resulting
``UQResult`` into oracle-queue entries and per-generator scatter lists,
and provide the float64 reference ports the parity tests compare against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class SelectionResult:
    """Outcome of one prediction_check round."""

    inputs_to_oracle: List[np.ndarray]
    data_to_generators: List[Any]            # one per generator, rank-sorted
    uncertain_mask: np.ndarray               # (n_gen,) bool
    std: np.ndarray                          # (n_gen,) scalar disagreement


def prediction_check(
    list_data_to_pred: Sequence[np.ndarray],    # gathered generator inputs
    committee_preds: np.ndarray,                # (K, n_gen, out_dim)
    threshold: float,
    flag_value: Optional[float] = None,
) -> SelectionResult:
    """Faithful port of the paper's utils.prediction_check.

    Committee std over members; samples whose std exceeds `threshold` in any
    output component are queued for the oracle.  Generators receive the
    committee mean; for uncertain samples the paper's example sends a flag
    (0) instead — we return the mean plus a mask so generators can apply
    their own patience policy (flag_value reproduces the paper's behavior
    when set).
    """
    preds = np.asarray(committee_preds, dtype=np.float64)
    k = preds.shape[0]
    std = preds.std(axis=0, ddof=1) if k > 1 else np.zeros_like(preds[0])
    uncertain = (std > threshold).any(axis=tuple(range(1, std.ndim)))
    scalar_std = std.reshape(std.shape[0], -1).max(axis=-1)

    inputs_to_oracle = [np.asarray(list_data_to_pred[i])
                        for i in np.where(uncertain)[0]]
    mean = preds.mean(axis=0)
    if flag_value is not None:
        mean = mean.copy()
        mean[uncertain] = flag_value
    data_to_generators = list(mean)
    return SelectionResult(inputs_to_oracle, data_to_generators, uncertain,
                           scalar_std)


def prediction_check_fast(
    list_data_to_pred: Sequence[np.ndarray],
    mean: np.ndarray,                           # (n_gen, out_dim)
    scalar_std: np.ndarray,                     # (n_gen,)
    uncertain_mask: np.ndarray,                 # (n_gen,) bool
    flag_value: Optional[float] = None,
    scatter_out: Optional[List[Any]] = None,
) -> SelectionResult:
    """Fast-path ``prediction_check`` consuming precomputed device UQ.

    The fused acquisition engine (acquisition.FusedEngine) already computed
    mean / ddof-1 scalar std / selection mask on device in the same
    dispatch as the committee forward; this just routes them — no float64
    recompute, no (K, n_gen, out_dim) host tensor.  Semantics match
    ``prediction_check`` exactly (same SelectionResult for the same
    committee outputs).

    ``scatter_out``: an optional preallocated per-generator list to fill
    in place (and return as ``data_to_generators``) instead of allocating
    a fresh scatter list every round — the Exchange hot loop reuses its
    buffer through this.
    """
    mean = np.asarray(mean)
    mask = np.asarray(uncertain_mask, dtype=bool)
    scalar_std = np.asarray(scalar_std)
    inputs_to_oracle = [np.asarray(list_data_to_pred[i])
                        for i in np.where(mask)[0]]
    if flag_value is not None:
        mean = mean.copy()
        mean[mask] = flag_value
    if scatter_out is None:
        scatter = list(mean)
    else:
        for i in range(len(mean)):
            scatter_out[i] = mean[i]
        scatter = scatter_out
    return SelectionResult(inputs_to_oracle, scatter, mask, scalar_std)


def selection_from_uq(
    list_data_to_pred: Sequence[np.ndarray],
    uq,                                         # acquisition.UQResult
    flag_value: Optional[float] = None,
    scatter_out: Optional[List[Any]] = None,
) -> SelectionResult:
    """Route an acquisition-engine ``UQResult`` into a SelectionResult.

    The engine already computed mean / std statistics AND the final rule
    mask (device-side on fused backends); this only materializes the
    per-generator scatter lists (into ``scatter_out`` when the caller
    reuses a buffer).  Semantics match ``prediction_check`` exactly for
    the default threshold rule.
    """
    return prediction_check_fast(list_data_to_pred, uq.mean, uq.scalar_std,
                                 uq.mask, flag_value,
                                 scatter_out=scatter_out)


def adjust_input_for_oracle(
    to_orcl_buffer: List[np.ndarray],
    committee_preds: np.ndarray,                # (K, n_buf, out_dim)
    threshold: float,
) -> List[np.ndarray]:
    """Faithful port of utils.adjust_input_for_oracle: sort the waiting
    oracle inputs by fresh-committee std (descending) and drop entries whose
    uncertainty no longer exceeds the threshold."""
    if not to_orcl_buffer:
        return []
    preds = np.asarray(committee_preds, dtype=np.float64)
    k = preds.shape[0]
    std = preds.std(axis=0, ddof=1) if k > 1 else np.zeros_like(preds[0])
    score = std.reshape(std.shape[0], -1).mean(axis=-1)
    order = np.argsort(score)[::-1]
    keep = [int(i) for i in order
            if (std[i] > threshold).any()]
    return [to_orcl_buffer[i] for i in keep]


def adjust_input_for_oracle_uq(
    to_orcl_buffer: List[np.ndarray],
    uq,                                         # acquisition.UQResult
    threshold: float,
    honor_selection: bool = False,
) -> List[np.ndarray]:
    """``adjust_input_for_oracle`` consuming an engine ``UQResult``: sort
    waiting oracle inputs by mean-over-components committee std
    (descending, ``uq.component_std``) and drop entries whose max-component
    std no longer exceeds ``threshold`` (``(std > t).any(components) ==
    scalar_std > t``).  Same kept-order semantics as the stacked-preds
    port, with no ``(K, n_buf, out_dim)`` host tensor and no float64
    recompute — the statistics come straight off the device pass.

    ``honor_selection``: additionally keep every entry the engine's OWN
    rule pipeline re-selected (``uq.mask``) even if below ``threshold`` —
    under the default threshold rule this is a no-op (mask == scalar_std >
    threshold for the same configured value), but with a custom pipeline
    (e.g. top-fraction) it guarantees the re-prioritization never drops a
    sample the active selection policy just chose."""
    if not to_orcl_buffer:
        return []
    order = np.argsort(np.asarray(uq.component_std))[::-1]
    keep_mask = np.asarray(uq.scalar_std) > threshold
    if honor_selection:
        keep_mask = keep_mask | np.asarray(uq.mask, dtype=bool)
    return [to_orcl_buffer[int(i)] for i in order if keep_mask[int(i)]]


class PatienceTracker:
    """Generator-side reaction policy to central uncertainty flags (§2.2).

    A trajectory may continue through up to ``patience`` consecutive
    uncertain steps; beyond that the generator should restart (reset to a
    trusted state).  One counter per generator rank.

    This is the HOST realization, used by the per-generator Exchange path.
    The device-resident exploration fleet applies the identical update as
    ``exploration.fleet.PatienceRestart`` — stacked ``jnp.where`` counters
    folded into the fused dispatch — and the parity test holds the two to
    the same counts/restarts/flags step for step."""

    def __init__(self, n_generators: int, patience: int):
        self.patience = patience
        self.counts = np.zeros(n_generators, dtype=int)
        self.restarts = np.zeros(n_generators, dtype=int)

    def step(self, uncertain_mask: np.ndarray) -> np.ndarray:
        """Returns a bool mask of generators that must restart now."""
        self.counts = np.where(uncertain_mask, self.counts + 1, 0)
        restart = self.counts > self.patience
        self.restarts += restart
        self.counts[restart] = 0
        return restart

    def state_dict(self):
        return {"counts": self.counts.copy(), "restarts": self.restarts.copy()}

    def load_state_dict(self, s):
        self.counts = np.asarray(s["counts"]).copy()
        self.restarts = np.asarray(s["restarts"]).copy()


# ---------------------------------------------------------------------------
# Alternative acquisition scores (beyond the paper's std-threshold, for the
# LM path and ablations)
# ---------------------------------------------------------------------------


def top_fraction(scores: np.ndarray, fraction: float) -> np.ndarray:
    """Indices of the top `fraction` most-uncertain samples."""
    n = max(int(round(len(scores) * fraction)), 0)
    if n == 0:
        return np.empty(0, dtype=int)
    return np.argsort(scores)[::-1][:n]


def diversity_filter(inputs: Sequence[np.ndarray], selected: np.ndarray,
                     min_dist: float) -> np.ndarray:
    """Greedy de-duplication: drop selected samples closer than min_dist to
    an already-kept one (paper §3.1: 'avoiding similar and thus redundant
    TDDFT calculations').

    The full pairwise-distance matrix is computed in one vectorized NumPy
    pass (Gram-matrix identity), with pairs that land within cancellation
    error of the ``min_dist`` boundary recomputed via direct differences;
    the greedy sweep then reduces each candidate to a single masked row
    lookup.  Kept-index semantics match the original O(n^2) pure-Python
    loop: candidates are visited in ``selected`` order and kept iff no
    already-kept sample lies strictly closer than ``min_dist``.
    """
    sel_idx = np.asarray(selected, dtype=int).reshape(-1)
    if sel_idx.size == 0:
        return np.empty(0, dtype=int)
    X = np.stack([np.asarray(inputs[int(i)], dtype=np.float64).reshape(-1)
                  for i in sel_idx])
    sq = np.einsum("id,id->i", X, X)
    d2 = np.maximum(sq[:, None] + sq[None, :] - 2.0 * (X @ X.T), 0.0)
    md2 = float(min_dist) ** 2
    close = d2 < md2
    # Gram identity cancels catastrophically for large-norm inputs; pairs
    # within its error band of the threshold get the exact distance
    band = np.abs(d2 - md2) <= 1e-9 * np.maximum(
        1.0, sq[:, None] + sq[None, :])
    for i, j in zip(*np.nonzero(band)):
        close[i, j] = np.linalg.norm(X[i] - X[j]) < min_dist
    kept_mask = np.zeros(sel_idx.size, dtype=bool)
    for i in range(sel_idx.size):
        kept_mask[i] = not close[i, kept_mask].any()
    return sel_idx[kept_mask]
