"""Committee (query-by-committee) machinery — paper §2.1/§3.1.

The paper runs one MPI rank per committee member; on TPU an ensemble of K
models is ONE SPMD program: parameters are stacked on a leading committee
axis and the forward is ``vmap``-ed, shardable over the mesh (DESIGN.md §2).

Also provides the paper's 1-D weight packing (S4: ``get_weight`` /
``get_weight_size`` / ``update``) — used verbatim by the weight-sync path so
the wire format matches the paper even though in-process transfer could ship
pytrees directly.  ``get_weight`` accepts an ``out=`` buffer so the publish
path can reuse a preallocated array instead of ``np.concatenate``-ing a
fresh one every round.

``FusedPredictSelect`` is the fused exchange engine (see kernels/ops
``committee_uq``): the vmapped committee forward and the uncertainty
statistics run as ONE jitted device program per shape bucket (n_gen padded
to power-of-two buckets so varying generator counts never retrace), and
only ``(mean, scalar_std, mask)`` return to host.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# 1-D weight packing (paper S4)
# ---------------------------------------------------------------------------


def get_weight_size(params: Any) -> int:
    """Size of the packed 1-D array (paper: negotiated once at startup)."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def get_weight(params: Any, out: Optional[np.ndarray] = None) -> np.ndarray:
    """Pack a pytree into one 1-D float32 array (paper's wire format).

    ``out``: optional preallocated destination (must match the packed size);
    leaves are copied in at their offsets, so a publish loop can reuse one
    buffer instead of allocating via ``np.concatenate`` every round.
    """
    leaves = jax.tree.leaves(params)
    if out is None:
        out = np.empty(sum(int(np.prod(x.shape)) for x in leaves), np.float32)
    off = 0
    for x in leaves:
        flat = np.asarray(x, dtype=np.float32).reshape(-1)
        out[off:off + flat.size] = flat
        off += flat.size
    if off != out.size:
        raise ValueError(f"pack buffer size mismatch: {out.size} buffer vs "
                         f"{off} packed")
    return out


def update(params_like: Any, weight_array: np.ndarray) -> Any:
    """Unpack a 1-D array into the structure of ``params_like``."""
    leaves, treedef = jax.tree.flatten(params_like)
    out = []
    off = 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        seg = weight_array[off:off + n].reshape(leaf.shape)
        out.append(jnp.asarray(seg, dtype=leaf.dtype))
        off += n
    if off != weight_array.size:
        raise ValueError(f"weight array size mismatch: {weight_array.size} "
                         f"packed vs {off} expected")
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Committee evaluation
# ---------------------------------------------------------------------------


def stack_members(members) -> Any:
    """[params, ...] -> stacked pytree with leading committee axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *members)


def member(cparams: Any, i: int) -> Any:
    return jax.tree.map(lambda a: a[i], cparams)


def committee_size(cparams: Any) -> int:
    return jax.tree.leaves(cparams)[0].shape[0]


def make_committee_apply(apply_fn: Callable) -> Callable:
    """apply_fn(params, x) -> y  ==>  capply(cparams, x) -> (K, ...) y."""
    return jax.vmap(apply_fn, in_axes=(0, None))


def mean_std(preds: jnp.ndarray, axis: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Committee mean and std (ddof=1, matching the paper's utils)."""
    mean = jnp.mean(preds, axis=axis)
    k = preds.shape[axis]
    std = jnp.std(preds, axis=axis, ddof=1) if k > 1 else jnp.zeros_like(mean)
    return mean, std


def disagreement(preds: jnp.ndarray) -> jnp.ndarray:
    """Scalar per-sample uncertainty: max std over output components.

    preds: (K, B, ...) -> (B,).  This is the quantity prediction_check
    thresholds (paper utils: (std > threshold).any(axis=1))."""
    _, std = mean_std(preds, axis=0)
    flat = std.reshape(std.shape[0], -1)
    return jnp.max(flat, axis=-1)


# ---------------------------------------------------------------------------
# LM committee uncertainty (the datacenter-scale path, DESIGN.md §3)
# ---------------------------------------------------------------------------


def lm_token_nll(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """(B, T, V) x (B, T) -> (B, T) token NLL in fp32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    return lse - ll


def lm_committee_uncertainty(clogits: jnp.ndarray, labels: jnp.ndarray):
    """clogits: (K, B, T, V).  Returns (mean_nll (B,), std_nll (B,)).

    Sequence-level committee disagreement = std over members of the mean
    token NLL — the LM analog of energy-prediction std."""
    nll = jax.vmap(lm_token_nll, in_axes=(0, None))(clogits, labels)  # (K,B,T)
    seq_nll = jnp.mean(nll, axis=-1)                                  # (K,B)
    return mean_std(seq_nll, axis=0)


class Committee:
    """Convenience wrapper pairing stacked params with a vmapped apply."""

    def __init__(self, apply_fn: Callable, cparams: Any, jit: bool = True):
        capply = make_committee_apply(apply_fn)
        self.apply = jax.jit(capply) if jit else capply
        self.params = cparams

    @property
    def size(self) -> int:
        return committee_size(self.params)

    def predict(self, x) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Returns (preds (K, ...), mean, std)."""
        preds = self.apply(self.params, x)
        mean, std = mean_std(preds, axis=0)
        return preds, mean, std

    def replace_member(self, i: int, params: Any):
        self.params = jax.tree.map(
            lambda c, p: c.at[i].set(p), self.params, params)


# ---------------------------------------------------------------------------
# Fused committee-UQ exchange engine
# ---------------------------------------------------------------------------


def shape_bucket(n: int, minimum: int = 8) -> int:
    """Next power-of-two >= n (floored at ``minimum``) — the jit-cache key."""
    b = max(1, minimum)
    while b < n:
        b *= 2
    return b


class FusedPredictSelect:
    """Single-dispatch committee inference + uncertainty quantification.

    One exchange iteration becomes ONE device program: the vmapped committee
    forward fused with ``ops.committee_uq`` (mean / ddof-1 scalar std /
    ``std > threshold`` mask, streamed over the K axis) under ``jax.jit``.
    Only ``(mean, scalar_std, mask)`` cross back to host — the full
    ``(K, n_gen, out_dim)`` tensor never leaves the device.

    Varying generator counts are padded to power-of-two shape buckets so a
    run with fluctuating ``n_gen`` compiles at most once per bucket
    (``trace_counts`` records tracings per bucket; tests assert <= 1).  The
    padded input batch is donated to the compiled program, so XLA reuses its
    buffer instead of allocating per iteration.

    ``apply_fn(params, x)`` must map a single member's params over a batch
    ``x: (n, in_dim) -> (n, out_dim)``.
    """

    def __init__(self, apply_fn: Callable, cparams: Any, threshold: float,
                 *, impl: str = "xla", min_bucket: int = 8,
                 donate: bool = True, block_n: int = 128):
        from repro.kernels import ops as _ops

        self._ops = _ops
        self.apply = make_committee_apply(apply_fn)
        self.cparams = cparams
        self.threshold = float(threshold)
        self.impl = impl
        self.min_bucket = min_bucket
        self.donate = donate
        self.block_n = block_n
        self.version = -1                      # last WeightStore version seen
        self._cache: Dict[int, Callable] = {}
        self._stacked: Optional[Callable] = None
        self.trace_counts: Dict[int, int] = {}
        # host<->device traffic accounting (benchmarks/committee_uq.py)
        self.bytes_to_device = 0
        self.bytes_to_host = 0

    @property
    def size(self) -> int:
        return committee_size(self.cparams)

    # ------------------------------------------------------------- compile
    def _compiled(self, nb: int) -> Callable:
        fn = self._cache.get(nb)
        if fn is None:
            def fused(cparams, x):
                # trace-time counter: fires once per (bucket) compilation
                self.trace_counts[nb] = self.trace_counts.get(nb, 0) + 1
                preds = self.apply(cparams, x)
                return self._ops.committee_uq(
                    preds, self.threshold, impl=self.impl,
                    block_n=self.block_n)
            # donation is a no-op (plus a warning) on CPU — only request it
            # where XLA can actually alias the buffer
            donate = self.donate and jax.default_backend() != "cpu"
            fn = jax.jit(fused, donate_argnums=(1,)) if donate \
                else jax.jit(fused)
            self._cache[nb] = fn
        return fn

    def _compiled_stacked(self) -> Callable:
        # one jit wrapper is enough: jit's own cache is keyed by input shape,
        # and bucketing already quantizes the shapes it sees
        if self._stacked is None:
            self._stacked = jax.jit(self.apply)
        return self._stacked

    def _pad_batch(self, list_data: Sequence[np.ndarray]):
        """Stack generator proposals into one padded (bucket, in_dim) batch."""
        rows = [np.asarray(x, dtype=np.float32).reshape(-1)
                for x in list_data]
        n = len(rows)
        nb = shape_bucket(n, self.min_bucket)
        x = np.zeros((nb, rows[0].size), np.float32)
        for i, r in enumerate(rows):
            x[i] = r
        return x, n, nb

    # -------------------------------------------------------------- predict
    def __call__(self, list_data: Sequence[np.ndarray]
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """list of per-generator inputs -> host (mean, scalar_std, mask),
        sliced to the true n_gen."""
        x, n, nb = self._pad_batch(list_data)
        self.bytes_to_device += x.nbytes
        mean, sstd, mask = self._compiled(nb)(self.cparams, jnp.asarray(x))
        mean, sstd, mask = (np.asarray(mean), np.asarray(sstd),
                            np.asarray(mask))
        self.bytes_to_host += mean.nbytes + sstd.nbytes + mask.nbytes
        return mean[:n], sstd[:n], mask[:n]

    def predict_stacked(self, list_data: Sequence[np.ndarray]) -> np.ndarray:
        """Full (K, n, out_dim) predictions in one dispatch — the slow-lane
        path for consumers that need per-member outputs (e.g. the manager's
        dynamic oracle-buffer re-prioritization)."""
        x, n, nb = self._pad_batch(list_data)
        self.bytes_to_device += x.nbytes
        preds = np.asarray(self._compiled_stacked()(self.cparams,
                                                    jnp.asarray(x)))
        self.bytes_to_host += preds.nbytes
        return preds[:, :n]

    # -------------------------------------------------------------- weights
    def refresh_from(self, store) -> int:
        """Refresh the stacked committee from a WeightStore if anything
        newer exists.  Prediction member i replicates training member
        ``i % store.n_members`` (paper: prediction models are replicas of
        training models), so the committee size K is preserved even when
        fewer trainers publish — shapes never change, so no retrace.
        Returns the number of refreshed committees (0 or 1)."""
        v = store.version()
        if v <= self.version:
            return 0
        K = self.size
        packs = [store.pull_packed(i % store.n_members) for i in range(K)]
        if any(p is None for p in packs):
            return 0              # not all trainers have published yet
        members = [update(member(self.cparams, i), packs[i][0])
                   for i in range(K)]
        self.cparams = stack_members(members)
        self.version = v
        return 1
