"""Committee (query-by-committee) machinery — paper §2.1/§3.1.

The paper runs one MPI rank per committee member; on TPU an ensemble of K
models is ONE SPMD program: parameters are stacked on a leading committee
axis and the forward is ``vmap``-ed, shardable over the mesh (DESIGN.md §2).

Also provides the paper's 1-D weight packing (S4: ``get_weight`` /
``get_weight_size`` / ``update``) — used verbatim by the weight-sync path so
the wire format matches the paper even though in-process transfer could ship
pytrees directly.  ``get_weight`` accepts an ``out=`` buffer so the publish
path can reuse a preallocated array instead of ``np.concatenate``-ing a
fresh one every round.

The fused exchange engine lives in ``core/acquisition.py``
(``FusedEngine``): the vmapped committee forward, the uncertainty
statistics (kernels/ops ``committee_uq``), and the selection-rule pipeline
run as ONE jitted device program per shape bucket (``shape_bucket`` here:
n_gen padded to power-of-two buckets so varying generator counts never
retrace), and only ``(mean, scalar_std, component_std, mask)`` return to
host.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# 1-D weight packing (paper S4)
# ---------------------------------------------------------------------------


def get_weight_size(params: Any) -> int:
    """Size of the packed 1-D array (paper: negotiated once at startup)."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def get_weight(params: Any, out: Optional[np.ndarray] = None) -> np.ndarray:
    """Pack a pytree into one 1-D float32 array (paper's wire format).

    ``out``: optional preallocated destination (must match the packed size);
    leaves are copied in at their offsets, so a publish loop can reuse one
    buffer instead of allocating via ``np.concatenate`` every round.
    """
    leaves = jax.tree.leaves(params)
    if out is None:
        out = np.empty(sum(int(np.prod(x.shape)) for x in leaves), np.float32)
    off = 0
    for x in leaves:
        flat = np.asarray(x, dtype=np.float32).reshape(-1)
        out[off:off + flat.size] = flat
        off += flat.size
    if off != out.size:
        raise ValueError(f"pack buffer size mismatch: {out.size} buffer vs "
                         f"{off} packed")
    return out


def update(params_like: Any, weight_array: np.ndarray) -> Any:
    """Unpack a 1-D array into the structure of ``params_like``."""
    leaves, treedef = jax.tree.flatten(params_like)
    out = []
    off = 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        seg = weight_array[off:off + n].reshape(leaf.shape)
        out.append(jnp.asarray(seg, dtype=leaf.dtype))
        off += n
    if off != weight_array.size:
        raise ValueError(f"weight array size mismatch: {weight_array.size} "
                         f"packed vs {off} expected")
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Committee evaluation
# ---------------------------------------------------------------------------


def stack_members(members) -> Any:
    """[params, ...] -> stacked pytree with leading committee axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *members)


def member(cparams: Any, i: int) -> Any:
    return jax.tree.map(lambda a: a[i], cparams)


def committee_size(cparams: Any) -> int:
    return jax.tree.leaves(cparams)[0].shape[0]


def make_committee_apply(apply_fn: Callable) -> Callable:
    """apply_fn(params, x) -> y  ==>  capply(cparams, x) -> (K, ...) y."""
    return jax.vmap(apply_fn, in_axes=(0, None))


def mean_std(preds: jnp.ndarray, axis: int = 0) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Committee mean and std (ddof=1, matching the paper's utils)."""
    mean = jnp.mean(preds, axis=axis)
    k = preds.shape[axis]
    std = jnp.std(preds, axis=axis, ddof=1) if k > 1 else jnp.zeros_like(mean)
    return mean, std


def disagreement(preds: jnp.ndarray) -> jnp.ndarray:
    """Scalar per-sample uncertainty: max std over output components.

    preds: (K, B, ...) -> (B,).  This is the quantity prediction_check
    thresholds (paper utils: (std > threshold).any(axis=1))."""
    _, std = mean_std(preds, axis=0)
    flat = std.reshape(std.shape[0], -1)
    return jnp.max(flat, axis=-1)


# ---------------------------------------------------------------------------
# LM committee uncertainty (the datacenter-scale path, DESIGN.md §3)
# ---------------------------------------------------------------------------


def lm_token_nll(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """(B, T, V) x (B, T) -> (B, T) token NLL in fp32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    return lse - ll


def lm_committee_uncertainty(clogits: jnp.ndarray, labels: jnp.ndarray):
    """clogits: (K, B, T, V).  Returns (mean_nll (B,), std_nll (B,)).

    Sequence-level committee disagreement = std over members of the mean
    token NLL — the LM analog of energy-prediction std."""
    nll = jax.vmap(lm_token_nll, in_axes=(0, None))(clogits, labels)  # (K,B,T)
    seq_nll = jnp.mean(nll, axis=-1)                                  # (K,B)
    return mean_std(seq_nll, axis=0)


class Committee:
    """Convenience wrapper pairing stacked params with a vmapped apply."""

    def __init__(self, apply_fn: Callable, cparams: Any, jit: bool = True):
        capply = make_committee_apply(apply_fn)
        self.apply = jax.jit(capply) if jit else capply
        self.params = cparams

    @property
    def size(self) -> int:
        return committee_size(self.params)

    def predict(self, x) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Returns (preds (K, ...), mean, std)."""
        preds = self.apply(self.params, x)
        mean, std = mean_std(preds, axis=0)
        return preds, mean, std

    def replace_member(self, i: int, params: Any):
        self.params = jax.tree.map(
            lambda c, p: c.at[i].set(p), self.params, params)


# ---------------------------------------------------------------------------
# Shape bucketing (jit-cache quantization for the acquisition engine)
# ---------------------------------------------------------------------------


def shape_bucket(n: int, minimum: int = 8) -> int:
    """Next power-of-two >= n (floored at ``minimum``) — the jit-cache key."""
    b = max(1, minimum)
    while b < n:
        b *= 2
    return b
