"""PAL — the paper's primary contribution: a parallel, asynchronous,
modular active-learning workflow (five kernels + two sub-controllers).

Public surface:
  transport     — MPI-shaped non-blocking channels (isend/irecv/Test)
  api           — UserModel / UserGene / UserOracle kernel interfaces (S4–S7)
  buffers       — oracle input buffer, retrain_size training buffer, rolling
  committee     — vmapped committee + the paper's 1-D weight packing +
                  shape bucketing
  acquisition   — the ONE UQ path: UQEngine backends (FusedEngine: committee
                  forward + committee_uq kernel + device-side selection
                  rules in a single dispatch under a power-of-two
                  shape-bucketed jit cache; LegacyEngine: per-member
                  UserModel.predict), composable rules (ThresholdRule /
                  TopFractionRule / DiversityRule), and the config-driven
                  make_engine factory
  budget        — cross-round budgeted acquisition: OracleBudgetController
                  (PI control of the effective threshold toward a target
                  oracle rate), the stateful BudgetRule carrying that
                  control on device through the fused dispatch, and the
                  RollingReweightRule (SI Use Case 2 analog: decayed
                  per-region score boost)
  selection     — prediction_check (paper port) / selection_from_uq /
                  adjust_input_for_oracle(_uq) / patience
  weight_sync   — versioned training->prediction weight publication with
                  preallocated ping-pong pack buffers (alloc-free publish);
                  demoted to checkpoint/legacy duty on the fused-training
                  path, where weights hand off device-to-device
  controller    — Exchange + Manager sub-controllers; one engine call per
                  exchange iteration, dynamic_oracle_list on the same engine
  supervisor    — per-loop-class FailurePolicy: task retries with backoff +
                  jitter, crashed-loop restart in place, escalation to
                  StopToken only past the crash budget
  chaos         — deterministic seeded fault injection (FaultPlan /
                  ChaosInjector): scheduled raises, crashes, delays, NaN
                  labels, poisoned committee members
  runtime       — PAL: threads, fault tolerance, elastic pools, checkpoints;
                  pass loss_fn= with a CommitteeSpec and the per-member
                  trainer threads collapse into the fused CommitteeTrainer
                  loop (training/committee_trainer.py)
  speedup       — the SI S2 analytic speedup model
"""
from repro.core.acquisition import (  # noqa: F401
    CommitteeSpec, DiversityRule, FusedEngine, LegacyEngine, SelectionRule,
    ThresholdRule, TopFractionRule, UQEngine, UQResult, make_engine,
)
from repro.core.api import UserGene, UserModel, UserOracle  # noqa: F401
from repro.core.budget import (  # noqa: F401
    BudgetRule, OracleBudgetController, RollingReweightRule,
    rules_from_config,
)
from repro.core.chaos import (  # noqa: F401
    ChaosCrash, ChaosFault, ChaosInjector, FaultEvent, FaultPlan,
)
from repro.core.runtime import PAL  # noqa: F401
from repro.core.supervisor import FailurePolicy, Supervisor  # noqa: F401
from repro.core.speedup import WorkloadParams  # noqa: F401
# NOTE: the speedup() function is NOT re-exported here -- it would shadow the
# `repro.core.speedup` submodule attribute.  Use repro.core.speedup.speedup.
