"""PAL — the paper's primary contribution: a parallel, asynchronous,
modular active-learning workflow (five kernels + two sub-controllers).

Public surface:
  transport     — MPI-shaped non-blocking channels (isend/irecv/Test)
  api           — UserModel / UserGene / UserOracle kernel interfaces (S4–S7)
  buffers       — oracle input buffer, retrain_size training buffer, rolling
  committee     — vmapped committee + the paper's 1-D weight packing, plus
                  FusedPredictSelect: the single-dispatch exchange engine
                  (committee forward fused with the committee_uq kernel
                  under a power-of-two shape-bucketed jit cache)
  selection     — prediction_check (+ the fast path consuming device UQ) /
                  adjust_input_for_oracle / patience
  weight_sync   — versioned training->prediction weight publication with
                  preallocated ping-pong pack buffers (alloc-free publish)
  controller    — Exchange + Manager sub-controllers; with a fused engine
                  one exchange iteration is ONE device dispatch
  runtime       — PAL: threads, fault tolerance, elastic pools, checkpoints
  speedup       — the SI S2 analytic speedup model
"""
from repro.core.api import UserGene, UserModel, UserOracle  # noqa: F401
from repro.core.runtime import PAL  # noqa: F401
from repro.core.speedup import WorkloadParams  # noqa: F401
# NOTE: the speedup() function is NOT re-exported here -- it would shadow the
# `repro.core.speedup` submodule attribute.  Use repro.core.speedup.speedup.
