"""Real-time timing / throughput monitoring (paper §4 "future developments":
real-time tracking of timing and resource usage — implemented here).

Lightweight, lock-protected counters and EWMA timers that every kernel pool
updates in place; ``report()`` renders one dict for logging / EXPERIMENTS.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Dict, Optional


class Timer:
    """EWMA + totals for a repeatedly-timed section."""

    def __init__(self, alpha: float = 0.1):
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.total = 0.0
        self.count = 0
        self.max = 0.0
        self._lock = threading.Lock()

    def add(self, dt: float):
        with self._lock:
            self.total += dt
            self.count += 1
            self.max = max(self.max, dt)
            self.ewma = dt if self.ewma is None else (
                self.alpha * dt + (1 - self.alpha) * self.ewma)

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.add(time.perf_counter() - self._t0)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def stats(self) -> Dict[str, float]:
        return {"mean_s": self.mean, "ewma_s": self.ewma or 0.0,
                "max_s": self.max, "count": self.count,
                "total_s": self.total}


class Monitor:
    """Named timers + counters for the whole PAL run."""

    def __init__(self):
        self._timers: Dict[str, Timer] = collections.defaultdict(Timer)
        self._counters: Dict[str, int] = collections.defaultdict(int)
        self._lock = threading.Lock()
        self.start_time = time.time()

    def timer(self, name: str) -> Timer:
        with self._lock:
            return self._timers[name]

    def incr(self, name: str, n: int = 1):
        with self._lock:
            self._counters[name] += n

    def count(self, name: str) -> int:
        with self._lock:
            return self._counters[name]

    def report(self) -> Dict[str, object]:
        with self._lock:
            return {
                "uptime_s": time.time() - self.start_time,
                "timers": {k: t.stats() for k, t in self._timers.items()},
                "counters": dict(self._counters),
            }
