"""Controller-side buffers (paper §2.5: oracle input buffer + training data
buffer; SI Use Case 2: rolling training set).

All buffers are thread-safe: the Exchange loop appends to the oracle buffer
while the Manager drains it and the training side consumes released batches.
"""
from __future__ import annotations

import pickle
import threading
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np


class OracleInputBuffer:
    """Samples selected for labeling, waiting for a free oracle.

    Supports the paper's ``dynamic_oracle_list``: when retraining finishes,
    the buffer is re-scored with the freshest committee and re-prioritized /
    pruned via a user function (``adjust_input_for_oracle`` in utils).
    """

    def __init__(self, max_size: int = 0):
        self._items: List[Any] = []
        self._lock = threading.Lock()
        self.max_size = max_size
        self.dropped = 0
        self.total_enqueued = 0
        # optional arrival hook (e.g. the runtime's manager-wake event):
        # called OUTSIDE the lock after every successful put
        self.on_put: Optional[Callable[[], None]] = None

    def put(self, items: Sequence[Any]):
        with self._lock:
            self._items.extend(items)
            self.total_enqueued += len(items)
            if self.max_size and len(self._items) > self.max_size:
                overflow = len(self._items) - self.max_size
                # drop the oldest (stalest uncertainty estimates)
                self._items = self._items[overflow:]
                self.dropped += overflow
        if self.on_put is not None:
            self.on_put()

    def pop(self) -> Optional[Any]:
        with self._lock:
            if not self._items:
                return None
            return self._items.pop(0)

    def pop_many(self, n: int) -> List[Any]:
        with self._lock:
            out, self._items = self._items[:n], self._items[n:]
            return out

    def remove_one(self, match: Callable[[Any], bool]) -> bool:
        """Remove the first queued item ``match`` accepts (late-straggler
        dedupe: when a timed-out task's result finally arrives and its label
        is used, the requeued twin still waiting here must be cancelled or
        the oracle recomputes a label the training buffer already has)."""
        with self._lock:
            for i, item in enumerate(self._items):
                if match(item):
                    del self._items[i]
                    return True
        return False

    def adjust(self, fn: Callable[[List[Any]], List[Any]]):
        """paper: adjust_input_for_oracle(to_orcl_buffer, pred_list)."""
        with self._lock:
            self._items = list(fn(list(self._items)))

    def __len__(self):
        with self._lock:
            return len(self._items)

    def snapshot(self) -> List[Any]:
        with self._lock:
            return list(self._items)

    def restore(self, items: Sequence[Any]):
        with self._lock:
            self._items = list(items)

    def snapshot_for_adjust(self) -> Tuple[List[Any], int]:
        """Snapshot plus the enqueue generation at snapshot time — pass the
        generation back to ``merge_adjusted`` so concurrent appends are
        identified correctly even on a bounded buffer."""
        with self._lock:
            return list(self._items), self.total_enqueued

    def merge_adjusted(self, new_items: Sequence[Any], enqueued_at: int,
                       snapshot_len: int = 0):
        """Replace the re-scored snapshot portion with ``new_items``
        (priority-sorted, most uncertain first), KEEPING anything appended
        concurrently since the snapshot was taken (dynamic_oracle_list:
        scoring runs outside the lock, and the Exchange thread keeps
        enqueueing while it does — a blind ``restore`` would silently drop
        those fresh selections).  Pops only happen on the Manager's own
        thread, so the un-scored portion is the appended suffix; it is
        counted via the enqueue generation, not list length, so a
        ``max_size`` trim during scoring cannot drop fresh selections.  On
        overflow the LOWEST-priority re-scored items are evicted first
        (``new_items`` is priority-sorted, unlike the age-sorted steady
        state where ``put`` drops the stalest), and fresh appends are only
        trimmed oldest-first if they alone exceed ``max_size``.

        ``snapshot_len`` (length of the snapshot the caller re-scored) is
        used to keep the ``dropped`` counter honest: snapshot items a
        concurrent ``put`` trim already counted as dropped may be
        re-inserted here via ``new_items``, so merge-overflow evictions are
        only counted beyond what that trim already charged (best-effort —
        identity is not tracked)."""
        with self._lock:
            n_appended = min(len(self._items),
                             self.total_enqueued - enqueued_at)
            appended = self._items[len(self._items) - n_appended:] \
                if n_appended > 0 else []
            new_items = list(new_items)
            trimmed_during = max(
                0, snapshot_len - (len(self._items) - n_appended))
            evicted = 0
            if self.max_size:
                overflow = len(new_items) + len(appended) - self.max_size
                if overflow > 0:
                    keep_new = max(0, len(new_items) - overflow)
                    evicted += len(new_items) - keep_new
                    new_items = new_items[:keep_new]
                if len(appended) > self.max_size:
                    extra = len(appended) - self.max_size
                    appended = appended[extra:]
                    evicted += extra
            self.dropped += max(0, evicted - trimmed_during)
            self._items = new_items + appended


class TrainingDataBuffer:
    """Labeled (input, target) pairs; released to trainers in blocks of
    ``retrain_size`` (paper SI S3: "batch size of increment retraining set").
    """

    def __init__(self, retrain_size: int = 20):
        self.retrain_size = retrain_size
        self._items: List[Tuple[Any, Any]] = []
        self._lock = threading.Lock()
        self.total_labeled = 0

    def add(self, inputs: Any, labels: Any):
        with self._lock:
            self._items.append((inputs, labels))
            self.total_labeled += 1

    def ready(self) -> bool:
        with self._lock:
            return len(self._items) >= self.retrain_size

    def release(self) -> List[Tuple[Any, Any]]:
        """Pop one retrain_size block (or everything if smaller on flush)."""
        with self._lock:
            n = self.retrain_size if len(self._items) >= self.retrain_size \
                else len(self._items)
            out, self._items = self._items[:n], self._items[n:]
            return out

    def __len__(self):
        with self._lock:
            return len(self._items)

    def snapshot(self):
        with self._lock:
            return list(self._items)

    def restore(self, items):
        with self._lock:
            self._items = list(items)


class RollingTrainingBuffer:
    """Fixed-capacity rolling training set (paper SI Use Case 2): newly
    labeled samples push out the oldest ones, keeping epoch time bounded and
    adapting the set to the region currently explored by the generators."""

    def __init__(self, capacity: int):
        assert capacity > 0
        self.capacity = capacity
        self._x: List[np.ndarray] = []
        self._y: List[np.ndarray] = []
        self._lock = threading.Lock()
        self.evicted = 0

    def extend(self, xs: Sequence[np.ndarray], ys: Sequence[np.ndarray]):
        with self._lock:
            self._x.extend(xs)
            self._y.extend(ys)
            if len(self._x) > self.capacity:
                k = len(self._x) - self.capacity
                self._x, self._y = self._x[k:], self._y[k:]
                self.evicted += k

    def arrays(self) -> Tuple[np.ndarray, np.ndarray]:
        with self._lock:
            return np.asarray(self._x), np.asarray(self._y)

    def __len__(self):
        with self._lock:
            return len(self._x)


def save_buffers(path: str, *buffers) -> None:
    """Paper SI S3: orcl_buffer_path / ml_buffer_path backups."""
    state = [b.snapshot() for b in buffers]
    with open(path, "wb") as fh:
        pickle.dump(state, fh)


def load_buffers(path: str, *buffers) -> None:
    with open(path, "rb") as fh:
        state = pickle.load(fh)
    for b, s in zip(buffers, state):
        b.restore(s)
