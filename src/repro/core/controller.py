"""The controller kernel — two sub-controllers, as in the paper (Fig. 2):

* ``Exchange``: the high-frequency generator<->prediction loop.  Gathers
  proposals from every generator, scores them through the ONE acquisition
  engine (core/acquisition.UQEngine — committee forward, UQ statistics, and
  the device-side selection-rule pipeline in a single dispatch on fused
  backends), queues selected samples for the oracle, scatters committee
  means (with restart flags realized as ``None``, the paper's
  first-iteration semantics) back to generators.  There is no fast/legacy
  branching here: every backend returns the same ``UQResult`` and the loop
  body is identical.
* ``Manager``: oracle dispatch (first-available, point-to-point), labeled
  data collection into the training buffer, retrain_size-block release to
  trainers, dynamic oracle-buffer re-prioritization (consuming the SAME
  engine's ``UQResult`` — no stacked ``(K, n_buf, out_dim)`` host tensor,
  no float64 recompute), fault handling (timeout->requeue, dead-worker
  requeue), and AL-state checkpoints.

Both are plain objects with ``step()`` methods — the threaded runtime
(core/runtime.py) drives them, and tests drive them synchronously.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import acquisition as acq
from repro.core import selection as sel
from repro.core.buffers import OracleInputBuffer, TrainingDataBuffer
from repro.core.fault import Heartbeat, TaskLedger
from repro.core.monitor import Monitor
from repro.core.transport import Channel, StopToken
from repro.core.weight_sync import WeightStore


class PredictionPool:
    """The prediction kernel: committee members + their acquisition engine.

    All scoring flows through ``engine.score`` (core/acquisition.UQEngine).
    The engine decides HOW: fused backends run one compiled device program
    over the stacked committee; the legacy backend calls each
    ``UserModel(mode='predict').predict`` — the paper's per-process
    structure — via ``predict_all``.  A user ``predict_all_override``
    replaces the raw committee predictions and therefore forces the legacy
    backend (installed by the runtime / ``Exchange`` default).

    Weights refresh from the WeightStore at pull cadence (paper §2.1):
    fused engines refresh their stacked params directly; per-member models
    are pulled only when the engine actually uses them.
    """

    def __init__(self, models: Sequence[Any], store: Optional[WeightStore],
                 monitor: Optional[Monitor] = None,
                 engine: Optional[acq.UQEngine] = None,
                 predict_all_override: Optional[Callable] = None):
        self.models = list(models)
        self.store = store
        self.monitor = monitor or Monitor()
        self._versions = [-1] * len(self.models)
        self._override = predict_all_override
        self._engine: Optional[acq.UQEngine] = None
        self.engine = engine

    @property
    def engine(self) -> Optional[acq.UQEngine]:
        return self._engine

    @engine.setter
    def engine(self, eng: Optional[acq.UQEngine]):
        # invariant: a predict_all_override puts the user in control of the
        # raw committee predictions, so only backends that consume
        # predict_all (the legacy path) may score this pool — a fused
        # engine would silently bypass the override
        if (eng is not None and self._override is not None
                and not eng.uses_models):
            raise ValueError(
                "predict_all_override requires a legacy (per-member) UQ "
                "backend; a fused engine would bypass the override")
        self._engine = eng

    def refresh_weights(self):
        if self.store is None:
            return 0
        n = 0
        if self.engine is not None:
            n = self.engine.refresh_from(self.store)
        if self.engine is None or self.engine.uses_models:
            for i, m in enumerate(self.models):
                # prediction member i replicates training member
                # i % ml_process (paper: prediction models are replicas of
                # training models)
                packed = self.store.pull_packed(i % self.store.n_members,
                                                newer_than=self._versions[i])
                if packed is not None:
                    arr, v = packed
                    m.update(arr)
                    self._versions[i] = v
                    n += 1
        if n:
            self.monitor.incr("prediction.weight_refreshes", n)
        return n

    def predict_uq(self, list_data_to_pred: List[np.ndarray]) -> acq.UQResult:
        """The one scoring call: engine -> UQResult (mean, scalar_std,
        component_std, mask)."""
        with self.monitor.timer("exchange.predict"):
            return self.engine.score(list_data_to_pred)

    def predict_all(self, list_data_to_pred: List[np.ndarray]) -> np.ndarray:
        """-> (K, n_gen, out_dim) stacked committee predictions — the raw
        input of the legacy backend (and of user overrides)."""
        if self._override is not None:
            return np.asarray(self._override(list_data_to_pred))
        if not self.models:
            raise RuntimeError(
                "PredictionPool has no per-member models; fused engines "
                "never materialize stacked predictions")
        outs = [m.predict(list_data_to_pred) for m in self.models]
        return np.asarray(outs)


@dataclasses.dataclass
class ExchangeConfig:
    std_threshold: float = 0.05
    patience: int = 5
    weight_pull_every: int = 1       # exchange iterations between pulls
    progress_save_interval: float = 60.0
    flag_restart_with_none: bool = True
    min_interval: float = 0.0        # iteration floor (few-core fairness)


class Exchange:
    """High-frequency generator<->prediction loop (one dedicated
    sub-controller in the paper).

    The loop body is backend-agnostic: gather -> ``engine.score`` ->
    scatter.  If the PredictionPool arrives without an engine (direct
    construction in tests/tools), a legacy per-member engine with the
    config's threshold rule is installed — the runtime normally builds the
    engine from ``PALRunConfig`` via ``acquisition.make_engine``.
    """

    def __init__(
        self,
        generators: Sequence[Any],               # UserGene instances
        prediction: PredictionPool,
        oracle_buffer: OracleInputBuffer,
        cfg: ExchangeConfig,
        monitor: Optional[Monitor] = None,
        fleet=None,                              # exploration.WalkerFleet
    ):
        self.generators = list(generators)
        self.prediction = prediction
        self.oracle_buffer = oracle_buffer
        self.cfg = cfg
        self.monitor = monitor or Monitor()
        self.fleet = fleet
        if self.prediction.engine is None:
            self.prediction.engine = acq.LegacyEngine(
                self.prediction.predict_all, cfg.std_threshold)
        n = len(self.generators)
        self.data_to_gene: List[Optional[np.ndarray]] = [None] * n
        # gather buffer, preallocated and reused across iterations — the
        # per-iteration list rebuild was measurable against the fused
        # engine's single-dispatch scoring
        self._gather: List[Optional[np.ndarray]] = [None] * n
        self.patience = sel.PatienceTracker(n, cfg.patience)
        self.iteration = 0
        self._last_save = time.time()

    def step(self) -> Optional[StopToken]:
        if self.fleet is not None:
            return self._step_fleet()
        t0 = time.perf_counter()
        # 1. gather proposals from every generator (paper: MPI gather)
        inputs = self._gather
        for i, g in enumerate(self.generators):
            stop, x = g.generate_new_data(self.data_to_gene[i])
            if stop:
                # proposals gathered BEFORE the stopping generator would
                # otherwise be dropped un-scored — drain them first
                self._drain_on_stop(i)
                return StopToken(f"generator{i}", "generator stop criterion")
            inputs[i] = np.asarray(x)
        t_gen = time.perf_counter() - t0
        self.monitor.incr("exchange.gather_ns", int(t_gen * 1e9))

        # 2. committee inference + UQ + selection rules — one engine call
        #    (one device dispatch on fused backends)
        if self.iteration % max(1, self.cfg.weight_pull_every) == 0:
            self.prediction.refresh_weights()
        uq = self.prediction.predict_uq(inputs)

        # 3. realize the selection; queue to oracle; scatter back
        t1 = time.perf_counter()
        res = sel.selection_from_uq(inputs, uq,
                                    scatter_out=self.data_to_gene)
        # acquisition accounting: queued_to_oracle/proposals is the
        # realized oracle rate the cross-round budget controller
        # (core/budget.BudgetRule) steers toward PALRunConfig.oracle_budget
        self.monitor.incr("exchange.proposals", len(inputs))
        if res.inputs_to_oracle:
            self.oracle_buffer.put(res.inputs_to_oracle)
            self.monitor.incr("exchange.queued_to_oracle",
                              len(res.inputs_to_oracle))
        restart = self.patience.step(res.uncertain_mask)
        out = res.data_to_generators          # == self.data_to_gene, reused
        if self.cfg.flag_restart_with_none:
            for i in np.where(restart)[0]:
                out[int(i)] = None
        self.monitor.timer("exchange.comm").add(
            t_gen + (time.perf_counter() - t1))
        self.monitor.incr("exchange.iterations")
        self.iteration += 1

        # periodic progress save (paper: progress_save_interval)
        if (time.time() - self._last_save) >= self.cfg.progress_save_interval:
            for g in self.generators:
                g.save_progress()
            self._last_save = time.time()
        if self.cfg.min_interval:
            left = self.cfg.min_interval - (time.perf_counter() - t0)
            if left > 0:
                time.sleep(left)
        return None

    def _drain_on_stop(self, n_gathered: int):
        """A StopToken mid-gather used to silently drop the proposals
        already gathered from earlier generators this iteration.  Score
        that prefix (advance=False — a partial round must not consume
        cross-round budget state) and queue whatever is selected, so no
        proposal vanishes on stop."""
        if n_gathered <= 0:
            return
        inputs = [self._gather[i] for i in range(n_gathered)]
        uq = self.prediction.engine.score(inputs, advance=False)
        res = sel.selection_from_uq(inputs, uq)
        if res.inputs_to_oracle:
            self.oracle_buffer.put(res.inputs_to_oracle)
            self.monitor.incr("exchange.queued_to_oracle",
                              len(res.inputs_to_oracle))
        self.monitor.incr("exchange.drained_on_stop", n_gathered)

    def _step_fleet(self) -> Optional[StopToken]:
        """Fleet fast path: the whole gather → score → select → scatter
        cycle is ONE fused device dispatch inside ``WalkerFleet.step``.
        The only per-iteration host traffic is the selected oracle
        candidates (plus one int32 count); patience/restart run as device
        rules, so the host ``PatienceTracker`` stays untouched."""
        t0 = time.perf_counter()
        if self.iteration % max(1, self.cfg.weight_pull_every) == 0:
            self.prediction.refresh_weights()
        with self.monitor.timer("exchange.predict"):
            out = self.fleet.step()
        self.monitor.incr("exchange.proposals", self.fleet.n_walkers)
        if out.n_selected:
            self.oracle_buffer.put(list(out.selected))
            self.monitor.incr("exchange.queued_to_oracle", out.n_selected)
        self.monitor.incr("exchange.iterations")
        self.iteration += 1
        max_steps = self.fleet.cfg.max_steps
        if max_steps and self.fleet.steps_done >= max_steps:
            return StopToken("fleet", "fleet max_steps reached")
        if self.cfg.min_interval:
            left = self.cfg.min_interval - (time.perf_counter() - t0)
            if left > 0:
                time.sleep(left)
        return None


class OracleTaskFailure:
    """Result-channel sentinel: a worker exhausted its in-place retries on
    ONE task (FailurePolicy.task_retries) and is reporting the failure
    instead of dying.  The Manager redispatches the payload while ledger
    retries remain, then records the task as failed — task failure never
    becomes worker death, worker death never becomes run death."""

    __slots__ = ("error",)

    def __init__(self, error: str):
        self.error = error

    def __repr__(self):
        return f"OracleTaskFailure({self.error!r})"


def _payload_fp(payload) -> bytes:
    """Content fingerprint for oracle payloads (dtype+shape+bytes) — the
    dedupe key for requeued-task twins."""
    arr = np.ascontiguousarray(payload)
    return f"{arr.dtype.str}|{arr.shape}|".encode() + arr.tobytes()


@dataclasses.dataclass
class ManagerConfig:
    retrain_size: int = 20
    dynamic_oracle_list: bool = True
    oracle_timeout: float = 30.0
    max_oracle_retries: int = 2
    heartbeat_interval: float = 5.0
    # dynamic_oracle_list drop threshold: waiting inputs whose fresh
    # max-component committee std fell to or below this are dropped (stale —
    # the retrained committee is no longer uncertain about them).  The
    # runtime plumbs PALRunConfig.std_threshold here; 0.0 keeps entries with
    # any disagreement at all.
    std_threshold: float = 0.0


class OracleEndpoint:
    """Manager-side handle for one oracle worker: job + result channels."""

    def __init__(self, rank: str):
        self.rank = rank
        self.jobs = Channel(f"jobs:{rank}")
        self.results = Channel(f"results:{rank}")
        self.busy_task: Optional[int] = None


class Manager:
    """Oracle/training traffic sub-controller."""

    def __init__(
        self,
        oracle_buffer: OracleInputBuffer,
        train_buffer: TrainingDataBuffer,
        trainer_channels: Sequence[Channel],
        cfg: ManagerConfig,
        monitor: Optional[Monitor] = None,
        adjust_fn: Optional[Callable] = None,   # (items, UQResult) -> items
        fresh_score: Optional[Callable] = None,  # inputs -> UQResult
    ):
        self.oracle_buffer = oracle_buffer
        self.train_buffer = train_buffer
        self.trainer_channels = list(trainer_channels)
        self.cfg = cfg
        self.monitor = monitor or Monitor()
        self.ledger = TaskLedger(cfg.oracle_timeout, cfg.max_oracle_retries)
        self.heartbeat = Heartbeat(cfg.heartbeat_interval)
        self.endpoints: Dict[str, OracleEndpoint] = {}
        self.adjust_fn = adjust_fn
        self.fresh_score = fresh_score
        self.releases = 0
        self._retrain_completions_seen = 0
        # late-straggler dedupe state (keyed by payload fingerprint):
        # _requeued_fp counts payloads requeued by fault handling whose
        # original result may still arrive; _expect_duplicate counts twins
        # whose label was already delivered by that late result, so the
        # twin's own result must be dropped when it lands
        self._requeued_fp: Dict[bytes, int] = {}
        self._expect_duplicate: Dict[bytes, int] = {}

    # ------------------------------------------------------------ elasticity
    def register_oracle(self, rank: str) -> OracleEndpoint:
        ep = OracleEndpoint(rank)
        self.endpoints[rank] = ep
        self.heartbeat.beat(rank)
        return ep

    def unregister_oracle(self, rank: str):
        ep = self.endpoints.pop(rank, None)
        if ep is None:
            return
        for t in self.ledger.requeue_worker(rank):
            self._note_requeued(t.payload)
            self.oracle_buffer.put([t.payload])
        self.heartbeat.forget(rank)

    def requeue_crashed_worker(self, rank: str):
        """Crash-recovery hook (runtime ``on_crash``): pull the crashed
        worker's in-flight tasks back into the oracle buffer and free its
        endpoint, WITHOUT unregistering — the supervised restart re-enters
        the same rank.  A result the worker managed to send before dying is
        then absorbed by the late-straggler dedupe path."""
        ep = self.endpoints.get(rank)
        if ep is not None:
            ep.busy_task = None
        for t in self.ledger.requeue_worker(rank):
            self._note_requeued(t.payload)
            self.oracle_buffer.put([t.payload])
        self.monitor.incr("manager.requeued_crash")

    def _note_requeued(self, payload):
        fp = _payload_fp(payload)
        self._requeued_fp[fp] = self._requeued_fp.get(fp, 0) + 1

    # ---------------------------------------------------------------- step
    def step(self, retrain_completions: int = 0) -> None:
        self._collect_results()
        self._handle_faults()
        self._dispatch()
        self._release_training_data()
        if (self.cfg.dynamic_oracle_list
                and retrain_completions > self._retrain_completions_seen):
            self._retrain_completions_seen = retrain_completions
            self._adjust_oracle_buffer()

    def _collect_results(self):
        for ep in list(self.endpoints.values()):
            while ep.results.poll():
                task_id, inp, label = ep.results.recv()
                self.heartbeat.beat(ep.rank)
                if ep.busy_task == task_id:
                    ep.busy_task = None
                t = self.ledger.complete(task_id)
                if isinstance(label, OracleTaskFailure):
                    self._handle_task_failure(t, label)
                    continue
                if t is None:
                    self._handle_late_result(inp, label)
                    continue
                fp = _payload_fp(t.payload)
                if self._expect_duplicate.get(fp, 0) > 0:
                    # this task's payload was already labeled by its timed-out
                    # twin's late result — adding it again would duplicate a
                    # training row
                    self._dec(self._expect_duplicate, fp)
                    self.monitor.incr("oracle.duplicate_results")
                    continue
                if self._requeued_fp.get(fp, 0) > 0:
                    # the requeued twin delivered first: any late straggler
                    # for this payload is now a duplicate, not a usable label
                    self._dec(self._requeued_fp, fp)
                if not self._label_ok(label):
                    self._handle_bad_label(t)
                    continue
                self.train_buffer.add(inp, label)
                self.monitor.incr("manager.labeled")

    @staticmethod
    def _label_ok(label) -> bool:
        lab = np.asarray(label)
        if lab.dtype.kind != "f":
            return True
        return bool(np.isfinite(lab).all())

    @staticmethod
    def _dec(counts: Dict[bytes, int], fp: bytes):
        n = counts.get(fp, 0) - 1
        if n > 0:
            counts[fp] = n
        else:
            counts.pop(fp, None)

    def _handle_task_failure(self, t, failure: OracleTaskFailure):
        """Worker-reported task failure (retries exhausted in place)."""
        self.monitor.incr("oracle.task_failures_reported")
        if t is None:       # already requeued by timeout — twin handles it
            return
        if t.retries < self.ledger.max_retries:
            self._redispatch(t.payload, t.retries + 1)
        else:
            self.ledger.fail(t)
            self.monitor.incr("oracle.task_gave_up")

    def _handle_bad_label(self, t):
        """Non-finite label (chaos nan_label / genuinely broken oracle):
        never admit it to the training buffer; retry the task elsewhere."""
        self.monitor.incr("oracle.nonfinite_labels")
        if t.retries < self.ledger.max_retries:
            self._redispatch(t.payload, t.retries + 1)
        else:
            self.ledger.fail(t)
            self.monitor.incr("oracle.task_gave_up")

    def _handle_late_result(self, inp, label):
        """Result for a task the ledger already requeued (timeout / dead or
        crashed worker).  The old behavior discarded the label and let the
        twin recompute it — wasted oracle work, and the only guard against
        DOUBLE-labeling was the discard itself.  Now: if the twin has not
        delivered yet, USE this label and cancel the twin (drop it from the
        buffer if still queued, else mark its future result a duplicate);
        if the twin already delivered, this is a true duplicate."""
        fp = _payload_fp(inp)
        if self._requeued_fp.get(fp, 0) > 0 and self._label_ok(label):
            self._dec(self._requeued_fp, fp)
            self.train_buffer.add(inp, label)
            self.monitor.incr("manager.labeled")
            self.monitor.incr("manager.late_results_used")
            if not self.oracle_buffer.remove_one(
                    lambda item: _payload_fp(item) == fp):
                # twin already dispatched (or mid-flight): its result must
                # be dropped when it arrives
                self._expect_duplicate[fp] = \
                    self._expect_duplicate.get(fp, 0) + 1
            return
        self.monitor.incr("oracle.duplicate_results")
        self.monitor.incr("manager.duplicate_results")

    def _handle_faults(self):
        for t in self.ledger.expired():
            self.monitor.incr("manager.requeued_timeout")
            ep = self.endpoints.get(t.worker)
            if ep is not None and ep.busy_task == t.task_id:
                ep.busy_task = None
            self._note_requeued(t.payload)
            self._redispatch(t.payload, t.retries + 1)
        for rank in self.heartbeat.dead_workers():
            self.monitor.incr("manager.dead_workers")
            ep = self.endpoints.get(rank)
            if ep is not None:
                ep.busy_task = None
            for t in self.ledger.requeue_worker(rank):
                self._note_requeued(t.payload)
                self._redispatch(t.payload, t.retries + 1)

    def _redispatch(self, payload, retries: int):
        ep = self._free_endpoint()
        if ep is None:
            self.oracle_buffer.put([payload])
            return
        tid = self.ledger.dispatch(payload, ep.rank, retries)
        ep.busy_task = tid
        ep.jobs.isend((tid, payload))

    def _free_endpoint(self) -> Optional[OracleEndpoint]:
        # list() copy: workers register/unregister concurrently
        for ep in list(self.endpoints.values()):
            if ep.busy_task is None and not self.heartbeat.is_dead(ep.rank):
                return ep
        return None

    def _dispatch(self):
        """Paper §2.5: buffered data sent to the first available oracle."""
        while True:
            ep = self._free_endpoint()
            if ep is None:
                return
            payload = self.oracle_buffer.pop()
            if payload is None:
                return
            tid = self.ledger.dispatch(payload, ep.rank)
            ep.busy_task = tid
            ep.jobs.isend((tid, payload))
            self.monitor.incr("manager.dispatched")

    def _release_training_data(self):
        """Broadcast retrain_size blocks to every trainer (paper §2.5)."""
        while self.train_buffer.ready():
            block = self.train_buffer.release()
            for ch in self.trainer_channels:
                ch.isend(block)
            self.releases += 1
            self.monitor.incr("manager.releases")

    def _adjust_oracle_buffer(self):
        """dynamic_oracle_list: re-score waiting inputs with the freshest
        committee and drop/reorder (paper SI Utilities).

        ``fresh_score`` is the SAME acquisition engine the exchange loop
        uses — one ``UQResult`` (scalar_std for the drop decision,
        component_std for the ranking) replaces the former stacked
        ``(K, n_buf, out_dim)`` host tensor + float64 recompute."""
        if self.fresh_score is None:
            return
        items, enq0 = self.oracle_buffer.snapshot_for_adjust()
        if not items:
            return
        uq = self.fresh_score(items)
        if self.adjust_fn is not None:
            new_items = self.adjust_fn(items, uq)
        else:
            # honor_selection: whatever the engine's rule pipeline just
            # re-selected survives even below the drop threshold, so a
            # custom policy (e.g. top-fraction) is never contradicted here
            new_items = sel.adjust_input_for_oracle_uq(
                items, uq, self.cfg.std_threshold, honor_selection=True)
        # merge, don't restore: the Exchange thread kept enqueueing while
        # the engine scored the snapshot — those must survive un-dropped
        self.oracle_buffer.merge_adjusted(new_items, enq0,
                                          snapshot_len=len(items))
        self.monitor.incr("manager.buffer_adjusts")
