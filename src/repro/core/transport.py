"""MPI-shaped non-blocking transport (paper SI S1, Figure 4).

The paper's kernels communicate via mpi4py Isend/Irecv/Test.  This module
keeps that API surface — ``Channel.isend`` / ``Channel.irecv`` returning
``Request`` objects with ``test()`` / ``wait()`` — so the controller logic is
a faithful port, while the realization is swappable:

* ``InProcessBackend`` (default): thread-safe queues.  JAX dispatch releases
  the GIL inside compiled code, so kernel pools overlap on one host.
* A ``jax.distributed`` process-group backend is the documented multi-host
  path (same API; each kernel pool is a process group).  Not exercisable in
  this container — see DESIGN.md §2.

Matching the paper's constraint that "data transferred among kernels should
be arranged as 1-D Numpy numerical arrays", payloads are validated as numpy
arrays (or pytrees thereof) when ``strict_arrays`` is set; fixed_size_data
mirrors the paper's size-prenegotiation knob (SI S3) and is validated here.
"""
from __future__ import annotations

import collections
import itertools
import queue
import threading
import time
from typing import Any, Deque, Dict, Iterable, List, Optional, Tuple

import numpy as np


class TransportError(RuntimeError):
    pass


# ---------------------------------------------------------------- chaos hook
# Process-wide fault-injection point (core/chaos.ChaosInjector), installed by
# the runtime when a FaultPlan is supplied.  ``Channel.isend`` consults it at
# the ``transport.send`` site (rank = channel name), which lets a plan
# exercise message-path failures without subclassing the transport.
_CHAOS = None


def install_chaos(injector) -> None:
    global _CHAOS
    _CHAOS = injector


def uninstall_chaos() -> None:
    global _CHAOS
    _CHAOS = None


class Request:
    """Non-blocking operation handle, mirroring mpi4py.MPI.Request."""

    def __init__(self):
        self._done = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None

    # -- producer side -----------------------------------------------------
    def _complete(self, value: Any = None):
        self._value = value
        self._done.set()

    def _fail(self, err: BaseException):
        self._error = err
        self._done.set()

    # -- consumer side (paper: req_data.Test() in the retrain loop) --------
    def test(self) -> bool:
        return self._done.is_set()

    Test = test  # mpi4py capitalization, used verbatim by ported user code

    def wait(self, timeout: Optional[float] = None) -> Any:
        if not self._done.wait(timeout):
            raise TimeoutError("Request.wait timed out")
        if self._error is not None:
            raise self._error
        return self._value

    Wait = wait

    @property
    def value(self) -> Any:
        if not self._done.is_set():
            raise TransportError("value read before completion")
        if self._error is not None:
            raise self._error
        return self._value


def _check_payload(data: Any, fixed_size: Optional[Tuple[int, ...]]):
    """Paper: MPI messages require predetermined sizes to be efficient."""
    if isinstance(data, np.ndarray):
        if fixed_size is not None and tuple(data.shape) != fixed_size:
            raise TransportError(
                f"fixed_size_data violated: got {data.shape}, "
                f"expected {fixed_size}")


class Channel:
    """Point-to-point channel with non-blocking send/recv semantics."""

    def __init__(self, name: str = "chan", maxsize: int = 0,
                 fixed_size: Optional[Tuple[int, ...]] = None):
        self.name = name
        self._q: "queue.Queue[Tuple[Any, Request]]" = queue.Queue(maxsize)
        self._pending_recv: Deque[Request] = collections.deque()
        self._lock = threading.Lock()
        self.fixed_size = fixed_size
        self.sent = 0
        self.received = 0

    # ------------------------------------------------------------------ tx
    def isend(self, data: Any) -> Request:
        if _CHAOS is not None:
            _CHAOS.check("transport.send", rank=self.name)
        _check_payload(data, self.fixed_size)
        req = Request()
        with self._lock:
            if self._pending_recv:
                rreq = self._pending_recv.popleft()
                rreq._complete(data)
                req._complete()
                self.sent += 1
                self.received += 1
                return req
            self._q.put((data, req))
            self.sent += 1
        return req

    def send(self, data: Any):
        self.isend(data)  # queue-backed: send completes on enqueue

    # ------------------------------------------------------------------ rx
    def irecv(self) -> Request:
        req = Request()
        with self._lock:
            try:
                data, sreq = self._q.get_nowait()
            except queue.Empty:
                self._pending_recv.append(req)
                return req
            sreq._complete()
            req._complete(data)
            self.received += 1
        return req

    def recv(self, timeout: Optional[float] = None) -> Any:
        """Blocking receive.  On timeout the posted request is CANCELLED —
        otherwise it stays parked in the pending queue and silently consumes
        the next message (jobs delivered to a receiver that stopped waiting
        vanish; this deadlocked the oracle pool whenever dispatch started
        later than the workers' first poll)."""
        req = self.irecv()
        try:
            return req.wait(timeout)
        except TimeoutError:
            with self._lock:
                try:
                    self._pending_recv.remove(req)
                except ValueError:
                    pass  # raced: isend completed it under the lock
            if req.test():
                return req.value
            raise

    def poll(self) -> bool:
        with self._lock:
            return not self._q.empty()

    def qsize(self) -> int:
        return self._q.qsize()


class Communicator:
    """A set of named ranks with channels between them (one MPI_COMM analog).

    Collective helpers mirror the paper's controller usage: gather from a
    pool, broadcast/scatter to a pool.
    """

    def __init__(self, name: str = "comm"):
        self.name = name
        self._channels: Dict[Tuple[str, str], Channel] = {}
        self._lock = threading.Lock()

    def channel(self, src: str, dst: str) -> Channel:
        key = (src, dst)
        with self._lock:
            if key not in self._channels:
                self._channels[key] = Channel(f"{self.name}:{src}->{dst}")
            return self._channels[key]

    # ---------------------------------------------------------- collectives
    def gather(self, srcs: Iterable[str], dst: str,
               timeout: Optional[float] = None) -> List[Any]:
        """Blocking gather (sorted by rank, as the paper requires)."""
        return [self.channel(s, dst).recv(timeout) for s in srcs]

    def broadcast(self, src: str, dsts: Iterable[str], data: Any):
        for d in dsts:
            self.channel(src, d).isend(data)

    def scatter(self, src: str, dsts: Iterable[str], datas: Iterable[Any]):
        dsts, datas = list(dsts), list(datas)
        if len(dsts) != len(datas):
            raise TransportError(
                f"scatter arity mismatch: {len(dsts)} ranks, "
                f"{len(datas)} payloads")
        for d, x in zip(dsts, datas):
            self.channel(src, d).isend(x)

    def stats(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {
                f"{s}->{d}": {"sent": c.sent, "received": c.received,
                              "backlog": c.qsize()}
                for (s, d), c in self._channels.items()
            }


class StopToken:
    """Sentinel broadcast on shutdown (paper: stop_run signalling)."""

    def __init__(self, origin: str, reason: str = ""):
        self.origin = origin
        self.reason = reason
        self.timestamp = time.time()

    def __repr__(self):
        return f"StopToken(origin={self.origin!r}, reason={self.reason!r})"


_counter = itertools.count()


def unique_rank(prefix: str) -> str:
    return f"{prefix}{next(_counter)}"
