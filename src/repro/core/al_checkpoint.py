"""Whole-AL-state checkpoint/restart (beyond-paper; DESIGN.md §2).

Snapshot = committee weights (packed 1-D per member, the paper's own wire
format) + oracle/training buffers + generator states + patience counters +
progress counters.  Written atomically (tmp + rename) so a crash mid-write
never corrupts the restore point; retention keeps the last K snapshots.
"""
from __future__ import annotations

import logging
import os
import pickle
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

log = logging.getLogger(__name__)


def save_atomic(path: str, state: Dict[str, Any]) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".alckpt_")
    try:
        with os.fdopen(fd, "wb") as fh:
            pickle.dump(state, fh, protocol=pickle.HIGHEST_PROTOCOL)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load(path: str) -> Dict[str, Any]:
    with open(path, "rb") as fh:
        return pickle.load(fh)


class ALCheckpointer:
    """Periodic whole-state snapshots with retention + auto-resume."""

    def __init__(self, result_dir: str, every_seconds: float = 0.0,
                 keep: int = 3):
        self.result_dir = result_dir
        self.every = every_seconds
        self.keep = keep
        self._last = 0.0
        self.saves = 0
        self.corrupt_skipped = 0

    def _path(self, step: int) -> str:
        return os.path.join(self.result_dir, f"al_state_{step:08d}.pkl")

    def due(self) -> bool:
        return self.every > 0 and (time.time() - self._last) >= self.every

    def save(self, step: int, state: Dict[str, Any]) -> str:
        path = self._path(step)
        state = dict(state)
        state["__step__"] = step
        state["__time__"] = time.time()
        save_atomic(path, state)
        self._last = time.time()
        self.saves += 1
        self._retain()
        return path

    def _retain(self):
        snaps = self.list_snapshots()
        for p in snaps[:-self.keep]:
            os.unlink(p)

    def list_snapshots(self) -> List[str]:
        if not os.path.isdir(self.result_dir):
            return []
        return sorted(
            os.path.join(self.result_dir, f)
            for f in os.listdir(self.result_dir)
            if f.startswith("al_state_") and f.endswith(".pkl"))

    def latest(self) -> Optional[Dict[str, Any]]:
        """Newest LOADABLE snapshot.  ``save_atomic`` makes an in-progress
        write invisible, but a kill can still leave a truncated/garbage file
        at the newest path through other channels (copied trees, disk-full
        renames) — restore must fall back to the previous intact snapshot
        instead of dying on the corrupt one."""
        for p in reversed(self.list_snapshots()):
            try:
                return load(p)
            except (OSError, EOFError, pickle.UnpicklingError,
                    AttributeError, ImportError, IndexError, ValueError) as e:
                self.corrupt_skipped += 1
                log.warning("skipping unreadable checkpoint %s: %r", p, e)
        return None
