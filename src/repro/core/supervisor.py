"""Supervised execution of the PAL kernel loops (ISSUE 6 tentpole).

The seed runtime was strictly fail-stop: ``PAL._guard`` turned ANY
exception in any kernel thread into a workflow-wide StopToken.  For
days-long AL campaigns with failure-prone ab initio oracles that policy
conflates three very different severities.  This module separates them:

  task failure   — one ``oracle.run_calc`` raising.  Retried in place with
                   exponential backoff + jitter (``FailurePolicy.
                   task_retries``); exhausted retries surface as a failure
                   sentinel on the results channel and the Manager's
                   TaskLedger redispatches or fails THAT task.  The worker
                   never dies for a task.
  loop crash     — a kernel loop (oracle worker, trainer, exchange, ...)
                   raising out of its main loop.  The supervisor logs it,
                   records a :class:`FaultRecord`, runs the loop's
                   ``on_crash`` cleanup (e.g. requeue the rank's in-flight
                   ledger tasks) and RESTARTS the loop in the same thread
                   after a backoff.  The trainer resumes from its
                   device-resident replay ring + last stacked TrainState;
                   an oracle re-registers a fresh endpoint.
  run failure    — more than ``max_crashes`` crashes of one loop inside
                   ``crash_window_s``.  Only then does the supervisor
                   escalate to the fail-stop path (StopToken), because at
                   that point restarting is hiding a systemic problem.

Counters (``monitor``): ``runtime.thread_crashes`` (kept from the seed —
healthy-run tests assert it stays 0), ``runtime.thread_restarts``,
``supervisor.escalations`` and per-class ``supervisor.crashes.<class>``.
"""
from __future__ import annotations

import dataclasses
import logging
import random
import threading
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class FailurePolicy:
    """Per-loop-class failure handling knobs (see ``PALRunConfig``).

    ``task_retries``      in-place retries for one oracle task before the
                          worker gives up and reports a task failure.
    ``task_backoff_s``    first retry delay; grows by ``backoff_factor``
                          per attempt, capped at ``backoff_max_s``, with
                          ``jitter`` relative randomization (decorrelates
                          thundering-herd retries across workers).
    ``max_crashes``       crash count within ``crash_window_s`` at which
                          the supervisor stops restarting and escalates
                          to a StopToken.  1 == the seed's fail-stop.
    ``restart_backoff_s`` first restart delay (same growth/jitter rules).
    """

    task_retries: int = 2
    task_backoff_s: float = 0.05
    backoff_factor: float = 2.0
    backoff_max_s: float = 2.0
    jitter: float = 0.25
    max_crashes: int = 3
    crash_window_s: float = 30.0
    restart_backoff_s: float = 0.1


@dataclasses.dataclass
class FaultRecord:
    """One observed crash, kept for ``PAL.report()['last_fault']``."""

    thread: str
    loop_class: str
    error: str
    time: float
    restarts: int

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class Supervisor:
    """Restart-with-backoff driver for named kernel loops.

    ``run(name, loop_class, fn, *args)`` executes ``fn`` in the CALLING
    thread under supervision: the thread object survives crashes (so
    ``PAL.shutdown`` joins the same handles it started), only the loop
    body is re-entered.  Backoff sleeps wait on ``stop_event`` so a
    shutdown interrupts them immediately.

    ``escalate`` is the fail-stop callback (``PAL._signal_stop``); it
    receives ``(name, reason)`` and is invoked once the loop burns through
    its crash budget.
    """

    def __init__(self, monitor, escalate: Callable[[str, str], None],
                 stop_event: threading.Event, *,
                 policies: Optional[Dict[str, FailurePolicy]] = None,
                 seed: int = 0):
        self.monitor = monitor
        self.escalate = escalate
        self.stop_event = stop_event
        self.policies = dict(policies or {})
        self.default_policy = self.policies.get("default", FailurePolicy())
        self._lock = threading.Lock()
        self._crash_times: Dict[str, deque] = {}
        self._restarts: Dict[str, int] = {}
        self._rng = random.Random(seed)
        self.last_fault: Optional[FaultRecord] = None
        self.faults: List[FaultRecord] = []
        self._health: Dict[str, Callable[[], Any]] = {}

    # -------------------------------------------------------------- policy
    def policy(self, loop_class: str) -> FailurePolicy:
        return self.policies.get(loop_class, self.default_policy)

    def backoff_delay(self, policy: FailurePolicy, attempt: int,
                      base: Optional[float] = None) -> float:
        """Exponential backoff with relative jitter: ``base * factor^n``,
        capped, then scaled by ``1 ± jitter``."""
        b = policy.task_backoff_s if base is None else base
        d = min(b * (policy.backoff_factor ** max(attempt, 0)),
                policy.backoff_max_s)
        with self._lock:
            j = 1.0 + policy.jitter * (2.0 * self._rng.random() - 1.0)
        return max(d * j, 0.0)

    # ----------------------------------------------------------- bookkeeping
    def _record_crash(self, name: str, loop_class: str,
                      err: BaseException) -> FaultRecord:
        now = time.monotonic()
        with self._lock:
            times = self._crash_times.setdefault(name, deque())
            times.append(now)
            pol = self.policy(loop_class)
            while times and now - times[0] > pol.crash_window_s:
                times.popleft()
            rec = FaultRecord(thread=name, loop_class=loop_class,
                              error=repr(err), time=time.time(),
                              restarts=self._restarts.get(name, 0))
            self.last_fault = rec
            self.faults.append(rec)
        if self.monitor is not None:
            self.monitor.incr("runtime.thread_crashes")
            self.monitor.incr(f"supervisor.crashes.{loop_class}")
        return rec

    def _crashes_in_window(self, name: str) -> int:
        with self._lock:
            return len(self._crash_times.get(name, ()))

    def total_restarts(self) -> int:
        with self._lock:
            return sum(self._restarts.values())

    def register_health(self, name: str, fn: Callable[[], Any]):
        """Attach a component health probe (e.g. the serving queue's
        ``health``): ``snapshot()['components'][name]`` carries its latest
        payload, so one supervisor snapshot is the whole degradation
        surface."""
        with self._lock:
            self._health[name] = fn

    def snapshot(self) -> Dict[str, Any]:
        """Observability payload for ``PAL.report()``."""
        with self._lock:
            probes = dict(self._health)
            snap = {
                "last_fault": (self.last_fault.as_dict()
                               if self.last_fault else None),
                "faults_total": len(self.faults),
                "restarts": dict(self._restarts),
            }
        # probes run OUTSIDE self._lock: each takes its component's own
        # lock (the serving queue's health() does) and nesting the
        # supervisor lock around them invites lock-order inversions
        if probes:
            comps: Dict[str, Any] = {}
            for name, fn in probes.items():
                try:
                    comps[name] = fn()
                except BaseException as e:  # noqa: BLE001 — probe, not fatal
                    comps[name] = {"error": repr(e)}
            snap["components"] = comps
        return snap

    # ----------------------------------------------------------------- run
    def run(self, name: str, loop_class: str, fn: Callable, *args,
            on_crash: Optional[Callable[[BaseException], None]] = None,
            should_stop: Optional[Callable[[], bool]] = None):
        """Drive ``fn(*args)`` under supervision in the current thread.

        Returns when ``fn`` returns cleanly, when a stop is requested, or
        after escalation.  ``on_crash`` runs between the crash and the
        restart (exceptions in it are logged, never fatal); ``should_stop``
        adds loop-private stop conditions (ElasticPool worker events) on
        top of the global ``stop_event``.
        """
        pol = self.policy(loop_class)

        def stopping() -> bool:
            return self.stop_event.is_set() or (
                should_stop is not None and should_stop())

        while not stopping():
            try:
                fn(*args)
                return                              # clean exit
            except BaseException as e:  # noqa: BLE001 — supervision boundary
                rec = self._record_crash(name, loop_class, e)
                log.warning("supervised loop %r (%s) crashed: %r",
                            name, loop_class, e, exc_info=True)
                if on_crash is not None:
                    try:
                        on_crash(e)
                    except BaseException as ce:  # noqa: BLE001
                        log.error("on_crash cleanup for %r failed: %r",
                                  name, ce)
                n_window = self._crashes_in_window(name)
                if n_window >= pol.max_crashes:
                    if self.monitor is not None:
                        self.monitor.incr("supervisor.escalations")
                    self.escalate(
                        name,
                        f"crashed {n_window} times within "
                        f"{pol.crash_window_s}s (last: {rec.error}) — "
                        f"exceeds FailurePolicy.max_crashes={pol.max_crashes}")
                    return
                if stopping():
                    return
                with self._lock:
                    self._restarts[name] = self._restarts.get(name, 0) + 1
                if self.monitor is not None:
                    self.monitor.incr("runtime.thread_restarts")
                delay = self.backoff_delay(pol, n_window - 1,
                                           base=pol.restart_backoff_s)
                log.info("restarting %r in %.3fs (crash %d/%d in window)",
                         name, delay, n_window, pol.max_crashes)
                self.stop_event.wait(delay)

    def spawn(self, name: str, loop_class: str, fn: Callable, *args,
              **kw) -> threading.Thread:
        """Convenience: a daemon thread running ``run(...)``."""
        t = threading.Thread(
            target=self.run, args=(name, loop_class, fn) + args, kwargs=kw,
            name=name, daemon=True)
        t.start()
        return t


def policies_from_config(cfg) -> Dict[str, FailurePolicy]:
    """Map ``PALRunConfig`` knobs onto per-loop-class policies.  With
    ``supervise=False`` every class gets ``max_crashes=1`` — the first
    crash escalates, reproducing the seed's fail-stop behavior through
    the same code path."""
    supervise = getattr(cfg, "supervise", True)
    base = dict(
        task_retries=int(getattr(cfg, "oracle_task_retries", 2)),
        task_backoff_s=float(getattr(cfg, "oracle_task_backoff_s", 0.05)),
        max_crashes=(int(getattr(cfg, "loop_max_crashes", 3))
                     if supervise else 1),
        crash_window_s=float(getattr(cfg, "loop_crash_window_s", 30.0)),
        restart_backoff_s=float(getattr(cfg, "loop_restart_backoff_s", 0.1)),
    )
    pol = FailurePolicy(**base)
    return {"default": pol, "oracle": pol, "trainer": pol,
            "exchange": pol, "manager": pol, "generator": pol,
            "prediction": pol}
