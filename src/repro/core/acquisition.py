"""Unified device-resident acquisition engine — ONE UQ path from the
exchange hot loop to the Manager's oracle re-prioritization.

The paper's promise is a modular controller where uncertainty estimation,
selection, and oracle re-prioritization are user-swappable without giving up
parallel throughput.  This module is that contract:

  * ``UQResult``  — everything the controller ever needs from a committee
    evaluation: mean, scalar (max-over-components) std, mean-over-components
    std, and the final selection mask.  Nothing larger ever crosses to host.
  * ``UQEngine``  — the one interface: ``score(inputs) -> UQResult``.
  * Backends     — ``FusedEngine`` (vmapped committee forward fused with the
    ``committee_uq`` kernel, impl='pallas'|'pallas_interpret'|'xla', one
    device dispatch per exchange iteration, shape-bucketed jit cache) and
    ``LegacyEngine`` (per-member ``UserModel.predict`` for arbitrary user
    kernels, float64 host statistics — the paper's original structure).
  * Rules        — composable selection logic (``ThresholdRule``,
    ``TopFractionRule``, ``DiversityRule``) written in jnp.  The fused
    backend traces them INSIDE its compiled dispatch, so custom selection
    runs device-side and never forfeits fusion; the legacy backend executes
    the very same functions eagerly on host statistics, so both backends
    select identically by construction.  Rules may be STATEFUL
    (``stateful = True`` + ``init_state`` / ``apply_stateful``): their
    small carried state is threaded through the compiled dispatch and
    stays device-resident across rounds — ``core/budget.py`` builds the
    cross-round oracle-rate controller (``BudgetRule``) and the rolling
    re-weighting rule (``RollingReweightRule``) on this protocol.
  * ``make_engine`` — config-driven factory (``PALRunConfig.uq_impl`` /
    ``uq_block_n`` / ``uq_bucket``, plus the ``oracle_budget`` /
    ``budget_horizon`` / ``reweight_*`` budget knobs): the runtime never
    hand-threads engines.

The pre-engine escape hatches (``prediction_check=`` host callables,
manual ``fused_engine=`` threading, ``predict_stacked`` host round trips)
are gone: every scenario — examples, benchmarks, the Manager's
``dynamic_oracle_list`` — consumes ``UQResult`` from the same hot path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import re
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

log = logging.getLogger(__name__)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.committee import (
    committee_size, make_committee_apply, member, shape_bucket, stack_members,
    update,
)


# ---------------------------------------------------------------------------
# Results and statistics
# ---------------------------------------------------------------------------

# Scoring-stream tags: every ``UQEngine.score`` round is attributed to the
# traffic stream that produced it — the exchange hot loop or the serving
# path.  The tag enters the fused dispatch as a TRACED scalar (part of
# ``UQStats``), so stream-aware rules (``core/budget.BudgetRule`` with a
# distinct ``target_serve``) meter both streams through ONE compiled
# program per shape bucket instead of doubling the trace cache.
STREAM_EXCHANGE = 0
STREAM_SERVE = 1


@dataclasses.dataclass
class UQResult:
    """Host-side outcome of one committee scoring round (all (n,)-shaped or
    (n, d)-shaped numpy arrays, n = true number of inputs scored).

    ``scalar_std``    max over output components of the ddof=1 committee std
                      — the quantity the paper's ``prediction_check``
                      thresholds.
    ``component_std`` mean over output components of the same std — the
                      ranking score of ``adjust_input_for_oracle``
                      (``dynamic_oracle_list``), emitted in the same Welford
                      pass so the Manager never recomputes statistics from a
                      ``(K, n, d)`` host tensor.
    ``mask``          final selection decision after the rule pipeline.
    ``finite_members`` per-row count of committee members whose outputs
                      were finite (int32).  Members with any non-finite
                      component are quarantined out of the statistics
                      inside the same fused pass (degraded-K mean/std),
                      so ``finite_members < K`` is the degradation signal
                      for monitoring/serving health.  None on paths that
                      predate quarantine (direct constructors).
    """

    mean: np.ndarray            # (n, d)
    scalar_std: np.ndarray      # (n,)
    component_std: np.ndarray   # (n,)
    mask: np.ndarray            # (n,) bool
    finite_members: Optional[np.ndarray] = None   # (n,) int32


@dataclasses.dataclass
class FusedStepOut:
    """Host-side outcome of one ``FusedEngine.score_after`` round — the
    fused walker-advance + scoring dispatch used by the exploration fleet
    (``exploration/fleet.py``).

    Unlike ``UQResult``, the per-row statistics stay DEVICE-resident
    (``mask``/``scalar_std``/... are jax arrays over the padded bucket):
    the exchange loop never needs them on host, and transferring them for
    N walkers every iteration would reintroduce exactly the per-row host
    traffic the fleet exists to remove.  The only host fields are
    ``n_selected`` (one int32 scalar) and ``selected`` — the selected
    rows, packed to the front of the bucket on device and sliced, so
    unselected walkers cost zero host bytes.
    """

    n_selected: int             # rows selected this round (host int)
    selected: np.ndarray        # (n_selected, d) host — the oracle candidates
    mask: Any                   # (nb,) bool, device
    mean: Any                   # (nb, d), device
    scalar_std: Any             # (nb,), device
    component_std: Any          # (nb,), device
    finite_members: Any         # (nb,) int32, device


@dataclasses.dataclass
class UQStats:
    """Per-round statistics handed to selection rules.

    Inside the fused dispatch every field is a traced jnp array over the
    PADDED bucket; on the legacy path they are host numpy arrays over the
    true n.  ``valid`` masks real rows (padding rows are never selectable);
    ``n_valid`` is the true input count (traced scalar on device, so
    fraction-of-n rules never force a retrace when n varies in a bucket).
    """

    x: Any                      # (nb, in_dim) the stacked proposal batch
    mean: Any                   # (nb, d)
    scalar_std: Any             # (nb,)
    component_std: Any          # (nb,)
    valid: Any                  # (nb,) bool
    n_valid: Any                # scalar int
    stream: Any = STREAM_EXCHANGE  # scalar int: STREAM_EXCHANGE | STREAM_SERVE
    finite_members: Any = None  # (nb,) int32 finite-member count (quarantine)


# ---------------------------------------------------------------------------
# Selection rules — jnp-traceable, so one definition serves both backends
# ---------------------------------------------------------------------------


class SelectionRule:
    """Composable selection logic: ``apply(stats, mask) -> mask``.

    Rules are folded in order over the incoming mask (initially every valid
    row).  Implementations must be pure jnp so the fused backend can trace
    them into its single compiled dispatch; the same code runs eagerly on
    host arrays for the legacy backend.  Set ``needs_inputs`` when the rule
    reads ``stats.x`` — the legacy backend only stacks the input batch
    (which the fused path gets for free) for rules that declare it.

    STATEFUL rules (``stateful = True``) carry a small jax-pytree state
    across scoring rounds — the cross-round budget controller and the
    rolling re-weighting rule in ``core/budget.py``.  They implement
    ``init_state()`` and ``apply_stateful(stats, mask, state) ->
    (stats, mask, new_state)`` instead of ``apply``; returning ``stats``
    lets a rule transform the statistics downstream rules consume (e.g.
    re-weighted scores) without touching the raw ``UQResult`` the engine
    reports.  On the fused backend the state is an input/output of the
    compiled dispatch and stays device-resident between rounds; the engine
    snapshots it to host only for checkpoints (``UQEngine.state_dict``).
    """

    needs_inputs: bool = False
    stateful: bool = False

    def apply(self, stats: UQStats, mask: jnp.ndarray) -> jnp.ndarray:
        raise NotImplementedError

    def init_state(self) -> Any:
        """Initial carried state (stateful rules only): a jax pytree of
        small arrays/scalars."""
        raise NotImplementedError

    def apply_stateful(self, stats: UQStats, mask: jnp.ndarray,
                       state: Any) -> Tuple[UQStats, jnp.ndarray, Any]:
        """Stateful fold step: ``(stats, mask, state) -> (stats', mask',
        state')`` in pure jnp (traced into the fused dispatch)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class ThresholdRule(SelectionRule):
    """The paper's central check: select where scalar_std > threshold.

    Compares in the statistics' native dtype — float32 on the fused device
    path, float64 on the legacy host path (the seed ``prediction_check``
    semantics; forcing a jnp cast here would silently downgrade the legacy
    backend's near-threshold decisions to fp32)."""

    threshold: float

    def apply(self, stats: UQStats, mask):
        return mask & (stats.scalar_std > self.threshold)


@dataclasses.dataclass(frozen=True)
class TopFractionRule(SelectionRule):
    """Keep exactly the top ``round(fraction * n_valid)`` most-uncertain
    candidates (by scalar_std) among those still masked — the device-side
    equivalent of ``selection.top_fraction``.  Caps oracle traffic at a
    fixed fraction of the generator pool regardless of how noisy the
    committee currently is.  Rank-based, so exact ties (e.g. duplicate
    proposals from patience-restarted generators) never push the selection
    over the cap; tied ranks break toward the lower index.
    """

    fraction: float

    def apply(self, stats: UQStats, mask):
        # k must equal the host's int(round(n * fraction)) EXACTLY — fp32
        # arithmetic on the device cannot reproduce float64 rounding for
        # arbitrary (n, fraction) (e.g. 45*0.7: fp32 lands on 31.5 -> 32,
        # float64 on 31.499999999999996 -> 31).  fraction is static and
        # n_valid is bounded by the (static) bucket size, so the exact k
        # for every possible n is precomputed host-side at trace time and
        # the traced n_valid just indexes the table.
        n = int(mask.shape[0])
        k_table = jnp.asarray(
            [int(round(m * self.fraction)) for m in range(n + 1)],
            jnp.int32)
        k = k_table[jnp.clip(stats.n_valid, 0, n)]
        score = jnp.where(mask, stats.scalar_std, -jnp.inf)
        order = jnp.argsort(-score)            # stable: ties by lower index
        rank = jnp.zeros(n, jnp.int32).at[order].set(
            jnp.arange(n, dtype=jnp.int32))
        return mask & (rank < k)


@dataclasses.dataclass(frozen=True)
class DiversityRule(SelectionRule):
    """Greedy de-duplication in input space (paper §3.1: avoid redundant
    oracle calculations): visit masked candidates in descending-uncertainty
    order and keep one only if no already-kept candidate lies closer than
    ``min_dist`` — ``selection.diversity_filter`` compiled into the
    dispatch (the O(n^2) distance matrix lives on device; n is the bucket).
    """

    min_dist: float
    needs_inputs = True

    def apply(self, stats: UQStats, mask):
        x = jnp.asarray(stats.x, jnp.float32)
        mask = jnp.asarray(mask)
        n = x.shape[0]
        md2 = jnp.float32(self.min_dist) ** 2
        order = jnp.argsort(
            jnp.where(mask, -jnp.asarray(stats.scalar_std), jnp.inf))

        # distances per candidate row inside the loop, via direct
        # differences — NOT the Gram identity (||a||^2+||b||^2-2ab cancels
        # catastrophically in fp32 for large-norm inputs; the host
        # diversity_filter needs a float64 boundary recompute for exactly
        # this reason) and NOT a precomputed (n, n, in_dim) difference
        # tensor (the Manager scores whole oracle buffers through the same
        # engine, where that intermediate would be GBs); O(n*d) memory,
        # same O(n^2*d) work.
        def body(t, kept):
            i = order[t]
            di = jnp.sum((x - x[i]) ** 2, axis=-1)
            ok = mask[i] & ~jnp.any(kept & (di < md2))
            return kept.at[i].set(ok)

        return jax.lax.fori_loop(0, n, body, jnp.zeros(n, bool))


def default_rules(threshold: float) -> Tuple[SelectionRule, ...]:
    return (ThresholdRule(threshold),)


# ---------------------------------------------------------------------------
# Engine protocol
# ---------------------------------------------------------------------------


class UQEngine:
    """One interface for committee scoring.  ``score`` is the ONLY call the
    controller makes on the hot path; ``refresh_from`` pulls fresh weights
    from a WeightStore (no-op for backends whose members refresh
    themselves); ``uses_models`` tells the PredictionPool whether the
    per-member ``UserModel`` instances are part of this engine's path.

    ``rule_state`` carries the state of stateful rules (``BudgetRule``,
    ``RollingReweightRule``) across rounds — one pytree per stateful rule,
    in pipeline order.  ``score(..., advance=False)`` evaluates the
    pipeline against the current state WITHOUT advancing it: the Manager's
    ``dynamic_oracle_list`` re-scoring and read-only serving traffic use
    this so they never consume exchange-round budget.  ``state_dict`` /
    ``load_state_dict`` snapshot the carried state to host numpy for
    ``PAL.checkpoint`` and restore it on resume."""

    uses_models: bool = False
    rule_state: Tuple[Any, ...] = ()

    def score(self, list_data: Sequence[np.ndarray], *,
              advance: bool = True,
              stream: int = STREAM_EXCHANGE) -> UQResult:
        raise NotImplementedError

    def refresh_from(self, store) -> int:
        return 0

    def _init_rule_state(self):
        """Shared stateful-rule plumbing: one state pytree per stateful
        rule (pipeline order) plus the lock that makes an ADVANCING
        round's read-state -> score -> store-state cycle atomic."""
        self.rule_state = tuple(r.init_state() for r in self.rules
                                if r.stateful)
        self._state_lock = threading.Lock()

    def _state_guard(self, advance: bool):
        """Lock held by advancing scorers (exchange loop, serving with
        advance=True): without it, concurrent rounds would both update
        from the same base state and the second store would silently drop
        the first round's controller/re-weighting update.  advance=False
        scorers (Manager re-scoring) stay lock-free — they only snapshot
        the state tuple."""
        if advance and self.rule_state:
            return self._state_lock
        return contextlib.nullcontext()

    def state_dict(self) -> Tuple[Any, ...]:
        """Host-numpy snapshot of the carried cross-round rule state."""
        return jax.tree.map(np.asarray, tuple(self.rule_state))

    def load_state_dict(self, state: Sequence[Any]):
        """Restore a ``state_dict`` snapshot — if it structurally matches
        the CURRENT rule pipeline.  A snapshot taken under a different
        budget/re-weighting configuration (different rule count, state
        keys, or array shapes) is skipped with a warning and the freshly
        initialized state is kept: the controller re-converges instead of
        crashing at trace time inside the fused dispatch."""
        restored = jax.tree.map(jnp.asarray, tuple(state))
        cur_leaves, cur_def = jax.tree.flatten(tuple(self.rule_state))
        new_leaves, new_def = jax.tree.flatten(restored)
        if cur_def != new_def or any(
                np.shape(a) != np.shape(b)
                for a, b in zip(cur_leaves, new_leaves)):
            log.warning(
                "engine rule-state snapshot does not match the current "
                "rule pipeline (%s vs %s) — skipping restore, carried "
                "acquisition state re-converges from scratch",
                new_def, cur_def)
            return
        self.rule_state = restored


class FusedEngine(UQEngine):
    """Single-dispatch committee inference + UQ + device-side selection.

    One exchange iteration is ONE compiled device program: the vmapped
    committee forward, the ``ops.committee_uq`` statistics (streaming
    Welford over the K axis: mean / max-component std / mean-component std /
    threshold mask), and the rule pipeline all trace into the same jit.
    Only ``(mean, scalar_std, component_std, mask)`` cross back to host —
    the ``(K, n, d)`` prediction tensor never leaves the device, regardless
    of which rules are installed.

    Varying generator counts are padded to power-of-two shape buckets so a
    run with fluctuating ``n_gen`` compiles at most once per bucket
    (``trace_counts`` records tracings per bucket; tests assert <= 1); the
    true count enters the program as a traced scalar, so fraction-of-n rules
    don't retrace either.  The padded input batch is donated to the compiled
    program where the backend supports aliasing.

    ``apply_fn(params, x)`` must map a single member's params over a batch
    ``x: (n, in_dim) -> (n, out_dim)``.

    MESH-PARALLEL PATH (``mesh=``): the same single compiled dispatch, laid
    out over a device mesh.  The stacked committee parameters are placed
    over the mesh via the ``COMMITTEE`` logical-axis rules
    (``sharding/rules.py``: ``COMMITTEE -> ('model',)``, with the standard
    divisibility fallback — a K=4 committee on a 16-way model axis simply
    replicates), the padded request batch is sharded over the ``data`` axis
    (``BATCH`` rules), and the compiled program is constructed with
    ``jax.jit``'s ``in_shardings``/``out_shardings`` so the vmapped
    forward, the Welford UQ kernel, and the rule pipeline stay inside ONE
    dispatch — XLA inserts the collectives.  Carried rule state and the
    ``n_valid``/``stream`` scalars are replicated.  On the degenerate
    ``launch.mesh.make_host_mesh()`` (1x1) every sharding resolves to the
    single device and the program is the SAME computation as the
    unsharded path — bit-identical results (tested).
    """

    def __init__(self, apply_fn: Callable, cparams: Any, threshold: float,
                 *, rules: Optional[Sequence[SelectionRule]] = None,
                 impl: str = "xla", min_bucket: int = 8,
                 donate: bool = True, block_n: int = 128,
                 mesh=None, sharding_rules=None):
        from repro.kernels import ops as _ops

        self._ops = _ops
        self.apply = make_committee_apply(apply_fn)
        self.mesh = mesh
        self._mesh_rules = None
        self._x_shardings: Dict[int, Any] = {}
        if mesh is not None:
            from repro.sharding.rules import MeshRules, warn_fallbacks

            self._mesh_rules = MeshRules(mesh, sharding_rules)
            cparams = jax.device_put(
                cparams, self._cparams_shardings(cparams))
            # surface divisibility fallbacks (e.g. K=3 on an 8-way model
            # axis degrading to replicated) once, with the chosen layout
            self._fallback_mark = warn_fallbacks(
                self._mesh_rules, "FusedEngine")
        self.cparams = cparams
        self.threshold = float(threshold)
        self.rules = tuple(rules) if rules is not None \
            else default_rules(threshold)
        # carried state of stateful rules (budget controller, rolling
        # re-weighting), device-resident between rounds — an input/output
        # of the compiled dispatch, never a host round trip
        self._init_rule_state()
        self.rule_state = self._place_replicated(self.rule_state)
        self.impl = impl
        self.min_bucket = min_bucket
        self.donate = donate
        self.block_n = block_n
        self.version = -1                      # last WeightStore version seen
        self._cache: Dict[int, Callable] = {}
        self.trace_counts: Dict[int, int] = {}
        # score_after (fused step+score, exploration fleet) keeps its OWN
        # jit cache and trace counter: its programs are keyed by (caller
        # key, bucket) and must not perturb the plain score() cache whose
        # per-bucket trace counts tests assert exactly
        self._step_cache: Dict[Tuple[str, int], Callable] = {}
        self.step_trace_counts: Dict[Tuple[str, int], int] = {}
        self._step_warmed: set = set()
        # the Exchange and Manager threads score through the SAME engine:
        # the compile cache and traffic counters need a lock or two threads
        # hitting a fresh bucket would both trace it (duplicate multi-second
        # XLA compiles, trace_counts == 2) and lose counter increments
        self._compile_lock = threading.Lock()
        self._counter_lock = threading.Lock()
        self._warmed: set = set()
        # host<->device traffic accounting (benchmarks/committee_uq.py)
        self.bytes_to_device = 0
        self.bytes_to_host = 0
        # weight-refresh accounting (benchmarks/committee_train.py): the
        # WeightStore path round-trips packed 1-D arrays through host
        # memory; the device path (refresh_from_device) must stay at 0
        self.refresh_host_bytes = 0
        self.device_refreshes = 0
        # quarantine observability (PAL.report): finite-member count of the
        # most recent round's worst row, and how many rounds saw any member
        # quarantined at all
        self.last_finite_min: Optional[int] = None
        self.quarantine_rounds = 0

    @property
    def size(self) -> int:
        return committee_size(self.cparams)

    # ------------------------------------------------------------ sharding
    def _cparams_shardings(self, cparams):
        """NamedShardings laying the stacked committee over the mesh: the
        leading K axis follows the COMMITTEE logical-axis rules, every
        other dimension is replicated (per-member params are small; it is
        the K-way ensemble that scales out)."""
        from repro.sharding.rules import committee_shardings

        return committee_shardings(self._mesh_rules, cparams)

    def _batch_sharding(self, nb: int):
        """Request-batch sharding for one shape bucket: rows over the BATCH
        rules' mesh axes (divisibility fallback applies — an 8-row bucket
        on a 16-way data axis replicates), features replicated.  The spec
        depends only on the bucket size: the feature dim's logical axis is
        None (never mapped), so its concrete size is irrelevant — cached
        per nb alongside the jit cache."""
        from repro.configs import base as axes

        sh = self._x_shardings.get(nb)
        if sh is None:
            sh = self._mesh_rules.sharding(
                (axes.BATCH, None), (nb, 1), name="uq_batch")
            self._x_shardings[nb] = sh
        return sh

    def _place_replicated(self, tree):
        """Explicitly replicate a pytree over the mesh (no-op unsharded).

        Rule state and other small carried pytrees are created on the
        default device; at >= 2 devices, mixing a single-device-committed
        leaf into a mesh-sharded dispatch either fails to place or pays a
        reshard in the program prologue every round — placing once at
        init/restore keeps the hot loop transfer-free."""
        if self._mesh_rules is None:
            return tree
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        rep = NamedSharding(self._mesh_rules.mesh, P())
        return jax.tree.map(lambda a: jax.device_put(jnp.asarray(a), rep),
                            tree)

    def place_carry(self, carry, nb: int):
        """Lay a ``score_after`` carry out over the mesh: leaves whose
        leading dimension equals the padded bucket ``nb`` (per-walker
        state — positions, velocities, RNG keys, patience counters) shard
        rows over the BATCH mesh axes alongside the proposal batch;
        everything else replicates.  The exploration fleet calls this at
        construction and checkpoint restore so the fused step+score
        dispatch never resharding-copies the fleet each iteration.
        No-op without a mesh."""
        if self._mesh_rules is None:
            return carry
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        mesh = self._mesh_rules.mesh
        rep = NamedSharding(mesh, P())
        row_axes = self._batch_sharding(nb).spec[0] \
            if len(self._batch_sharding(nb).spec) else None

        def leaf(a):
            a = jnp.asarray(a)
            if a.ndim and int(a.shape[0]) == nb:
                spec = P(row_axes, *([None] * (a.ndim - 1)))
                return jax.device_put(a, NamedSharding(mesh, spec))
            return jax.device_put(a, rep)

        return jax.tree.map(leaf, carry)

    def _constrain_preds(self, preds, nb: int):
        """Pin the (K, nb, d) prediction tensor's in-program layout: K
        gathered (unsharded), rows kept on the batch sharding.

        The Welford committee-UQ reduction runs over K; leaving K sharded
        over 'model' makes XLA reduce local partials then all-reduce,
        changing the fp32 summation ORDER and costing 1-2 ULP vs the
        unsharded program.  Gathering K before the reduction restores the
        sequential order bit-for-bit.  Row reductions downstream (rule
        sums/maxes over selected rows) are integer/max arithmetic — exact
        under any row partitioning — so rows spread over EVERY free mesh
        axis ('data' AND 'model', greedy divisibility like rules.pspec):
        on a committee-axis mesh the gathered tensor's UQ work is then
        row-split across the devices instead of redundantly replicated.
        No-op without a mesh."""
        if self._mesh_rules is None:
            return preds
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        mesh = self._mesh_rules.mesh
        chosen, prod = [], 1
        for a in ("data", "model"):
            sz = mesh.shape.get(a, 1)
            if a in mesh.shape and nb % (prod * sz) == 0:
                chosen.append(a)
                prod *= sz
        row_axes = tuple(chosen) if chosen else None
        return jax.lax.with_sharding_constraint(
            preds, NamedSharding(mesh, P(None, row_axes, None)))

    def _jit_shardings(self, nb: int):
        """(in_shardings, out_shardings) for one bucket's compiled dispatch.
        Row-wise outputs inherit the batch's row partitioning; scalars and
        carried rule state are replicated."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        mesh = self._mesh_rules.mesh
        rep = NamedSharding(mesh, P())
        x_sh = self._batch_sharding(nb)
        row_axes = x_sh.spec[0] if len(x_sh.spec) else None
        vec_sh = NamedSharding(mesh, P(row_axes))
        mat_sh = NamedSharding(mesh, P(row_axes, None))
        state_sh = jax.tree.map(lambda _: rep, tuple(self.rule_state))
        cp_sh = self._cparams_shardings(self.cparams)
        in_sh = (cp_sh, x_sh, rep, rep, state_sh)
        out_sh = (mat_sh, vec_sh, vec_sh, vec_sh, vec_sh, state_sh)
        return in_sh, out_sh

    # ------------------------------------------------------------- compile
    def _compiled_locked(self, nb: int) -> Callable:
        # caller holds self._compile_lock
        fn = self._cache.get(nb)
        if fn is None:
            def fused(cparams, x, n_valid, stream, rstate):
                # trace-time counter: fires once per (bucket) compilation
                self.trace_counts[nb] = self.trace_counts.get(nb, 0) + 1
                preds = self._constrain_preds(self.apply(cparams, x), nb)
                mean, sstd, cstd, _, finite = self._ops.committee_uq(
                    preds, self.threshold, impl=self.impl,
                    block_n=self.block_n)
                valid = jnp.arange(nb) < n_valid
                stats = UQStats(x=x, mean=mean, scalar_std=sstd,
                                component_std=cstd, valid=valid,
                                n_valid=n_valid, stream=stream,
                                finite_members=finite)
                mask = valid
                new_state, si = [], 0
                for rule in self.rules:
                    if rule.stateful:
                        stats, mask, ns = rule.apply_stateful(
                            stats, mask, rstate[si])
                        mask = jnp.asarray(mask) & valid
                        new_state.append(ns)
                        si += 1
                    else:
                        mask = jnp.asarray(rule.apply(stats, mask)) & valid
                # quarantine floor: a row no finite member scored carries
                # no information — never selectable, whatever the rules say
                mask = mask & (finite > 0)
                return mean, sstd, cstd, mask, finite, tuple(new_state)
            # donation is a no-op (plus a warning) on CPU — only request it
            # where XLA can actually alias the buffer
            donate = self.donate and jax.default_backend() != "cpu"
            kw: Dict[str, Any] = {"donate_argnums": (1,)} if donate else {}
            if self._mesh_rules is not None:
                kw["in_shardings"], kw["out_shardings"] = \
                    self._jit_shardings(nb)
            fn = jax.jit(fused, **kw)
            self._cache[nb] = fn
        return fn

    def _pad_batch(self, list_data: Sequence[np.ndarray]):
        """Stack generator proposals into one padded (bucket, in_dim) batch.

        Pre-stacked 2-D input (serving microbatches, benchmark drivers)
        takes a vectorized path — one ``np.asarray`` + block copy instead
        of a per-row Python loop, which at mesh scale-out batch sizes
        (hundreds of rows per dispatch) otherwise dominates the host-side
        cost of ``score``."""
        if isinstance(list_data, np.ndarray):
            arr = list_data.astype(np.float32, copy=False)
        else:
            try:
                arr = np.asarray(list_data, dtype=np.float32)
            except ValueError:          # ragged rows: slow path below
                arr = np.empty(0, np.float32)
        if arr.ndim == 2:
            n = arr.shape[0]
            nb = shape_bucket(n, self.min_bucket)
            if nb == n:
                return np.ascontiguousarray(arr), n, nb
            x = np.zeros((nb, arr.shape[1]), np.float32)
            x[:n] = arr
            return x, n, nb
        # ragged / object input: normalize row by row
        rows = [np.asarray(x, dtype=np.float32).reshape(-1)
                for x in list_data]
        n = len(rows)
        nb = shape_bucket(n, self.min_bucket)
        x = np.zeros((nb, rows[0].size), np.float32)
        for i, r in enumerate(rows):
            x[i] = r
        return x, n, nb

    # -------------------------------------------------------------- score
    def _dispatch(self, nb: int, args):
        if nb in self._warmed:                 # steady state: lock-free call
            return self._cache[nb](*args)
        # first call per bucket traces lazily inside jit — hold the
        # lock across it so concurrent Exchange/Manager scoring can't
        # double-trace the same bucket
        with self._compile_lock:
            out = self._compiled_locked(nb)(*args)
            self._warmed.add(nb)
            return out

    def score(self, list_data: Sequence[np.ndarray], *,
              advance: bool = True,
              stream: int = STREAM_EXCHANGE) -> UQResult:
        x, n, nb = self._pad_batch(list_data)
        if self._mesh_rules is not None:
            xd = jax.device_put(x, self._batch_sharding(nb))
        else:
            xd = jnp.asarray(x)
        head = (self.cparams, xd, np.int32(n), np.int32(stream))
        # advancing rounds are semantically sequential (_state_guard); the
        # state itself advances on device — only the compiled program's
        # output handle moves, no host transfer
        with self._state_guard(advance):
            out = self._dispatch(nb, head + (self.rule_state,))
            if advance:
                self.rule_state = out[5]
        mean, sstd, cstd, mask, finite = (np.asarray(o) for o in out[:5])
        finite_n = finite[:n]
        with self._counter_lock:
            self.bytes_to_device += x.nbytes
            self.bytes_to_host += (mean.nbytes + sstd.nbytes + cstd.nbytes
                                   + mask.nbytes + finite.nbytes)
            if finite_n.size:
                self.last_finite_min = int(finite_n.min())
                if self.last_finite_min < self.size:
                    self.quarantine_rounds += 1
        return UQResult(mean[:n], sstd[:n], cstd[:n], mask[:n], finite_n)

    # ------------------------------------------------- fused step + score
    def _step_compiled_locked(self, ckey: str, nb: int, step_fn: Callable,
                              react_fn: Optional[Callable]) -> Callable:
        # caller holds self._compile_lock
        key = (ckey, nb)
        fn = self._step_cache.get(key)
        if fn is None:
            def fused(cparams, carry, n_valid, stream, rstate):
                self.step_trace_counts[key] = \
                    self.step_trace_counts.get(key, 0) + 1
                x, mid = step_fn(carry)
                preds = self._constrain_preds(self.apply(cparams, x), nb)
                mean, sstd, cstd, _, finite = self._ops.committee_uq(
                    preds, self.threshold, impl=self.impl,
                    block_n=self.block_n)
                valid = jnp.arange(nb) < n_valid
                stats = UQStats(x=x, mean=mean, scalar_std=sstd,
                                component_std=cstd, valid=valid,
                                n_valid=n_valid, stream=stream,
                                finite_members=finite)
                mask = valid
                new_state, si = [], 0
                for rule in self.rules:
                    if rule.stateful:
                        stats, mask, ns = rule.apply_stateful(
                            stats, mask, rstate[si])
                        mask = jnp.asarray(mask) & valid
                        new_state.append(ns)
                        si += 1
                    else:
                        mask = jnp.asarray(rule.apply(stats, mask)) & valid
                mask = mask & (finite > 0)
                new_carry = react_fn(mid, stats, mask) \
                    if react_fn is not None else mid
                # pack selected rows to the front (stable order) so the
                # host can slice exactly n_selected rows off the device —
                # unselected walkers never cross the boundary
                order = jnp.argsort(~mask)
                sel_x = jnp.take(x, order, axis=0)
                n_sel = jnp.sum(mask).astype(jnp.int32)
                return (new_carry, mean, sstd, cstd, mask, finite,
                        n_sel, sel_x, tuple(new_state))
            donate = self.donate and jax.default_backend() != "cpu"
            kw: Dict[str, Any] = {"donate_argnums": (1,)} if donate else {}
            fn = jax.jit(fused, **kw)
            self._step_cache[key] = fn
        return fn

    def score_after(self, step_fn: Callable, carry: Any, n: int, nb: int,
                    *, react_fn: Optional[Callable] = None,
                    cache_key: str = "step", advance: bool = True,
                    stream: int = STREAM_EXCHANGE
                    ) -> Tuple[Any, FusedStepOut]:
        """Fuse a caller-supplied advance step with committee scoring:
        ``step_fn(carry) -> (x, mid)`` produces the (nb, in_dim) proposal
        batch INSIDE the compiled dispatch, then the committee forward,
        the ``committee_uq`` Welford statistics, and the selection-rule
        pipeline run exactly as in :meth:`score`, and finally
        ``react_fn(mid, stats, mask) -> new_carry`` (e.g. the fleet's
        patience/restart update) folds the round's outcome back into the
        carried state — one device program per (cache_key, bucket).

        ``carry`` is a device-resident pytree the caller owns (the fleet's
        stacked walker state); it never crosses to host.  ``n`` is the
        true row count, ``nb`` the padded bucket (the caller pads once at
        construction, so the hot loop has zero uploads).  Host traffic per
        call is the int32 selected count plus the selected rows only.

        Stateful-rule state is shared with :meth:`score` — both entry
        points thread ``self.rule_state`` under the same ``_state_guard``,
        so a budget controller meters fleet and host traffic jointly.
        """
        key = (cache_key, nb)
        with self._state_guard(advance):
            args = (self.cparams, carry, np.int32(n), np.int32(stream),
                    self.rule_state)
            if key in self._step_warmed:
                out = self._step_cache[key](*args)
            else:
                with self._compile_lock:
                    out = self._step_compiled_locked(
                        cache_key, nb, step_fn, react_fn)(*args)
                    self._step_warmed.add(key)
            if advance:
                self.rule_state = out[8]
        new_carry, mean, sstd, cstd, mask, finite, n_sel_d, sel_x = out[:8]
        n_sel = int(n_sel_d)                       # one int32 to host
        if n_sel:
            selected = np.asarray(sel_x[:n_sel])   # selected rows only
        else:
            selected = np.zeros((0,) + tuple(sel_x.shape[1:]), np.float32)
        with self._counter_lock:
            self.bytes_to_host += 4 + selected.nbytes
        return new_carry, FusedStepOut(
            n_selected=n_sel, selected=selected, mask=mask, mean=mean,
            scalar_std=sstd, component_std=cstd, finite_members=finite)

    # -------------------------------------------------------------- weights
    def refresh_from(self, store) -> int:
        """Refresh the stacked committee from a WeightStore if anything
        newer exists.  Prediction member i replicates training member
        ``i % store.n_members`` (paper: prediction models are replicas of
        training models), so the committee size K is preserved even when
        fewer trainers publish — shapes never change, so no retrace.
        Returns the number of refreshed committees (0 or 1)."""
        v = store.version()
        if v <= self.version:
            return 0
        K = self.size
        packs = [store.pull_packed(i % store.n_members) for i in range(K)]
        if any(p is None for p in packs):
            return 0              # not all trainers have published yet
        self.refresh_host_bytes += sum(p[0].nbytes for p in packs)
        members = [update(member(self.cparams, i), packs[i][0])
                   for i in range(K)]
        cparams = stack_members(members)
        if self._mesh_rules is not None:
            # fresh weights land replicated on the default device; put them
            # back on the committee layout so the next dispatch doesn't
            # reshard inside the compiled program's prologue every round
            cparams = jax.device_put(
                cparams, self._cparams_shardings(cparams))
        self.cparams = cparams
        self.version = v
        return 1

    def refresh_from_device(self, cparams) -> int:
        """Zero-copy weight handoff from the fused committee trainer: the
        refreshed STACKED pytree is re-placed on the committee layout
        directly (a device_put onto the mesh sharding when one is
        installed; a reference swap otherwise).  No packed 1-D host round
        trip — ``refresh_host_bytes`` stays untouched, which the
        benchmark/acceptance tests assert.  The caller must hand over a
        pytree it will not donate away (``CommitteeTrainer.
        snapshot_cparams``)."""
        k = committee_size(cparams)
        if k != self.size:
            raise ValueError(
                f"refresh_from_device: committee size changed ({k} vs "
                f"{self.size}) — shapes are baked into the jit cache")
        if self._mesh_rules is not None:
            cparams = jax.device_put(
                cparams, self._cparams_shardings(cparams))
        self.cparams = cparams
        self.device_refreshes += 1
        return 1

    # ------------------------------------------------------------ snapshot
    def load_state_dict(self, state: Sequence[Any]):
        """Restore carried rule state, then re-place it on the mesh: a
        checkpoint restores to host numpy -> default device, which at
        >= 2 devices would make every subsequent dispatch reshard the
        state in its prologue."""
        super().load_state_dict(state)
        self.rule_state = self._place_replicated(self.rule_state)


class LegacyEngine(UQEngine):
    """Per-member backend for arbitrary ``UserModel`` kernels (the paper's
    original per-process structure): K sequential ``model.predict`` calls
    (or a user ``predict_all_override``), float64 host statistics, then the
    SAME rule objects executed eagerly — so swapping a user model in never
    changes selection semantics, only throughput.

    Weight refresh stays with the PredictionPool (the models own their
    parameters), hence ``uses_models`` and a no-op ``refresh_from``.
    """

    uses_models = True

    def __init__(self, predict_all: Callable[[Sequence[np.ndarray]],
                                             np.ndarray],
                 threshold: float,
                 *, rules: Optional[Sequence[SelectionRule]] = None):
        self.predict_all = predict_all
        self.threshold = float(threshold)
        self.rules = tuple(rules) if rules is not None \
            else default_rules(threshold)
        self._init_rule_state()
        self.last_finite_min: Optional[int] = None
        self.quarantine_rounds = 0

    def score(self, list_data: Sequence[np.ndarray], *,
              advance: bool = True,
              stream: int = STREAM_EXCHANGE) -> UQResult:
        with self._state_guard(advance):
            return self._score(list_data, advance=advance, stream=stream)

    def _score(self, list_data: Sequence[np.ndarray], *,
               advance: bool, stream: int = STREAM_EXCHANGE) -> UQResult:
        preds = np.asarray(self.predict_all(list_data), dtype=np.float64)
        k = preds.shape[0]
        fin = np.isfinite(preds).all(axis=tuple(range(2, preds.ndim)))  # (K, n)
        cnt = fin.sum(axis=0).astype(np.int32)                          # (n,)
        if fin.all():
            # steady state: keep the exact historical float64 reductions
            mean = preds.mean(axis=0)
            std = preds.std(axis=0, ddof=1) if k > 1 \
                else np.zeros_like(preds[0])
        else:
            # degraded-K statistics over the finite members only — same
            # quarantine semantics as the fused kernels (ref.committee_uq_ref)
            w = fin.reshape(fin.shape + (1,) * (preds.ndim - 2))
            safe = np.maximum(cnt, 1).astype(np.float64)
            safe = safe.reshape((-1,) + (1,) * (preds.ndim - 2))
            mean = np.where(w, preds, 0.0).sum(axis=0) / safe
            dev = np.where(w, preds - mean, 0.0)
            var = (dev * dev).sum(axis=0) / np.maximum(
                cnt - 1, 1).reshape(safe.shape)
            var[cnt < 2] = 0.0
            std = np.sqrt(var)
        flat = std.reshape(std.shape[0], -1)
        sstd = flat.max(axis=-1)
        cstd = flat.mean(axis=-1)
        n = len(list_data)
        x = np.stack([np.asarray(r, np.float32).reshape(-1)
                      for r in list_data]) \
            if any(r.needs_inputs for r in self.rules) else None
        stats = UQStats(
            x=x, mean=mean, scalar_std=sstd, component_std=cstd,
            valid=np.ones(n, bool), n_valid=n, stream=stream,
            finite_members=cnt)
        mask = np.ones(n, bool)
        states, si = list(self.rule_state), 0
        for rule in self.rules:
            if rule.stateful:
                # the SAME jnp code the fused backend traces, run eagerly
                stats, mask, states[si] = rule.apply_stateful(
                    stats, mask, states[si])
                mask = np.asarray(mask, dtype=bool)
                si += 1
            else:
                mask = np.asarray(rule.apply(stats, mask), dtype=bool)
        mask = mask & (cnt > 0)
        if advance:
            self.rule_state = tuple(states)
        if cnt.size:
            self.last_finite_min = int(cnt.min())
            if self.last_finite_min < k:
                self.quarantine_rounds += 1
        return UQResult(mean, sstd, cstd, mask, cnt)


# ---------------------------------------------------------------------------
# Config-driven construction
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CommitteeSpec:
    """What the fused backends need from the user: a single-member batch
    apply ``apply_fn(params, x: (n, in_dim)) -> (n, out_dim)`` plus the
    stacked committee parameters (leading K axis, ``committee.stack_members``).
    """

    apply_fn: Callable
    cparams: Any


def wants_legacy(run_cfg, committee: Optional[CommitteeSpec],
                 force_legacy: bool = False) -> bool:
    """Whether ``make_engine`` will build the per-member legacy backend for
    this configuration — i.e. whether per-member prediction ``UserModel``
    instances are actually needed (the runtime skips constructing them
    otherwise)."""
    impl = getattr(run_cfg, "uq_impl", "auto")
    return force_legacy or impl == "legacy" or (impl == "auto"
                                                and committee is None)


def resolve_mesh(run_cfg):
    """``PALRunConfig.uq_mesh`` -> a concrete mesh (or None).

    ''  (default) — no mesh: single-device dispatch, today's path.
    'host'        — ``launch.mesh.make_host_mesh()``: the degenerate 1x1
                    ('data', 'model') mesh; same computation, sharded
                    construction exercised (CI parity).
    'scaleout'    — ``launch.mesh.make_scaleout_mesh()``: all visible
                    devices on the 'data' axis (committee replicated, rows
                    scale out) — the CI/emulated-device bring-up layout.
    'DxM'         — e.g. ``'4x2'``: an explicit ('data', 'model') grid
                    over the first D*M visible devices.
    'production'  — ``launch.mesh.make_production_mesh()``: the 16x16
                    ('data', 'model') pod mesh (committee over 'model',
                    request batch over 'data').

    Divisibility fallbacks (a committee/batch that does not divide the
    mapped axes) are NOT silent: ``FusedEngine``/``CommitteeTrainer`` log
    a WARNING with the chosen fallback layout at construction
    (``sharding.rules.warn_fallbacks``).
    """
    name = getattr(run_cfg, "uq_mesh", "") or ""
    if not name:
        return None
    from repro.launch import mesh as mesh_mod

    if name == "host":
        return mesh_mod.make_host_mesh()
    if name == "scaleout":
        return mesh_mod.make_scaleout_mesh()
    if name == "production":
        return mesh_mod.make_production_mesh()
    m = re.fullmatch(r"(\d+)x(\d+)", name)
    if m:
        return mesh_mod.make_scaleout_mesh(int(m.group(1)), int(m.group(2)))
    raise ValueError(f"uq_mesh={name!r}: expected '', 'host', 'scaleout', "
                     "'DxM' (e.g. '4x2') or 'production'")


def make_engine(
    run_cfg,
    *,
    committee: Optional[CommitteeSpec] = None,
    predict_all: Optional[Callable] = None,
    rules: Optional[Sequence[SelectionRule]] = None,
    force_legacy: bool = False,
    mesh=None,
    sharding_rules=None,
) -> UQEngine:
    """Build the acquisition engine from ``PALRunConfig`` knobs.

    ``uq_impl``:
      'auto'             — fused XLA backend when a ``CommitteeSpec`` is
                           given, per-member legacy otherwise
      'xla'              — fused single-dispatch, jnp reference statistics
      'pallas'           — fused single-dispatch, Pallas TPU kernel
      'pallas_interpret' — same kernel, interpret mode (CPU validation)
      'legacy'           — per-member ``UserModel.predict`` + host float64

    ``force_legacy`` overrides everything (used when a
    ``predict_all_override`` puts the user in control of raw predictions).

    ``mesh`` / ``sharding_rules`` select the mesh-parallel fused dispatch
    (committee over the ``model`` axis, request batch over ``data``); when
    ``mesh`` is None it is resolved from ``run_cfg.uq_mesh``
    (:func:`resolve_mesh`).  Meshes are a fused-backend feature — the
    legacy per-member path ignores them.

    When no explicit ``rules=`` are given, the pipeline comes from the
    config's budget knobs (``core/budget.rules_from_config``):
    ``oracle_budget > 0`` installs the cross-round oracle-rate controller
    (``BudgetRule``) in place of the static threshold rule, and
    ``reweight_buckets > 0`` prepends the rolling re-weighting rule.
    """
    impl = getattr(run_cfg, "uq_impl", "auto")
    threshold = run_cfg.std_threshold
    if rules is None:
        from repro.core import budget as _budget

        rules = _budget.rules_from_config(run_cfg)
    if wants_legacy(run_cfg, committee, force_legacy):
        if predict_all is None:
            raise ValueError(
                "legacy UQ backend needs a predict_all callable "
                "(no committee spec was provided)")
        return LegacyEngine(predict_all, threshold, rules=rules)
    if committee is None:
        raise ValueError(
            f"uq_impl={impl!r} is a fused backend and needs a CommitteeSpec "
            "(apply_fn + stacked cparams); pass committee=... to PAL or use "
            "uq_impl='legacy'")
    if mesh is None:
        mesh = resolve_mesh(run_cfg)
    return FusedEngine(
        committee.apply_fn, committee.cparams, threshold,
        rules=rules,
        impl=("xla" if impl == "auto" else impl),
        block_n=getattr(run_cfg, "uq_block_n", 128),
        min_bucket=getattr(run_cfg, "uq_bucket", 8),
        mesh=mesh,
        sharding_rules=sharding_rules,
    )
