"""Deterministic seeded fault injection for the PAL runtime.

Robustness that is not exercised is fiction: this module turns "what if an
oracle dies mid-campaign" into a REPRODUCIBLE test input.  A
:class:`FaultPlan` is a declarative schedule of :class:`FaultEvent`s —
"on the 3rd task oracle1 runs, raise"; "on the 2nd trainer round, crash
the loop"; "poison committee member 0" — executed by a
:class:`ChaosInjector` that the runtime consults at fixed instrumentation
sites.  Because events key on per-site call counts (not wall clock), the
same plan produces the same fault sequence on every run, which is what
lets tests/test_chaos.py assert exact recovery behavior and
benchmarks/fault_recovery.py measure throughput retention under a
STANDARD plan.

Instrumentation sites (rank = worker rank or channel name where noted):

  ``oracle.loop``     top of an oracle worker's recv loop (rank = worker)
  ``oracle.task``     before each ``oracle.run_calc`` (rank = worker);
                      a ``raise`` here is a TRANSIENT task failure — the
                      per-task retry path absorbs it
  ``oracle.label``    label corruption point (``nan_label`` events)
  ``trainer.loop``    once per trainer round, before ``train()``
  ``trainer.nan_member`` consumed by the runtime to call
                      ``CommitteeTrainer.poison_member(arg)``
  ``exchange.loop``   top of each exchange iteration
  ``fleet.step``      before each fused exploration-fleet step (``take``
                      site: ``nan_walker`` poisons walker ``int(arg)``,
                      which the fleet's restart gate must reset — never a
                      crash; generic kinds run via ``execute``)
  ``transport.send``  inside ``Channel.isend`` (rank = channel name);
                      installed process-wide via ``transport.install_chaos``

Event kinds:

  ``raise``   raise :class:`ChaosFault` (transient; retried where retries
              exist)
  ``crash``   raise :class:`ChaosCrash` (kills the enclosing loop — the
              supervisor's restart path is what absorbs it)
  ``delay``/``hang``  sleep ``arg`` seconds (``hang`` is the same sleep,
              named for plans that target the heartbeat/ledger timeout)
  ``nan_label``   corrupt the oracle label to NaN (``corrupt_label``)
  ``nan_member``  poison committee member ``int(arg)`` (``take`` site)
  ``nan_walker``  poison fleet walker ``int(arg)`` to NaN (``take`` site;
              the next fused step resets it to its trusted state)

Nothing here imports the runtime — the injector is a passive oracle the
runtime queries, so it is equally usable against a bare Manager or
ServingQueue in unit tests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Tuple

import numpy as np


class ChaosFault(RuntimeError):
    """Injected transient failure (absorbed by task-level retries)."""


class ChaosCrash(RuntimeError):
    """Injected loop-level crash (absorbed by supervised restarts)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: at the ``nth`` call of ``site`` (per rank,
    1-based), do ``kind``.  ``rank`` empty = first rank to reach ``nth``
    fires it (each event fires exactly once either way)."""

    site: str
    nth: int
    kind: str                    # raise | crash | delay | hang | nan_label | nan_member
    rank: str = ""
    arg: float = 0.0             # seconds (delay/hang) or member index (nan_member)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A deterministic schedule of faults; ``seed`` namespaces any future
    randomized extension (kept in the type so plans hash/compare whole)."""

    events: Tuple[FaultEvent, ...]
    seed: int = 0

    @staticmethod
    def acceptance(member: int = 0, fleet: bool = False) -> "FaultPlan":
        """The ISSUE-6 acceptance plan: 3 transient oracle failures, 1
        oracle-thread crash, 1 trainer crash mid-schedule, 1 NaN-weights
        member.  A supervised run absorbs ALL of it without a StopToken.

        ``fleet=True`` appends the exploration-fleet event (a poisoned
        walker on the 3rd fused step) for runs driving a ``WalkerFleet``
        — opt-in so plans against fleetless runs still fire completely."""
        events = [
            FaultEvent("oracle.task", 2, "raise", rank="oracle0"),
            FaultEvent("oracle.task", 4, "raise", rank="oracle1"),
            FaultEvent("oracle.task", 6, "raise", rank="oracle0"),
            FaultEvent("oracle.loop", 9, "crash", rank="oracle1"),
            FaultEvent("trainer.loop", 2, "crash"),
            FaultEvent("trainer.nan_member", 1, "nan_member", arg=member),
        ]
        if fleet:
            events.append(FaultEvent("fleet.step", 3, "nan_walker", arg=0.0))
        return FaultPlan(events=tuple(events))


class ChaosInjector:
    """Executes a :class:`FaultPlan` against per-(site, rank) call counters.

    Thread-safe: every kernel loop queries it concurrently.  ``fired``
    records ``(site, rank, event)`` tuples in firing order for test
    assertions; counters survive loop restarts (a restarted oracle keeps
    counting from where its predecessor died, so "nth call" means nth
    over the campaign, not per incarnation).
    """

    def __init__(self, plan: FaultPlan, monitor=None):
        self.plan = plan
        self.monitor = monitor
        self._lock = threading.Lock()
        self._counts: Dict[Tuple[str, str], int] = {}
        self._consumed: set = set()
        self.fired: List[Tuple[str, str, FaultEvent]] = []

    # ------------------------------------------------------------ matching
    def _match(self, site: str, rank: str) -> Optional[FaultEvent]:
        with self._lock:
            key = (site, rank)
            n = self._counts.get(key, 0) + 1
            self._counts[key] = n
            for i, ev in enumerate(self.plan.events):
                if i in self._consumed or ev.site != site or ev.nth != n:
                    continue
                if ev.rank and ev.rank != rank:
                    continue
                self._consumed.add(i)
                self.fired.append((site, rank, ev))
                if self.monitor is not None:
                    self.monitor.incr(f"chaos.{ev.kind}")
                return ev
        return None

    # ----------------------------------------------------------------- API
    def check(self, site: str, rank: str = ""):
        """Counter tick + fault execution for raise/crash/delay/hang sites.
        Call it INSIDE the try-scope whose recovery path should absorb the
        fault."""
        ev = self._match(site, rank)
        if ev is not None:
            self.execute(ev, rank=rank)

    def execute(self, ev: FaultEvent, rank: str = ""):
        """Run a matched event's generic effect (raise/crash/delay/hang).
        Public so ``take`` sites — whose special kinds the caller realizes
        itself (``nan_member``, ``nan_walker``) — can still honor generic
        kinds without ticking the counter twice."""
        if ev.kind in ("delay", "hang"):
            time.sleep(float(ev.arg))
        elif ev.kind == "raise":
            raise ChaosFault(f"injected transient fault at {ev.site}"
                             f"{f' ({rank})' if rank else ''} n={ev.nth}")
        elif ev.kind == "crash":
            raise ChaosCrash(f"injected crash at {ev.site}"
                             f"{f' ({rank})' if rank else ''} n={ev.nth}")

    def corrupt_label(self, label, rank: str = ""):
        """``oracle.label`` site: returns the label, NaN-filled when a
        ``nan_label`` event fires (the Manager's finite check must catch
        it and requeue the task)."""
        ev = self._match("oracle.label", rank)
        if ev is None or ev.kind != "nan_label":
            return label
        bad = np.array(label, dtype=np.float32, copy=True)
        bad[...] = np.nan
        return bad

    def take(self, site: str, rank: str = "") -> Optional[FaultEvent]:
        """Counter tick returning the matched event (or None) instead of
        executing it — for events the RUNTIME performs (``nan_member``)."""
        return self._match(site, rank)

    def summary(self) -> List[str]:
        with self._lock:
            return [f"{s}:{r or '*'}:{e.kind}@{e.nth}" for s, r, e in self.fired]
