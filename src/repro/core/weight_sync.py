"""Versioned weight publication: training kernel -> prediction kernel
(paper §2.1/§2.4: "trained model weights are periodically copied directly to
the prediction kernel").

The paper packs weights as 1-D arrays over MPI; here a ``WeightStore`` holds
the latest packed weights per committee member with a monotonically
increasing version, and the prediction side pulls at its own cadence — the
same *periodic, versioned, non-blocking* semantics without a rendezvous.

NOTE: on the fused-training path (``training/committee_trainer.py``) the
store is DEMOTED to the checkpoint wire format and the legacy per-member
backend: the committee trainer hands its stacked params to the acquisition
engine device-to-device (``FusedEngine.refresh_from_device`` — a
``jax.device_put`` onto the committee mesh layout, zero packed host
bytes), so the steady-state trainer->prediction hop never packs at all.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

from repro.core import committee as cmte


class WeightStore:
    """Thread-safe latest-wins store of packed member weights.

    Publishes write into a pair of preallocated ping-pong buffers per member
    (allocated once at first publish), so the steady-state publish path does
    zero heap allocation — no per-round ``np.concatenate`` (paper's
    ``get_weight``) and no retention of caller arrays.  The packer always
    writes the buffer that is NOT currently stored, and readers only touch
    stored buffers under the lock (``pull_packed``/``pull_all`` hand out
    copies), so no reader can observe a torn write.  One publisher per
    member (the paper's structure: trainer i owns member i) — concurrent
    publishes to the *same* member would race the buffer flip.
    """

    def __init__(self, n_members: int):
        self.n_members = n_members
        self._weights: Dict[int, np.ndarray] = {}
        self._versions: Dict[int, int] = {i: 0 for i in range(n_members)}
        self._lock = threading.Lock()
        self._global_version = 0
        self.publishes = 0
        self.last_publish_time: Optional[float] = None
        self._pack_bufs: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._pack_flip: Dict[int, int] = {}

    def _acquire_pack_buffer(self, member: int, size: int) -> np.ndarray:
        """Next ping-pong buffer for ``member`` — by construction never the
        currently-stored one, so packing outside the lock is safe."""
        pair = self._pack_bufs.get(member)
        if pair is None or pair[0].size != size:
            pair = (np.empty(size, np.float32), np.empty(size, np.float32))
            self._pack_bufs[member] = pair
            self._pack_flip[member] = 0
        i = self._pack_flip[member]
        self._pack_flip[member] = 1 - i
        return pair[i]

    # -- training side ------------------------------------------------------
    def publish(self, member: int, params: Any) -> int:
        """Pack and store member weights; returns the new global version."""
        size = cmte.get_weight_size(params)
        buf = self._acquire_pack_buffer(member, size)
        packed = cmte.get_weight(params, out=buf)
        with self._lock:
            self._weights[member] = packed
            self._global_version += 1
            self._versions[member] = self._global_version
            self.publishes += 1
            self.last_publish_time = time.time()
            return self._global_version

    def publish_packed(self, member: int, packed: np.ndarray) -> int:
        """Store already-packed 1-D weights (paper's get_weight output).
        Copied into the store's own buffer so callers may reuse theirs."""
        packed = np.asarray(packed)
        buf = self._acquire_pack_buffer(member, packed.size)
        np.copyto(buf, packed.astype(np.float32, copy=False))
        with self._lock:
            self._weights[member] = buf
            self._global_version += 1
            self._versions[member] = self._global_version
            self.publishes += 1
            self.last_publish_time = time.time()
            return self._global_version

    # -- prediction side ----------------------------------------------------
    def pull_packed(self, member: int, newer_than: int = -1
                    ) -> Optional[Tuple[np.ndarray, int]]:
        """Packed weights (a copy, safe to hold) if a newer version exists,
        else None.  The copy is made under the lock; version gating keeps
        this off the steady-state exchange path."""
        with self._lock:
            v = self._versions[member]
            if v <= newer_than or member not in self._weights:
                return None
            return self._weights[member].copy(), v

    def version(self, member: Optional[int] = None) -> int:
        with self._lock:
            if member is None:
                return self._global_version
            return self._versions[member]

    def pull(self, member: int, params_like: Any,
             newer_than: int = -1) -> Optional[Tuple[Any, int]]:
        """Unpack the stored weights into ``params_like`` structure if a
        version newer than ``newer_than`` exists; else None."""
        with self._lock:
            v = self._versions[member]
            if v <= newer_than or member not in self._weights:
                return None
            packed = self._weights[member].copy()
        return cmte.update(params_like, packed), v

    def pull_all(self, cparams_like: Any, newer_than: int = -1):
        """Refresh every member of a stacked committee tree.  Returns
        (new_cparams or None, version)."""
        import jax

        with self._lock:
            v = self._global_version
            if v <= newer_than or len(self._weights) < self.n_members:
                return None, v
            packed = {i: w.copy() for i, w in self._weights.items()}
        members = [
            cmte.update(cmte.member(cparams_like, i), packed[i])
            for i in range(self.n_members)
        ]
        return cmte.stack_members(members), v


class WeightSyncPolicy:
    """When should training publish? (paper: every N epochs / retrains)."""

    def __init__(self, every_n_rounds: int = 1):
        self.every = max(1, every_n_rounds)
        self._rounds = 0

    def should_publish(self) -> bool:
        self._rounds += 1
        return self._rounds % self.every == 0
