"""The paper's analytic speedup model (SI S2, Eqs. 1–13).

T_serial   = (N/P) * t_oracle + t_train + t_gen                      (Eq. 1)
T_parallel = max((N/P) * t_oracle, t_train, t_gen)                   (Eq. 2)
S          = T_serial / T_parallel                                   (Eq. 3/4)

Regimes validated in tests/benchmarks:
* balanced oracle/train, N >= P:  S -> 1 + P/N  (Eq. 7)
* training-bound:                 S -> 1        (Eq. 10)
* all-balanced, P = N:            S -> 3        (Eq. 13)

The model is a LOWER bound: in PAL the non-bottleneck kernels keep working
(more epochs, more exploration) instead of idling.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class WorkloadParams:
    t_oracle: float      # time to label one sample
    t_train: float       # one training round
    t_gen: float         # one generation/prediction round (1000 steps in SI)
    n_samples: int       # N: samples labeled per AL iteration
    n_workers: int       # P: parallel oracle workers (P <= N assumed)

    def __post_init__(self):
        if self.n_workers > self.n_samples:
            raise ValueError("model assumes P <= N (paper SI S2.1)")


def t_serial(w: WorkloadParams) -> float:
    return (w.n_samples / w.n_workers) * w.t_oracle + w.t_train + w.t_gen


def t_parallel(w: WorkloadParams) -> float:
    return max((w.n_samples / w.n_workers) * w.t_oracle, w.t_train, w.t_gen)


def speedup(w: WorkloadParams) -> float:
    return t_serial(w) / t_parallel(w)


def bottleneck(w: WorkloadParams) -> str:
    terms = {
        "oracle": (w.n_samples / w.n_workers) * w.t_oracle,
        "train": w.t_train,
        "gen": w.t_gen,
    }
    return max(terms, key=terms.get)


# --------------------------------------------------------------------------
# The three SI use cases
# --------------------------------------------------------------------------

USE_CASES: Dict[str, WorkloadParams] = {
    # Use Case 1: DFT + GNN (t_oracle = t_train = 1 h, t_gen << 1 h), P = N
    "dft_gnn": WorkloadParams(t_oracle=3600.0, t_train=3600.0, t_gen=36.0,
                              n_samples=16, n_workers=16),
    # Use Case 2: xTB oracle (10 s), GNN train 1 h, TS search 10 min
    "xtb_reaction": WorkloadParams(t_oracle=10.0, t_train=3600.0, t_gen=600.0,
                                   n_samples=64, n_workers=16),
    # Use Case 3: CFD — all balanced at 10 min, P = N
    "cfd": WorkloadParams(t_oracle=600.0, t_train=600.0, t_gen=600.0,
                          n_samples=8, n_workers=8),
}


def expected_speedups() -> Dict[str, float]:
    """Closed-form expectations from the paper for the three regimes."""
    uc1 = USE_CASES["dft_gnn"]
    return {
        "dft_gnn": 1.0 + uc1.n_workers / uc1.n_samples,   # Eq. 7 -> 2.0
        "xtb_reaction": 1.0,                               # Eq. 10 (approx)
        "cfd": 3.0,                                        # Eq. 13
    }
