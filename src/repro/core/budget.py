"""Cross-round budgeted acquisition (ROADMAP: global oracle-rate controller
+ the rolling-buffer Use Case 2 re-weighting, on device).

The PR-2 rule pipeline is stateless per dispatch: every exchange round makes
its selection in isolation, so the realized oracle rate drifts with the
committee's current disagreement level — exactly the failure mode the paper's
whole-workflow cost argument warns about (oracle labeling pays off only when
its rate is controlled across the run, not per batch).  This module adds the
two *stateful* rules that close that gap, both carried on device and threaded
through the fused single-dispatch hot path (core/acquisition.FusedEngine):

  * ``OracleBudgetController`` — the pure-jnp proportional/integral update
    that steers an effective ``ThresholdRule`` threshold toward a target
    oracle-queries-per-round rate.
  * ``BudgetRule``             — the controller as a ``SelectionRule``: one
    extra compare + a handful of scalar ops inside the compiled dispatch;
    its state (effective threshold, leaky integral, EMA rate, round count)
    never round-trips to host between rounds.
  * ``RollingReweightRule``    — the device-side analog of the paper's
    SI Use Case 2 rolling buffer: input space is hashed into buckets (fixed
    random projection, locality-sensitive), each bucket carries an
    exponentially-decayed score of the highest committee std recently seen
    there, and samples from recently-uncertain regions get their acquisition
    score boosted for downstream threshold/budget/top-fraction rules.
  * ``rules_from_config``      — builds the pipeline from ``PALRunConfig``
    knobs (``oracle_budget`` / ``budget_horizon`` / ``reweight_*``) so the
    runtime stays config-driven (acquisition.make_engine calls this when no
    explicit ``rules=`` are passed).

Both rules run identically (eagerly, same jnp code) on the legacy per-member
backend — fused-vs-legacy parity is tested in tests/test_budget.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.acquisition import (
    STREAM_SERVE, SelectionRule, ThresholdRule, UQStats,
)


# ---------------------------------------------------------------------------
# Locality-sensitive bucketing (shared by RollingReweightRule and the
# serving tier's LSH answer cache)
# ---------------------------------------------------------------------------


def lsh_projection(in_dim: int, seed: int, n_proj: int = 1) -> np.ndarray:
    """The fixed random projection both LSH consumers hash with: a seeded
    ``(in_dim, n_proj)`` float32 Gaussian matrix.  ``RollingReweightRule``
    uses one column (its trace-time constant); ``serving/cache.
    LSHAnswerCache`` stacks several columns to cut bucket collisions.
    Deterministic in ``(in_dim, seed, n_proj)`` so bucket assignment is
    stable across processes and restarts."""
    return np.random.RandomState(seed).randn(in_dim, n_proj) \
        .astype(np.float32)


# ---------------------------------------------------------------------------
# Oracle-rate controller (pure jnp — traceable into the fused dispatch)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OracleBudgetController:
    """Proportional/integral control of a selection threshold toward a
    target per-round oracle rate.

    The realized rate of round t is ``r_t = selected / n_valid``; the
    controller moves the effective threshold *multiplicatively*::

        err_t      = r_t - target
        integral_t = integral_{t-1} * (1 - 1/horizon) + err_t      (leaky)
        thr_{t+1}  = clip(thr_t * exp(kp*err_t + ki*integral_t),
                          thr_min, thr_max)

    Multiplicative-exponential updates make the gains scale-free: the same
    ``kp``/``ki`` work whether committee std lives at 1e-3 or 1e+1, because
    the step is a *relative* change of the threshold.  ``horizon`` (rounds)
    sets both the integral leak and the EMA window of the reported
    ``ema_rate`` — the controller forgets errors older than roughly one
    horizon, so a transient std spike cannot wind up the integral forever.

    State is a flat dict of f32/int32 scalars (a valid jax pytree), so it
    threads through a jitted dispatch as-is and pickles via ``numpy`` for
    checkpoints.
    """

    target: float                 # oracle-selected fraction per round
    kp: float = 0.8               # proportional gain (per unit rate error)
    ki: float = 0.15              # integral gain
    horizon: int = 16             # rounds: integral leak + EMA window

    def init_state(self, thr_init: float) -> Dict[str, Any]:
        return {
            "threshold": jnp.float32(max(float(thr_init), 1e-6)),
            "integral": jnp.float32(0.0),
            "ema_rate": jnp.float32(self.target),
            "rounds": jnp.int32(0),
        }

    def update(self, state: Dict[str, Any], rate,
               thr_min: float, thr_max: float,
               target=None) -> Dict[str, Any]:
        """One control step.  ``rate`` is the realized selected fraction of
        this round (traced f32 scalar inside the fused dispatch).

        ``target``: per-round override of the configured target — a traced
        f32 scalar when the round's target depends on which traffic stream
        produced it (``BudgetRule`` with a distinct ``target_serve``);
        None uses ``self.target``."""
        rate = jnp.asarray(rate, jnp.float32)
        tgt = jnp.float32(self.target) if target is None \
            else jnp.asarray(target, jnp.float32)
        err = rate - tgt
        leak = jnp.float32(1.0 - 1.0 / max(self.horizon, 1))
        integral = state["integral"] * leak + err
        thr = jnp.clip(
            state["threshold"] * jnp.exp(jnp.float32(self.kp) * err
                                         + jnp.float32(self.ki) * integral),
            jnp.float32(thr_min), jnp.float32(thr_max))
        alpha = jnp.float32(1.0 / max(self.horizon, 1))
        ema = state["ema_rate"] + (rate - state["ema_rate"]) * alpha
        return {"threshold": thr, "integral": integral, "ema_rate": ema,
                "rounds": state["rounds"] + 1}


# ---------------------------------------------------------------------------
# Latency controller (the SAME multiplicative PI, steering a queue deadline
# toward a served-p99 target instead of a threshold toward an oracle rate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LatencyController:
    """Adaptive ``ServingQueue`` deadline: steer ``max_wait_ms`` so the
    observed per-request p99 tracks ``target_ms``.

    This is the :class:`OracleBudgetController` control law re-aimed —
    the observed-over-target p99 ratio plays the role of the realized
    oracle rate (target 1.0), and the steered "threshold" is the queue
    deadline (same leaky integral, same multiplicative-exponential step,
    same clip bounds; host floats instead of device scalars because the
    update runs between microbatch dispatches, not inside one).  The
    gains are NEGATED relative to the budget rule because the plant
    responds the other way around: p99 above target must SHRINK the
    deadline (smaller microbatches, less queueing delay), p99 under
    target can GROW it (bigger microbatches, better amortization) — the
    queue trades batch size for deadline automatically as load shifts.
    The multiplicative-exponential update keeps the gains scale-free: the
    same ``kp``/``ki`` work for a 1 ms and a 100 ms target.

    ``wait_min_ms``/``wait_max_ms`` bound the controller's authority the
    same way ``thr_min``/``thr_max`` bound the budget rule: a load spike
    cannot push the deadline somewhere it takes a whole horizon to
    recover from, and the deadline can never go to zero (which would
    forfeit all batching) or to seconds (which would blow every SLO).
    """

    target_ms: float
    kp: float = 0.7
    ki: float = 0.12
    horizon: int = 12             # update windows: integral leak + EMA
    wait_min_ms: float = 0.05
    wait_max_ms: float = 50.0

    def init_state(self, wait_init_ms: float) -> Dict[str, Any]:
        return {
            "threshold": float(np.clip(wait_init_ms, self.wait_min_ms,
                                       self.wait_max_ms)),
            "integral": 0.0,
            "ema_rate": 1.0,
            "rounds": 0,
        }

    def update(self, state: Dict[str, Any], p99_ms) -> Dict[str, Any]:
        """One control step from one observed p99 window (the
        OracleBudgetController law with ``rate = p99/target``, ``target =
        1.0``, gains negated).  Host-side floats rather than jnp scalars:
        the update runs in the serving dispatcher thread between
        microbatch dispatches, where a handful of eager device ops per
        window would stall the very latencies being controlled.  Returns
        the new state; ``wait_ms(state)`` reads the steered deadline."""
        rel = float(p99_ms) / max(self.target_ms, 1e-6)
        err = rel - 1.0
        leak = 1.0 - 1.0 / max(self.horizon, 1)
        integral = state["integral"] * leak + err
        wait = float(np.clip(
            state["threshold"] * np.exp(-(self.kp * err
                                          + self.ki * integral)),
            self.wait_min_ms, self.wait_max_ms))
        alpha = 1.0 / max(self.horizon, 1)
        ema = state["ema_rate"] + (rel - state["ema_rate"]) * alpha
        return {"threshold": wait, "integral": integral, "ema_rate": ema,
                "rounds": state["rounds"] + 1}

    @staticmethod
    def wait_ms(state: Dict[str, Any]) -> float:
        return float(state["threshold"])


# ---------------------------------------------------------------------------
# Stateful selection rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BudgetRule(SelectionRule):
    """Budgeted threshold selection: ``scalar_std > thr_t`` where ``thr_t``
    is steered by an :class:`OracleBudgetController` toward ``target``
    selected-per-round rate.

    Drop-in replacement for the static ``ThresholdRule`` on the fused path:
    the compare, the rate measurement, and the PI update all trace into the
    same compiled dispatch, and the carried state never leaves the device
    between rounds.  ``thr_init`` seeds the effective threshold (typically
    ``PALRunConfig.std_threshold``); ``thr_min``/``thr_max`` default to
    1e-3x / 1e+3x of it, bounding the controller's authority so a long
    all-certain (or all-uncertain) stretch cannot push the threshold to a
    value it takes hundreds of rounds to recover from.

    The rate is measured against this rule's OWN selection (after ANDing
    with the incoming mask), over the TRUE ``n_valid`` — bucket padding
    rows never count toward the budget.

    PER-STREAM TARGETS: ``target`` meters exchange-loop rounds;
    ``target_serve`` (when set and different) meters rounds tagged
    ``STREAM_SERVE`` — queued serving traffic scored through the same
    engine.  The two streams steer the SAME effective threshold (control
    is joint: total labeling demand is what the oracle pool feels), but
    each round's error is measured against its own stream's target, so a
    serving-heavy phase converges to the serving budget while exchange
    rounds keep tracking the exchange budget.  The stream tag is a traced
    scalar inside ``UQStats`` — one compiled program per shape bucket
    regardless.  When ``target_serve`` is unset (or equal), the update is
    literally the single-target PR-3 code path.
    """

    target: float
    thr_init: float
    kp: float = 0.8
    ki: float = 0.15
    horizon: int = 16
    thr_min: Optional[float] = None     # default: thr_init * 1e-3
    thr_max: Optional[float] = None     # default: thr_init * 1e+3
    target_serve: Optional[float] = None  # default: target (shared budget)

    stateful = True

    @property
    def controller(self) -> OracleBudgetController:
        return OracleBudgetController(self.target, self.kp, self.ki,
                                      self.horizon)

    def _bounds(self) -> Tuple[float, float]:
        base = max(float(self.thr_init), 1e-6)
        lo = base * 1e-3 if self.thr_min is None else float(self.thr_min)
        hi = base * 1e+3 if self.thr_max is None else float(self.thr_max)
        return lo, hi

    def init_state(self) -> Dict[str, Any]:
        return self.controller.init_state(self.thr_init)

    def apply_stateful(self, stats: UQStats, mask, state):
        thr = state["threshold"]
        sel = mask & (stats.scalar_std > thr)
        n = jnp.maximum(jnp.asarray(stats.n_valid, jnp.int32), 1)
        rate = jnp.sum(sel).astype(jnp.float32) / n.astype(jnp.float32)
        lo, hi = self._bounds()
        t_serve = self.target if self.target_serve is None \
            else float(self.target_serve)
        if t_serve == self.target:      # shared budget: single-target path
            return stats, sel, self.controller.update(state, rate, lo, hi)
        target = jnp.where(
            jnp.asarray(stats.stream, jnp.int32) == STREAM_SERVE,
            jnp.float32(t_serve), jnp.float32(self.target))
        return stats, sel, self.controller.update(state, rate, lo, hi,
                                                  target=target)


@dataclasses.dataclass(frozen=True)
class RollingReweightRule(SelectionRule):
    """Device-side rolling re-weighting of acquisition scores (the SI Use
    Case 2 analog): regions of input space that recently produced high
    committee std get a boosted score for a while.

    Mechanics (all inside the fused dispatch):

      * inputs are hashed to ``n_buckets`` region buckets with a fixed
        random projection (seeded, generated at trace time):
        ``bucket = floor(x @ proj / bucket_width) mod n_buckets``;
      * each bucket carries an exponentially-decayed score — the running
        max committee std seen there:
        ``scores_t = max(decay * scores_{t-1}, scatter_max(std_t))``;
      * every sample's ``scalar_std`` is re-weighted
        ``std * (1 + boost * scores[bucket]/max(scores))`` for DOWNSTREAM
        rules in the pipeline.

    The rule itself never selects anything — it transforms the stats that a
    following ``ThresholdRule`` / ``BudgetRule`` / ``TopFractionRule``
    consumes, so the pipeline order is ``(RollingReweightRule(...),
    BudgetRule(...))``.  The carried ``(n_buckets,)`` score vector stays on
    device across rounds; the ``UQResult`` the engine reports to host keeps
    the RAW statistics (re-weighting only biases selection, not the
    committee mean/std the generators and Manager consume).
    """

    n_buckets: int = 64
    decay: float = 0.9            # per-round score decay
    boost: float = 1.0            # max relative score boost
    bucket_width: float = 1.0     # projection quantization step
    seed: int = 0

    stateful = True
    needs_inputs = True

    def init_state(self) -> Dict[str, Any]:
        return {"scores": jnp.zeros(self.n_buckets, jnp.float32)}

    def _bucket_ids(self, x):
        x = jnp.asarray(x, jnp.float32)
        in_dim = int(x.shape[-1])          # static under jit
        proj = lsh_projection(in_dim, self.seed)[:, 0]  # trace-time constant
        z = x @ jnp.asarray(proj)
        idx = jnp.floor(z / jnp.float32(self.bucket_width)).astype(jnp.int32)
        return jnp.mod(idx, self.n_buckets)

    def apply_stateful(self, stats: UQStats, mask, state):
        idx = self._bucket_ids(stats.x)
        sstd = jnp.asarray(stats.scalar_std, jnp.float32)
        valid = jnp.asarray(stats.valid)
        cur = jnp.zeros(self.n_buckets, jnp.float32).at[idx].max(
            jnp.where(valid, sstd, 0.0))
        scores = jnp.maximum(state["scores"] * jnp.float32(self.decay), cur)
        norm = scores / (jnp.max(scores) + jnp.float32(1e-12))
        weight = 1.0 + jnp.float32(self.boost) * norm[idx]
        boosted = jnp.where(valid, sstd * weight, 0.0)
        stats = dataclasses.replace(stats, scalar_std=boosted)
        return stats, mask, {"scores": scores}


# ---------------------------------------------------------------------------
# Config-driven pipeline construction
# ---------------------------------------------------------------------------


def rules_from_config(run_cfg) -> Optional[Tuple[SelectionRule, ...]]:
    """Selection-rule pipeline from ``PALRunConfig`` budget knobs.

    Returns ``None`` when no budget/re-weighting knob is set (the engine
    then installs its default static ``ThresholdRule``); otherwise the
    pipeline is ``(RollingReweightRule?, BudgetRule | ThresholdRule)`` —
    re-weighting first so the controller sees the boosted scores.
    Explicit ``rules=`` passed to ``PAL`` / ``make_engine`` always win over
    these knobs.

    Per-stream budgets: ``oracle_budget_exchange`` / ``oracle_budget_serve``
    meter the exchange loop and the serving path separately; either knob
    defaults to the shared ``oracle_budget`` when unset (0), and a stream
    whose own knob AND the shared budget are both unset inherits the other
    stream's target (one controller, one threshold — control stays joint).
    """
    rules = []
    n_buckets = int(getattr(run_cfg, "reweight_buckets", 0) or 0)
    if n_buckets > 0:
        rules.append(RollingReweightRule(
            n_buckets=n_buckets,
            decay=float(getattr(run_cfg, "reweight_decay", 0.9)),
            boost=float(getattr(run_cfg, "reweight_boost", 1.0))))
    shared = float(getattr(run_cfg, "oracle_budget", 0.0) or 0.0)
    t_ex = float(getattr(run_cfg, "oracle_budget_exchange", 0.0) or 0.0) \
        or shared
    t_sv = float(getattr(run_cfg, "oracle_budget_serve", 0.0) or 0.0) \
        or shared
    if t_ex > 0.0 or t_sv > 0.0:
        rules.append(BudgetRule(
            target=(t_ex or t_sv), thr_init=run_cfg.std_threshold,
            horizon=int(getattr(run_cfg, "budget_horizon", 16)),
            target_serve=(t_sv or t_ex)))
    elif rules:
        rules.append(ThresholdRule(run_cfg.std_threshold))
    return tuple(rules) if rules else None
