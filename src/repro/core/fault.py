"""Fault tolerance & elasticity for kernel pools (beyond-paper features the
paper lists as future work; DESIGN.md §2).

* ``TaskLedger``: every dispatched oracle job carries a deadline; expired
  jobs are requeued (straggler mitigation / dead-node tolerance) up to
  ``max_retries``, then surfaced as failed.
* ``Heartbeat``: worker liveness; a worker missing ``max_misses`` beats is
  marked dead and its in-flight work requeued.
* ``ElasticPool``: add/remove worker threads at runtime (elastic scaling of
  oracle/generator pools).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class Task:
    task_id: int
    payload: Any
    dispatched_at: float
    deadline: float
    worker: str
    retries: int = 0


class TaskLedger:
    """Tracks in-flight oracle jobs; requeues stragglers."""

    def __init__(self, timeout: float, max_retries: int = 2):
        self.timeout = timeout
        self.max_retries = max_retries
        self._inflight: Dict[int, Task] = {}
        self._lock = threading.Lock()
        self._ids = itertools.count()
        self.requeued = 0
        self.failed: List[Task] = []
        self.completed = 0

    def dispatch(self, payload: Any, worker: str,
                 retries: int = 0) -> int:
        now = time.time()
        with self._lock:
            tid = next(self._ids)
            self._inflight[tid] = Task(tid, payload, now, now + self.timeout,
                                       worker, retries)
            return tid

    def complete(self, task_id: int) -> Optional[Task]:
        with self._lock:
            t = self._inflight.pop(task_id, None)
            if t is not None:
                self.completed += 1
            return t  # None => was already requeued (late straggler result)

    def fail(self, task: Task):
        """Record a task as terminally failed (reported failure with no
        retries left — the worker-reported analog of retry exhaustion in
        ``expired``)."""
        with self._lock:
            self.failed.append(task)

    def expired(self) -> List[Task]:
        """Pop tasks past their deadline: retryable ones are returned for
        requeue; ones out of retries land in ``failed``."""
        now = time.time()
        out: List[Task] = []
        with self._lock:
            for tid in [t for t, v in self._inflight.items()
                        if v.deadline < now]:
                t = self._inflight.pop(tid)
                if t.retries < self.max_retries:
                    self.requeued += 1
                    out.append(t)
                else:
                    self.failed.append(t)
        return out

    def requeue_worker(self, worker: str) -> List[Task]:
        """Pull every in-flight task owned by a (dead) worker."""
        with self._lock:
            tids = [tid for tid, t in self._inflight.items()
                    if t.worker == worker]
            out = [self._inflight.pop(tid) for tid in tids]
            self.requeued += len(out)
            return out

    def inflight_count(self) -> int:
        with self._lock:
            return len(self._inflight)

    def inflight_payloads(self) -> List[Any]:
        """Payloads of every dispatched-but-unfinished task (checkpoint
        path: in-flight oracle work is requeued into the snapshot so a
        restore never silently loses dispatched-but-unlabeled inputs)."""
        with self._lock:
            return [t.payload for t in self._inflight.values()]


class Heartbeat:
    """Worker liveness tracking (interval-based miss counting)."""

    def __init__(self, interval: float, max_misses: int = 3):
        self.interval = interval
        self.max_misses = max_misses
        self._last: Dict[str, float] = {}
        self._dead: set = set()
        self._lock = threading.Lock()

    def beat(self, worker: str):
        with self._lock:
            self._last[worker] = time.time()
            self._dead.discard(worker)

    def dead_workers(self) -> List[str]:
        now = time.time()
        with self._lock:
            newly = []
            for w, t in self._last.items():
                if w in self._dead:
                    continue
                if now - t > self.interval * self.max_misses:
                    self._dead.add(w)
                    newly.append(w)
            return newly

    def is_dead(self, worker: str) -> bool:
        with self._lock:
            return worker in self._dead

    def forget(self, worker: str):
        with self._lock:
            self._last.pop(worker, None)
            self._dead.discard(worker)


class ElasticPool:
    """A resizable pool of daemon worker threads.

    ``worker_fn(rank: str, stop: threading.Event)`` runs until its private
    stop event (remove) or the pool-wide stop event (shutdown) is set.
    """

    def __init__(self, name: str, worker_fn: Callable[[str, threading.Event],
                                                      None]):
        self.name = name
        self.worker_fn = worker_fn
        self._workers: Dict[str, Dict[str, Any]] = {}
        self._lock = threading.Lock()
        self._ids = itertools.count()
        self.stop_all = threading.Event()

    def add(self, n: int = 1) -> List[str]:
        ranks = []
        with self._lock:
            for _ in range(n):
                rank = f"{self.name}{next(self._ids)}"
                stop = threading.Event()

                def run(rank=rank, stop=stop):
                    self.worker_fn(rank, stop)

                th = threading.Thread(target=run, name=rank, daemon=True)
                self._workers[rank] = {"thread": th, "stop": stop}
                th.start()
                ranks.append(rank)
        return ranks

    def remove(self, rank: str, join: bool = True, timeout: float = 5.0):
        with self._lock:
            w = self._workers.pop(rank, None)
        if w is None:
            return
        w["stop"].set()
        if join:
            w["thread"].join(timeout)

    def shrink(self, n: int = 1):
        with self._lock:
            ranks = list(self._workers)[-n:]
        for r in ranks:
            self.remove(r)

    def ranks(self) -> List[str]:
        with self._lock:
            return list(self._workers)

    def size(self) -> int:
        with self._lock:
            return len(self._workers)

    def shutdown(self, timeout: float = 10.0):
        self.stop_all.set()
        with self._lock:
            items = list(self._workers.items())
        for rank, w in items:
            w["stop"].set()
        for rank, w in items:
            w["thread"].join(timeout)
        with self._lock:
            self._workers.clear()
