"""Pallas TPU kernel for fused committee uncertainty quantification.

One streaming pass over the committee axis computes everything the
acquisition engine (core/acquisition.py) needs — for BOTH the exchange
loop's central check and the Manager's ``dynamic_oracle_list``
re-prioritization:

  * committee mean                       (n, d)  fp32
  * scalar disagreement per sample       (n,)    fp32  — max over output
    components of the ddof=1 std (the quantity the paper thresholds)
  * component disagreement per sample    (n,)    fp32  — mean over output
    components of the same std (the ``adjust_input_for_oracle`` ranking
    score), finalized from the same Welford state at zero extra passes
  * uncertainty mask ``scalar_std > threshold``  (n,)  uint8
  * finite-member count per sample       (n,)    int32 — members with any
    non-finite output component are quarantined out of the statistics
    (degraded-K mean/std) inside the same pass; the count is the
    degradation signal surfaced as ``UQResult.finite_members``

The K axis is the sequential innermost grid dimension; per-row Welford
state (running mean + finite count in output refs, running M2 in VMEM
scratch) is carried across committee members, so the (K, n, d) prediction
tensor is never materialized anywhere outside the committee forward
itself — the controller transfers only the small per-row outputs to host.

Grid: (n_blocks, K).  Rows are blocked; the trailing output dim d is the
lane dimension.  Validated against ``ref.committee_uq_ref`` with
``interpret=True`` in tests/test_committee_uq.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(preds_ref, mean_ref, sstd_ref, cstd_ref, mask_ref, cnt_ref,
            m2_ref, *, n_members: int, threshold: float):
    """One grid step: fold committee member ``k`` into the Welford state
    of one row block.

    Refs (shapes per block, bn = row-block size, d = output components):

      ``preds_ref``  (1, bn, d) in   — member k's predictions for the block
      ``mean_ref``   (bn, d)   out  — running masked mean; after k = K-1
                                      the committee mean over FINITE
                                      members (Welford: ``mean +=
                                      (x - mean) / cnt`` where cnt counts
                                      only finite rows)
      ``m2_ref``     (bn, d)   VMEM — running sum of squared deviations
                                      (``M2 += delta * (x - new_mean)``);
                                      scratch only, never leaves the chip
      ``sstd_ref``   (bn,)     out  — finalized at k = K-1: MAX over d of
                                      ``sqrt(M2 / (cnt-1))`` (ddof=1 over
                                      the finite members)
      ``cstd_ref``   (bn,)     out  — MEAN over d of the same std, from
                                      the same state at zero extra passes
      ``mask_ref``   (bn,)     out  — ``scalar_std > threshold`` AND at
                                      least one finite member, as uint8
                                      (bool is not a legal Pallas output
                                      dtype; the wrapper casts back)
      ``cnt_ref``    (bn,)     out  — running count of finite members per
                                      row (fp32 carried state; the wrapper
                                      casts to int32) — the quarantine
                                      degree reported as
                                      ``UQResult.finite_members``

    K is the sequential innermost grid dimension, so output refs persist
    across the k steps and double as carried state — the classic
    streaming-statistics trick that keeps the (K, n, d) tensor out of
    memory.  ``@pl.when`` guards split init (k=0) / accumulate (k>0) /
    finalize (k=K-1); with K=1 the k=0 branch also finalizes to std 0.

    Member quarantine: a member whose row has ANY non-finite component is
    excluded from the fold for that row (its delta is zeroed BEFORE it can
    contaminate mean/M2 — 0 * NaN would be NaN, hence the double where).
    With all members finite ``cnt`` equals ``k + 1`` at every step and the
    recurrence is bit-identical to the unmasked Welford fold.
    """
    k = pl.program_id(1)
    x = preds_ref[0].astype(jnp.float32)               # (bn, d)
    fin = jnp.all(jnp.isfinite(x), axis=-1)            # (bn,)
    finf = fin.astype(jnp.float32)

    @pl.when(k == 0)
    def _init():
        mean_ref[...] = jnp.where(fin[:, None], x, 0.0)
        m2_ref[...] = jnp.zeros_like(x)
        cnt_ref[...] = finf

    @pl.when(k > 0)
    def _welford():
        mean = mean_ref[...]
        cnt = cnt_ref[...] + finf
        delta = jnp.where(fin[:, None], x - mean, 0.0)
        mean = mean + delta / jnp.maximum(cnt, 1.0)[:, None]
        m2_ref[...] += delta * jnp.where(fin[:, None], x - mean, 0.0)
        mean_ref[...] = mean
        cnt_ref[...] = cnt

    @pl.when(k == n_members - 1)
    def _finalize():
        cnt = cnt_ref[...]
        var = m2_ref[...] / jnp.maximum(cnt - 1.0, 1.0)[:, None]   # ddof=1
        var = jnp.where((cnt >= 2.0)[:, None], var, 0.0)
        std = jnp.sqrt(var)                            # (bn, d)
        sstd = jnp.max(std, axis=-1)                   # (bn,)
        sstd_ref[...] = sstd
        cstd_ref[...] = jnp.mean(std, axis=-1)         # (bn,)
        mask_ref[...] = ((sstd > threshold) & (cnt > 0.0)).astype(jnp.uint8)


def committee_uq(
    preds: jnp.ndarray,      # (K, n, d) committee predictions
    threshold: float,
    *,
    block_n: int = 128,
    interpret: bool = False,
):
    """Fused mean / ddof=1 std statistics / threshold mask over the K axis.

    Returns the 5-tuple ``(mean (n, d) fp32, scalar_std (n,) fp32,
    component_std (n,) fp32, mask (n,) bool, finite (n,) int32)`` —
    scalar_std is the max-over-components std (the exchange check
    quantity), component_std the mean-over-components std (the oracle
    re-prioritization score); both finalize from the SAME single Welford
    pass, so the Manager's ``dynamic_oracle_list`` score costs no extra
    reduction.  ``finite`` counts, per row, the committee members whose
    outputs were finite — members with any non-finite component are
    quarantined out of the statistics inside the same pass (degraded-K
    mean/std; see ``ref.committee_uq_ref`` for the exact semantics), so a
    diverged member degrades UQ quality instead of poisoning it, at zero
    extra dispatches.

    Row blocking: the n axis is processed in blocks of ``block_n``
    (clamped to n) and padded up to a whole number of blocks; padding rows
    carry zeros through the Welford state (std 0, mask 0) and are sliced
    off before returning, so callers always see exactly n rows.  This
    internal padding is independent of the acquisition engine's
    power-of-two shape bucketing (``committee.shape_bucket``), which
    quantizes n itself to bound jit recompiles — by construction n is
    usually already a bucket size here and the kernel pad is a no-op.
    ``interpret=True`` runs the same kernel under the Pallas interpreter
    (CPU validation; tests/test_committee_uq.py checks parity against
    ``ref.committee_uq_ref``).
    """
    K, n, d = preds.shape
    bn = min(block_n, n)
    pad = (-n) % bn
    if pad:
        preds = jnp.pad(preds, ((0, 0), (0, pad), (0, 0)))
    npad = n + pad
    nb = npad // bn

    kernel = functools.partial(_kernel, n_members=K,
                               threshold=float(threshold))
    pspec = pl.BlockSpec((1, bn, d), lambda i, k: (k, i, 0))
    mean_spec = pl.BlockSpec((bn, d), lambda i, k: (i, 0))
    row_spec = pl.BlockSpec((bn,), lambda i, k: (i,))

    mean, sstd, cstd, mask, cnt = pl.pallas_call(
        kernel,
        grid=(nb, K),
        in_specs=[pspec],
        out_specs=[mean_spec, row_spec, row_spec, row_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((npad, d), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.uint8),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bn, d), jnp.float32)],
        interpret=interpret,
    )(preds)
    if pad:
        mean, sstd, cstd = mean[:n], sstd[:n], cstd[:n]
        mask, cnt = mask[:n], cnt[:n]
    return mean, sstd, cstd, mask.astype(jnp.bool_), cnt.astype(jnp.int32)
