"""Pallas TPU flash attention: block-wise online softmax with VMEM scratch.

Target: TPU v5e MXU.  Tiles: (block_q x head_dim) q blocks against
(block_k x head_dim) kv blocks; fp32 (m, l, acc) accumulators live in VMEM
scratch across the sequential kv grid axis.  Causal + sliding-window masking
and GQA (q-head blocks index their shared kv head) are handled in-kernel;
decode masking uses a (B,) kv_len input.  Validated on CPU with
``interpret=True`` against ``ref.attention_ref`` (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar-ish inputs (SMEM-friendly tiny arrays)
    qoff_ref,            # (1, 1) int32  — q position offset (decode index)
    kvl_ref,             # (B, 1) int32  — valid kv length per batch (or S)
    # tensor inputs
    q_ref,               # (1, bq, 1, D)
    k_ref,               # (1, bk, 1, D)
    v_ref,               # (1, bk, 1, D)
    # outputs
    o_ref,               # (1, bq, 1, D)
    # scratch
    acc_ref,             # (bq, D) f32
    m_ref,               # (bq, 1) f32
    l_ref,               # (bq, 1) f32
    *,
    causal: bool,
    window: Optional[int],
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    scale: float,
    mask_kv_len: bool,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    qoff = qoff_ref[0, 0]
    qpos = qoff + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level skip: no kv position in this block can be visible
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, ki * block_k <= qoff + qi * block_q
                              + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, (ki + 1) * block_k - 1 > qoff + qi * block_q - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq, bk)

        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        if mask_kv_len:
            kvl = kvl_ref[0, 0]
            mask = jnp.logical_and(mask, kpos < kvl)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                               # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)                   # fully-masked rows
        o_ref[0, :, 0, :] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,          # (B, T, H, D)
    k: jnp.ndarray,          # (B, S, KV, D)
    v: jnp.ndarray,          # (B, S, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_len: Optional[jnp.ndarray] = None,   # (B,) valid lengths
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    B, T, H, D = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    block_q = min(block_q, T)
    block_k = min(block_k, S)
    if T % block_q or S % block_k:
        raise ValueError(f"shape not tileable: T={T} bq={block_q} "
                         f"S={S} bk={block_k}")
    nq, nk = T // block_q, S // block_k

    qoff = jnp.asarray(q_offset, jnp.int32).reshape(1, 1)
    if kv_len is None:
        kvl = jnp.full((B, 1), S, jnp.int32)
        mask_kv_len = False
    else:
        kvl = kv_len.astype(jnp.int32).reshape(B, 1)
        mask_kv_len = True

    kernel = functools.partial(
        _kernel, causal=causal, window=window, block_q=block_q,
        block_k=block_k, num_k_blocks=nk, scale=1.0 / (D ** 0.5),
        mask_kv_len=mask_kv_len,
    )

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, qi, ki: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, qi, ki: (b, 0)),
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, D), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qoff, kvl, q, k, v)
    return out
