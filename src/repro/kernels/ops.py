"""Public ops with implementation switch: impl in {'xla', 'pallas', 'pallas_interpret'}.

'xla'             — chunked-but-exact jnp schedules (ref.py), used by the
                    512-device dry-run and CPU training.
'pallas'          — TPU Pallas kernels (target hardware).
'pallas_interpret'— same kernels, interpret=True (CPU validation in tests).

Models only ever call these entry points, so the whole zoo switches backend
with one config knob.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_IMPL = "xla"


def attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_len=None,
    impl: str = _DEFAULT_IMPL,
    q_chunk: int = 1024,
    kv_seq_shard: bool = False,
    rules=None,
):
    """Multi-head attention, GQA-aware. q: (B,T,H,D); k,v: (B,S,KV,D).

    kv_seq_shard: hint that the cache is sharded on its sequence axis
    (long_500k decode) — keeps the constraint inside the layer so XLA
    produces a flash-decode-style distributed softmax reduction instead of
    an all-gather of the cache.
    """
    B, T, H, D = q.shape
    if kv_seq_shard and rules is not None:
        from repro.configs import base as _ax
        from repro.sharding.rules import shard_constraint as _sc

        k = _sc(k, rules, (_ax.BATCH, _ax.CACHE_SEQ, _ax.KV_HEADS, _ax.HEAD_DIM))
        v = _sc(v, rules, (_ax.BATCH, _ax.CACHE_SEQ, _ax.KV_HEADS, _ax.HEAD_DIM))
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import flash_attention as fa

        return fa.flash_attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_len=kv_len, interpret=(impl == "pallas_interpret"),
        )
    # XLA path: direct for small T / decode, unrolled-chunked otherwise.
    if T <= q_chunk or kv_len is not None:
        return ref.attention_ref(
            q, k, v, causal=causal, window=window, q_offset=q_offset, kv_len=kv_len
        )
    return ref.attention_chunked_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset, chunk=q_chunk
    )


def committee_uq(preds, threshold: float, *, impl: str = _DEFAULT_IMPL,
                 block_n: int = 128):
    """Fused committee-UQ for the PAL acquisition engine.

    preds: (K, n, d) stacked committee predictions (one vmapped forward).
    Returns (mean (n, d) fp32, scalar_std (n,) fp32, component_std (n,)
    fp32, mask (n,) bool, finite (n,) int32) — the ONLY tensors the
    controller ever ships back to host.  scalar_std (max over components)
    feeds the exchange check; component_std (mean over components, same
    Welford pass) feeds the Manager's dynamic_oracle_list
    re-prioritization, replacing the seed path's full (K, n, d) round trip
    + float64 NumPy std recompute.  finite counts the committee members
    whose row was fully finite — non-finite members are quarantined out of
    the statistics (degraded-K mean/std) in the same pass, so a diverged
    member degrades UQ instead of emitting NaN scores.
    """
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import committee_uq as _cuq

        return _cuq.committee_uq(
            preds, threshold, block_n=block_n,
            interpret=(impl == "pallas_interpret"),
        )
    return ref.committee_uq_ref(preds, threshold)


def wkv6(r, k, v, w, u, state=None, *, impl: str = _DEFAULT_IMPL, chunk: int = 64):
    """RWKV6 WKV. r/k/v/w: (B,T,H,N); u: (H,N). Returns (y, state)."""
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import wkv6 as _wkv6

        return _wkv6.wkv6(
            r, k, v, w, u, state, chunk=chunk,
            interpret=(impl == "pallas_interpret"),
        )
    return ref.wkv6_chunked_ref(r, k, v, w, u, state, chunk=chunk)


def wkv6_decode(r, k, v, w, u, state):
    return ref.wkv6_decode_ref(r, k, v, w, u, state)


def ssd(x, a, Bm, Cm, state=None, *, impl: str = _DEFAULT_IMPL, chunk: int = 64):
    """Mamba-2/SSD chunked scan. Returns (y, state)."""
    if impl in ("pallas", "pallas_interpret"):
        from repro.kernels import ssd_scan

        return ssd_scan.ssd(
            x, a, Bm, Cm, state, chunk=chunk,
            interpret=(impl == "pallas_interpret"),
        )
    return ref.ssd_chunked_ref(x, a, Bm, Cm, state, chunk=chunk)


def ssd_decode(x, a, Bm, Cm, state):
    return ref.ssd_decode_ref(x, a, Bm, Cm, state)
