"""Pallas TPU kernel for the SSD (Mamba-2 form) chunked scan (DESIGN.md §6).

Scalar decay per head per step.  Grid (B, H, T/C), sequential chunk axis;
carried (N x P) fp32 state in VMEM scratch.  Intra-chunk work: a (C x C)
masked decay-weighted attention matmul (C_t·B_j) plus two (C x N)/(N x P)
matmuls — all MXU-friendly.  Validated with interpret=True against
ref.ssd_ref / ssd_chunked_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, s0_ref,
            y_ref, sout_ref, state_ref, *, chunk: int, n_chunks: int):
    C = chunk
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # (C, P)
    a = a_ref[0, :, 0].astype(jnp.float32)             # (C,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # (C, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # (C, N)

    la = jnp.log(jnp.clip(a, 1e-12, 1.0))              # (C,) <= 0
    incl = jnp.cumsum(la)                              # (C,)
    total = incl[-1]

    S = state_ref[...]                                 # (N, P)
    # inter-chunk: y_t = exp(incl_t) * C_t @ S
    y = jnp.exp(incl)[:, None] * jax.lax.dot_general(
        Cm, S, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    # intra-chunk: A[t,j] = (C_t . B_j) exp(incl_t - incl_j), j <= t
    ratio = jnp.exp(jnp.clip(incl[:, None] - incl[None, :], -60.0, 0.0))
    A = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * ratio
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(tj <= ti, A, 0.0)
    y = y + jax.lax.dot_general(A, x, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    b_dec = Bm * jnp.exp(jnp.clip(total - incl, -60.0, 0.0))[:, None]
    upd = jax.lax.dot_general(b_dec, x, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (N, P)
    state_ref[...] = jnp.exp(total) * S + upd

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sout_ref[0, 0] = state_ref[...]


def ssd(
    x: jnp.ndarray,          # (B, T, H, P) dt-scaled inputs
    a: jnp.ndarray,          # (B, T, H) decay in (0,1]
    Bm: jnp.ndarray,         # (B, T, H, N)
    Cm: jnp.ndarray,         # (B, T, H, N)
    state: Optional[jnp.ndarray] = None,  # (B, H, N, P) fp32
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} not divisible by chunk={chunk}")
    nC = T // chunk
    if state is None:
        state = jnp.zeros((B, H, N, P), jnp.float32)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nC)
    xspec = pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0))
    nspec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h, 0))
    aspec = pl.BlockSpec((1, chunk, 1), lambda b, h, ci: (b, ci, h))
    state_spec = pl.BlockSpec((1, 1, N, P), lambda b, h, ci: (b, h, 0, 0))

    y, state_out = pl.pallas_call(
        kernel,
        grid=(B, H, nC),
        in_specs=[xspec, aspec, nspec, nspec, state_spec],
        out_specs=[xspec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, a, Bm, Cm, state)
    return y, state_out
