"""Pure-jnp reference oracles for every Pallas kernel.

These are the *semantics* of the three perf-critical ops; the Pallas kernels
(flash_attention.py / wkv6.py / ssd_scan.py) are asserted allclose against
them across shape/dtype sweeps in tests/.  The XLA model path (ops.py,
impl='xla') uses chunked-but-exact variants of the same math so the dry-run
costs reflect a production schedule rather than naive O(S^2) materialization.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Committee uncertainty quantification (PAL exchange hot path)
# ---------------------------------------------------------------------------


def committee_uq_ref(preds: jnp.ndarray, threshold: float):
    """Committee mean / ddof=1 std statistics / threshold mask in one program.

    preds: (K, n, d).  Returns (mean (n, d) fp32, scalar_std (n,) fp32,
    component_std (n,) fp32, mask (n,) bool, finite (n,) int32).
    scalar_std is the max over output components of the per-component
    ddof=1 std — the quantity the paper's prediction_check thresholds
    ((std > t).any over components == scalar_std > t); component_std is
    the mean over components of the same std — the ranking score of
    adjust_input_for_oracle (dynamic_oracle_list), emitted from the same
    statistics pass.

    Member quarantine (degraded-K statistics): a member's row is excluded
    from the statistics when ANY of its d output components is non-finite
    (a diverged/poisoned committee member must not poison the committee
    mean or std for anyone).  ``finite`` reports the per-row count of
    members that participated; with fewer than 2 finite members the std
    is 0 (disagreement is unmeasurable) and with 0 finite members the
    mask is forced off.  When every member is finite — the steady state —
    the masked reductions are exactly the unmasked ones.
    """
    p = preds.astype(jnp.float32)
    K = p.shape[0]
    fin = jnp.all(jnp.isfinite(p), axis=-1)                # (K, n) per-member row
    cnt = jnp.sum(fin.astype(jnp.int32), axis=0)           # (n,)
    finw = fin[..., None]                                  # (K, n, 1)
    safe_cnt = jnp.maximum(cnt, 1).astype(jnp.float32)[:, None]
    mean = jnp.sum(jnp.where(finw, p, 0.0), axis=0) / safe_cnt
    dev = jnp.where(finw, p - mean, 0.0)
    var = jnp.sum(dev * dev, axis=0) / jnp.maximum(
        cnt - 1, 1).astype(jnp.float32)[:, None]
    std = jnp.sqrt(jnp.where((cnt >= 2)[:, None], var, 0.0))
    scalar_std = jnp.max(std, axis=-1)
    component_std = jnp.mean(std, axis=-1)
    mask = (scalar_std > jnp.float32(threshold)) & (cnt > 0)
    return mean, scalar_std, component_std, mask, cnt


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _mask(q_len: int, kv_len: int, q_offset, causal: bool,
          window: Optional[int]) -> jnp.ndarray:
    """(q_len, kv_len) boolean mask. q position i sits at q_offset + i."""
    qpos = q_offset + jnp.arange(q_len)[:, None]
    kpos = jnp.arange(kv_len)[None, :]
    m = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def attention_ref(
    q: jnp.ndarray,          # (B, T, H, D)
    k: jnp.ndarray,          # (B, S, KV, D)
    v: jnp.ndarray,          # (B, S, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_len=None,             # optional (B,) valid cache lengths (decode)
) -> jnp.ndarray:
    """Naive full-materialization attention; fp32 softmax; GQA-aware."""
    B, T, H, D = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    q = q.reshape(B, T, KV, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", q, kf) / jnp.sqrt(D).astype(jnp.float32)
    m = _mask(T, S, q_offset, causal, window)[None, None, None]
    if kv_len is not None:
        valid = jnp.arange(S)[None, :] < kv_len[:, None]
        m = m & valid[:, None, None, None, :]
    scores = jnp.where(m, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    out = jnp.einsum("bkgts,bskd->btkgd", p, vf)
    return out.reshape(B, T, H, D).astype(q.dtype if q.dtype != jnp.float32 else v.dtype)


def attention_chunked_ref(
    q, k, v, *, causal=True, window=None, q_offset=0, chunk: int = 1024,
) -> jnp.ndarray:
    """Flash-style (online softmax) attention, fully unrolled over q chunks.

    Exact same math as attention_ref; bounded memory.  Unrolled (python loop)
    so XLA cost analysis sees every chunk (DESIGN.md §8).
    """
    B, T, H, D = q.shape
    _, S, KV, _ = k.shape
    G = H // KV
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    outs = []
    for start in range(0, T, chunk):
        qc = q[:, start:start + chunk].astype(jnp.float32)
        L = qc.shape[1]
        qc = qc.reshape(B, L, KV, G, D)
        # bound kv range touched by this q chunk (causal => no future keys)
        if causal and isinstance(q_offset, int):
            kv_hi = min(S, q_offset + start + L)
        else:
            kv_hi = S
        kv_lo = 0
        if window is not None and isinstance(q_offset, int):
            kv_lo = max(0, q_offset + start - window + 1)
        kc = kf[:, kv_lo:kv_hi]
        vc = vf[:, kv_lo:kv_hi]
        scores = jnp.einsum("blkgd,bskd->bkgls", qc, kc) * scale
        qpos = q_offset + start + jnp.arange(L)[:, None]
        kpos = kv_lo + jnp.arange(kv_hi - kv_lo)[None, :]
        m = jnp.ones((L, kv_hi - kv_lo), dtype=bool)
        if causal:
            m &= kpos <= qpos
        if window is not None:
            m &= kpos > qpos - window
        scores = jnp.where(m[None, None, None], scores, -jnp.inf)
        p = jax.nn.softmax(scores, axis=-1)
        p = jnp.where(jnp.isnan(p), 0.0, p)
        # §Perf iter: probabilities are bounded [0,1] — the AV matmul reads
        # them in bf16 (halves the dominant score-chain HBM traffic; softmax
        # itself stays fp32 for stability)
        oc = jnp.einsum("bkgls,bskd->blkgd", p.astype(v.dtype), vc)
        outs.append(oc.reshape(B, L, H, D))
    return jnp.concatenate(outs, axis=1).astype(v.dtype)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) WKV — vector decay per key channel
# ---------------------------------------------------------------------------


def wkv6_ref(
    r: jnp.ndarray,          # (B, T, H, N)
    k: jnp.ndarray,          # (B, T, H, N)
    v: jnp.ndarray,          # (B, T, H, N)
    w: jnp.ndarray,          # (B, T, H, N) decay in (0,1), per key channel
    u: jnp.ndarray,          # (H, N) bonus
    state: Optional[jnp.ndarray] = None,  # (B, H, N, N) incoming state
):
    """Sequential-scan reference.

    y_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (y: (B,T,H,N), state_out: (B,H,N,N)).
    """
    B, T, H, N = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    def step(S, inputs):
        rt, kt, vt, wt = inputs          # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + uf[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (rf, kf, vf, wf))
    state_out, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1)
    return y.astype(v.dtype), state_out


def wkv6_chunked_ref(r, k, v, w, u, state=None, chunk: int = 64):
    """Chunked (linear-attention form) WKV6 — the TPU-native schedule.

    Intra-chunk decay ratios are computed in log space (exact, stable);
    inter-chunk contributions and state updates are matmuls (DESIGN.md §6).
    """
    B, T, H, N = r.shape
    assert T % chunk == 0, (T, chunk)
    C = chunk
    rf, kf, vf = (x.astype(jnp.float32) for x in (r, k, v))
    lw = jnp.log(w.astype(jnp.float32).clip(1e-12))      # (B,T,H,N) <= 0
    uf = u.astype(jnp.float32)
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    nC = T // C
    resh = lambda x: x.reshape(B, nC, C, H, N).transpose(1, 0, 3, 2, 4)  # (nC,B,H,C,N)
    rc, kc, vc, lwc = resh(rf), resh(kf), resh(vf), resh(lw)

    def chunk_step(S, inputs):
        rt, kt, vt, lwt = inputs                          # (B,H,C,N)
        incl = jnp.cumsum(lwt, axis=2)                    # log prod_{1..t}
        excl = incl - lwt                                 # log prod_{1..t-1}
        total = incl[:, :, -1:, :]                        # log prod over chunk
        # inter-chunk: y_t += (r_t * exp(excl_t)) @ S
        q_dec = rt * jnp.exp(excl)
        y = jnp.einsum("bhcn,bhnm->bhcm", q_dec, S)
        # intra-chunk: A[t,j] = sum_n r[t]k[j] exp(excl_t - incl_j), j<t
        dec = jnp.exp(
            jnp.clip(excl[:, :, :, None, :] - incl[:, :, None, :, :], -60.0, 0.0)
        )                                                  # (B,H,C,C,N)
        A = jnp.einsum("bhtn,bhjn,bhtjn->bhtj", rt, kt, dec)
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)
        A = jnp.where(mask[None, None], A, 0.0)
        # diagonal bonus u
        diag = jnp.einsum("bhtn,bhtn->bht", rt * uf[None, :, None, :], kt)
        y = y + jnp.einsum("bhtj,bhjm->bhtm", A, vt) + diag[..., None] * vt
        # state update: S' = diag(prod w) S + sum_j (prod_{j+1..C} w * k_j) v_j^T
        k_dec = kt * jnp.exp(jnp.clip(total - incl, -60.0, 0.0))
        S = jnp.exp(total[:, :, 0, :])[..., None] * S + jnp.einsum(
            "bhjn,bhjm->bhnm", k_dec, vt
        )
        return S, y

    state_out, ys = jax.lax.scan(chunk_step, state, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, N)
    return y.astype(v.dtype), state_out


def wkv6_decode_ref(r, k, v, w, u, state):
    """Single-token recurrent step. r,k,v,w: (B,H,N); state: (B,H,N,N)."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]
    y = jnp.einsum("bhn,bhnm->bhm", rf, state + uf[None, :, :, None] * kv)
    state = wf[..., :, None] * state + kv
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# SSD scan (Mamba-2 form) — scalar decay per head
# ---------------------------------------------------------------------------


def ssd_ref(
    x: jnp.ndarray,          # (B, T, H, P) values (already dt-scaled)
    a: jnp.ndarray,          # (B, T, H) decay in (0,1]
    Bm: jnp.ndarray,         # (B, T, H, N) input matrix ("k")
    Cm: jnp.ndarray,         # (B, T, H, N) output matrix ("q")
    state: Optional[jnp.ndarray] = None,  # (B, H, N, P)
):
    """Sequential reference: S_t = a_t S_{t-1} + B_t^T x_t ; y_t = C_t S_t."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    xf, af, bf, cf = (z.astype(jnp.float32) for z in (x, a, Bm, Cm))
    if state is None:
        state = jnp.zeros((B, H, N, P), jnp.float32)

    def step(S, inputs):
        xt, at, bt, ct = inputs
        S = at[..., None, None] * S + bt[..., :, None] * xt[..., None, :]
        y = jnp.einsum("bhn,bhnp->bhp", ct, S)
        return S, y

    xs = tuple(jnp.moveaxis(z, 1, 0) for z in (xf, af, bf, cf))
    state_out, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state_out


def ssd_chunked_ref(x, a, Bm, Cm, state=None, chunk: int = 64):
    """Chunked SSD (Mamba-2): intra-chunk (C x C) masked matmuls + carried
    (N x P) state.  Decay ratios are bounded <= 1 -> numerically benign."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    C = chunk
    assert T % C == 0
    xf, bf, cf = (z.astype(jnp.float32) for z in (x, Bm, Cm))
    la = jnp.log(a.astype(jnp.float32).clip(1e-12))       # (B,T,H)
    if state is None:
        state = jnp.zeros((B, H, N, P), jnp.float32)
    nC = T // C
    reshv = lambda z: z.reshape(B, nC, C, H, -1).transpose(1, 0, 3, 2, 4)
    xc, bc, cc = reshv(xf), reshv(bf), reshv(cf)          # (nC,B,H,C,*)
    lac = la.reshape(B, nC, C, H).transpose(1, 0, 3, 2)   # (nC,B,H,C)

    def chunk_step(S, inputs):
        xt, bt, ct, lat = inputs
        incl = jnp.cumsum(lat, axis=-1)                    # (B,H,C) log prod_{1..t}
        total = incl[..., -1:]
        # inter: y_t = exp(incl_t) * C_t @ S   (state S is pre-chunk)
        y = jnp.exp(incl)[..., None] * jnp.einsum("bhcn,bhnp->bhcp", ct, S)
        # intra: A[t,j] = (C_t . B_j) * exp(incl_t - incl_j) for j <= t
        ratio = jnp.exp(jnp.clip(incl[..., :, None] - incl[..., None, :], -60.0, 0.0))
        A = jnp.einsum("bhtn,bhjn->bhtj", ct, bt) * ratio
        mask = jnp.tril(jnp.ones((C, C), bool))
        A = jnp.where(mask[None, None], A, 0.0)
        y = y + jnp.einsum("bhtj,bhjp->bhtp", A, xt)
        # state update
        b_dec = bt * jnp.exp(jnp.clip(total - incl, -60.0, 0.0))[..., None]
        S = jnp.exp(total[..., 0])[..., None, None] * S + jnp.einsum(
            "bhjn,bhjp->bhnp", b_dec, xt
        )
        return S, y

    state_out, ys = jax.lax.scan(chunk_step, state, (xc, bc, cc, lac))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, T, H, P)
    return y.astype(x.dtype), state_out


def ssd_decode_ref(x, a, Bm, Cm, state):
    """Single-token step. x:(B,H,P), a:(B,H), Bm/Cm:(B,H,N), state:(B,H,N,P)."""
    xf, af, bf, cf = (z.astype(jnp.float32) for z in (x, a, Bm, Cm))
    state = af[..., None, None] * state + bf[..., :, None] * xf[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", cf, state)
    return y.astype(x.dtype), state
