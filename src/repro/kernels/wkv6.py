"""Pallas TPU kernel for the RWKV6 (Finch) WKV recurrence — chunked
linear-attention form (DESIGN.md §6).

Grid (B, H, T/C): the chunk axis is sequential; the carried per-(b,h) state
(N x N, fp32) lives in VMEM scratch across chunk steps.  Intra-chunk work is
(C x C) and (C x N)x(N x N) matmuls on the MXU; decay ratios are formed in
log space as *differences* (exp of a clipped non-positive exponent) — the
factorized exp(excl)·exp(-incl) form overflows under strong decay.

Validated with interpret=True against ref.wkv6_ref / wkv6_chunked_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
            y_ref, sout_ref, state_ref, *, chunk: int, n_chunks: int):
    C = chunk
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)          # (C, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)                # (N,)

    lw = jnp.log(jnp.clip(w, 1e-12, 1.0))              # (C, N) <= 0
    incl = jnp.cumsum(lw, axis=0)                      # log prod_{1..t}
    excl = incl - lw                                   # log prod_{1..t-1}
    total = incl[-1:, :]                               # (1, N)

    S = state_ref[...]                                 # (N, N) fp32
    q_dec = r * jnp.exp(excl)
    y = jax.lax.dot_general(q_dec, S, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (C, N)

    # intra-chunk: A[t,j] = sum_n r[t,n] k[j,n] exp(excl_t - incl_j), j < t
    dec = jnp.exp(jnp.clip(excl[:, None, :] - incl[None, :, :], -60.0, 0.0))
    A = jnp.sum(r[:, None, :] * k[None, :, :] * dec, axis=2)     # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    A = jnp.where(tj < ti, A, 0.0)

    diag = jnp.sum(r * u[None, :] * k, axis=1)         # (C,)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + diag[:, None] * v

    k_dec = k * jnp.exp(jnp.clip(total - incl, -60.0, 0.0))
    kv = jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, N)
    state_ref[...] = jnp.exp(total[0])[:, None] * S + kv

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sout_ref[0, 0] = state_ref[...]


def wkv6(
    r: jnp.ndarray,          # (B, T, H, N)
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,          # decay in (0,1), per key channel
    u: jnp.ndarray,          # (H, N)
    state: Optional[jnp.ndarray] = None,  # (B, H, N, N) fp32
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    B, T, H, N = r.shape
    chunk = min(chunk, T)
    if T % chunk:
        raise ValueError(f"T={T} not divisible by chunk={chunk}")
    nC = T // chunk
    if state is None:
        state = jnp.zeros((B, H, N, N), jnp.float32)

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=nC)
    seq_spec = pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci: (b, ci, h, 0))
    state_spec = pl.BlockSpec((1, 1, N, N), lambda b, h, ci: (b, h, 0, 0))

    y, state_out = pl.pallas_call(
        kernel,
        grid=(B, H, nC),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, N), lambda b, h, ci: (h, 0)),
            state_spec,
        ],
        out_specs=[seq_spec, state_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, N), v.dtype),
            jax.ShapeDtypeStruct((B, H, N, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, state_out
