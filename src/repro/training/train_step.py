"""Train/eval step builders: loss (incl. MoE aux) -> grads -> clip ->
schedule -> AdamW, with gradient accumulation and an optional gradient-
compression cast at the DP-reduction point (beyond-paper).

The returned step function is pure (state, batch) -> (state, metrics) and
jit/pjit-able; sharding is applied by the caller (launch/dryrun.py resolves
in_shardings from the ParamSpec logical axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.optim.adamw import (
    AdamWConfig, AdamWState, adamw_init, adamw_update, clip_by_global_norm,
)
from repro.optim.schedule import make_schedule


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt: AdamWState


def make_train_state(params: Any, train_cfg: TrainConfig) -> TrainState:
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt=adamw_init(params, quantized=train_cfg.quantized_opt_state,
                       moments=getattr(train_cfg, "opt_moments", "")),
    )


def make_train_step(
    loss_fn: Callable[[Any, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Dict]],
    train_cfg: TrainConfig,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict]]:
    schedule = make_schedule(
        train_cfg.schedule, train_cfg.learning_rate,
        warmup_steps=train_cfg.warmup_steps,
        decay_steps=train_cfg.decay_steps,
        stable_steps=train_cfg.stable_steps,
        min_lr_ratio=train_cfg.min_lr_ratio,
    )
    adam_cfg = AdamWConfig(
        beta1=train_cfg.beta1, beta2=train_cfg.beta2, eps=train_cfg.eps,
        weight_decay=train_cfg.weight_decay,
        quantized=train_cfg.quantized_opt_state,
        moments=getattr(train_cfg, "opt_moments", ""),
    )
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    accum = max(1, train_cfg.accum_steps)

    def compute_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # microbatch over the leading batch dim
            def micro(i, carry):
                g_acc, l_acc = carry
                mb = jax.tree.map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        x, i * (x.shape[0] // accum), x.shape[0] // accum, 0),
                    batch)
                (l, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return g_acc, l_acc + l

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, loss = jax.lax.fori_loop(
                0, accum, micro, (zeros, jnp.float32(0.0)))
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss / accum
            metrics = {}
        if train_cfg.grad_compression == "bf16":
            # beyond-paper: cast grads at the cross-replica reduction point;
            # under SPMD the psum then runs on 2-byte words (half the DP
            # all-reduce bytes), error feedback not needed at these scales.
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        return loss, metrics, grads

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        loss, metrics, grads = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip_norm)
        lr = schedule(state.step)
        new_params, new_opt = adamw_update(grads, state.opt, state.params,
                                           lr, adam_cfg)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return TrainState(state.step + 1, new_params, new_opt), metrics

    return train_step


def make_eval_step(loss_fn):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
