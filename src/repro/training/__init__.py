from repro.training.train_step import (  # noqa: F401
    TrainState, make_train_state, make_train_step, make_eval_step,
)
from repro.training.committee_trainer import (  # noqa: F401
    CommitteeTrainer, default_train_config,
)
from repro.optim.memory_policy import MemoryPolicy  # noqa: F401
