"""Fused committee training: ALL K members advance in ONE jitted step.

The paper's training kernel retrains every committee member in parallel
(one MPI rank per member) and ships weights to the prediction kernel as
packed 1-D arrays.  Here the whole committee is ONE SPMD program, mirroring
what PRs 1–4 did for scoring and serving:

  * per-member ``TrainState`` (params + AdamW moments + step) stacked on a
    leading committee axis — built once from the SAME stacked ``cparams``
    the acquisition engine scores, so training and prediction share layout;
  * ``training/train_step.make_train_step`` ``vmap``-ed over that axis:
    one compiled dispatch advances all K members, each on its OWN bootstrap
    minibatch (per-member fold of the step key keeps members decorrelated;
    ``bootstrap=False`` gives every member the identical minibatch — the
    legacy same-data-order semantics, used by the parity tests);
  * minibatches are gathered ON DEVICE from a
    ``data/replay.ReplayTrainingBuffer`` (fixed-capacity device ring,
    host blocks appended once) — a train step moves zero training bytes
    across the host boundary;
  * shardable over the ``model`` mesh axis by reusing
    ``sharding/rules.committee_shardings`` on the stacked TrainState, so a
    production mesh trains and scores the committee on the same layout
    (the degenerate 1x1 host mesh is bit-identical to unsharded — tested);
  * refreshed weights hand off DEVICE-TO-DEVICE:
    ``FusedEngine.refresh_from_device(trainer.snapshot_cparams())``
    re-places the stacked pytree on the committee layout directly.
    ``WeightStore``'s packed 1-D round trip remains only for the
    legacy per-member backend and checkpoint wire format.

Per-member storage is a POLICY, not hard-coded fp32: ``memory_policy``
(``optim/memory_policy.MemoryPolicy`` or a preset name) picks the AdamW
moment format (fp32 | bf16 | int8 ``QTensor``), the stacked-param storage
dtype, and the replay-ring row dtype.  Quantize/dequantize lives INSIDE
the one fused dispatch (``optim/adamw.py``), so K=64 with int8 moments
trains through the same single jitted vmapped step as K=8 fp32.  Update
math is fp32 under every policy.

``state_dict``/``load_state_dict`` snapshot the FULL TrainState (params,
Adam moments, per-member step) plus the RNG cursor and the replay ring, so
a restored run continues mid-schedule instead of resetting its optimizer.
Quantized moments checkpoint NATIVELY (int8 ``q`` + fp32 ``scale``, never
dequantized on save); restoring a snapshot whose storage format mismatches
the configured policy raises instead of silently re-formatting.
"""
from __future__ import annotations

import dataclasses
import logging
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

log = logging.getLogger(__name__)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.core.committee import committee_size, member
from repro.data.replay import ReplayTrainingBuffer
from repro.optim.adamw import QTensor, resolve_moments
from repro.optim.memory_policy import MemoryPolicy, resolve_policy
from repro.training.train_step import make_train_state, make_train_step


def default_train_config(lr: float) -> TrainConfig:
    """The committee-retrain optimizer defaults: constant-LR AdamW without
    warmup (retraining resumes continuously; a re-warmup every round would
    stall the member right when fresh labels arrive)."""
    return TrainConfig(learning_rate=lr, schedule="constant",
                       warmup_steps=0, weight_decay=0.0)


class CommitteeTrainer:
    """One-dispatch K-member retraining on a device-resident replay ring.

    ``loss_fn(params, batch) -> (loss, aux_dict)`` is a SINGLE member's
    loss over a minibatch ``{"x": (B, dx), "y": (B, dy)}`` — the same
    signature ``make_train_step`` consumes; the trainer vmaps it over the
    committee axis.  ``cparams`` is the stacked committee
    (``committee.stack_members``), typically the very pytree handed to the
    acquisition engine via ``CommitteeSpec``.
    """

    def __init__(
        self,
        loss_fn: Callable[[Any, Dict[str, jnp.ndarray]],
                          Tuple[jnp.ndarray, Dict]],
        cparams: Any,
        *,
        steps: int = 200,
        batch: int = 32,
        lr: float = 1e-3,
        bootstrap: bool = True,
        replay_capacity: int = 2048,
        train_cfg: Optional[TrainConfig] = None,
        mesh=None,
        sharding_rules=None,
        seed: int = 0,
        monitor=None,
        memory_policy: Union[str, MemoryPolicy, None] = None,
    ):
        self.size = committee_size(cparams)
        self.steps = int(steps)
        self.batch = int(batch)
        self.bootstrap = bool(bootstrap)
        self.monitor = monitor
        tcfg = train_cfg if train_cfg is not None else default_train_config(lr)
        policy = resolve_policy(memory_policy)
        if policy is None:
            # legacy path: derive the effective policy from TrainConfig so
            # snapshots always carry storage metadata, but leave tcfg alone
            fmt = resolve_moments(getattr(tcfg, "opt_moments", ""),
                                  tcfg.quantized_opt_state)
            policy = MemoryPolicy(name=fmt, moments=fmt)
        else:
            tcfg = dataclasses.replace(
                tcfg, opt_moments=policy.moments,
                quantized_opt_state=(policy.moments == "int8"))
        self.policy = policy
        # the replay ring must live where the train step runs: on a mesh,
        # `_write`'s jit output would otherwise commit the ring to device 0
        # and every mesh-sharded step would reshard it in its prologue
        # (or fail placement outright at >= 2 devices)
        ring_sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            ring_sharding = NamedSharding(mesh, P())
        self.replay = ReplayTrainingBuffer(replay_capacity,
                                           dtype=policy.replay_dtype,
                                           sharding=ring_sharding)
        self._member_step = make_train_step(loss_fn, tcfg)
        if policy.params_dtype != "float32":
            pd = jnp.dtype(policy.params_dtype)
            cparams = jax.tree.map(
                lambda x: x.astype(pd)
                if jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating) else x,
                cparams)

        # stacked TrainState: every leaf (step, params, mu, nu) grows a
        # leading K axis; adamw moments start as zeros_like(params) so the
        # stack preserves the committee layout of cparams itself
        states = [make_train_state(member(cparams, i), tcfg)
                  for i in range(self.size)]
        cstate = jax.tree.map(lambda *xs: jnp.stack(xs), *states)

        self.mesh = mesh
        self._mesh_rules = None
        if mesh is not None:
            from repro.sharding.rules import (MeshRules, committee_shardings,
                                              warn_fallbacks)

            self._mesh_rules = MeshRules(mesh, sharding_rules)
            cstate = jax.device_put(
                cstate, committee_shardings(self._mesh_rules, cstate))
            warn_fallbacks(self._mesh_rules, "CommitteeTrainer")
        self.cstate = cstate

        # donation keeps steady-state training alloc-free off-CPU; it also
        # means published params MUST be copied before the next step frees
        # them (snapshot_cparams handles that)
        self._donate = jax.default_backend() != "cpu"
        self._key = jax.random.PRNGKey(seed)
        self._step_seq = 0              # RNG cursor: one fold per step
        self.steps_done = 0
        self.rounds = 0
        self._last_metrics: Optional[Dict[str, Any]] = None
        # (K,) bool verdict of the last trained round's final step: False
        # entries are members whose step was rolled back (non-finite loss
        # or params) — the trainer-side quarantine signal
        self.last_member_ok: Optional[np.ndarray] = None
        # round lock: serializes whole train() rounds (trainer loop vs
        # warm-start/consolidation callers)
        self._lock = threading.Lock()
        # state lock: guards every cstate/replay-handle transition at STEP
        # granularity — held across each fused dispatch (which donates and
        # replaces the state buffers), across state_dict's host snapshot
        # (so a concurrent checkpoint can neither read a torn
        # params/_step_seq pair nor np.asarray a buffer the next step just
        # donated away), and across replay appends (which donate and
        # replace the ring buffers a queued step would otherwise re-use)
        self._state_lock = threading.Lock()
        self._fused = self._build_step()
        self._idx_fn = jax.jit(self._draw_indices)

    # ------------------------------------------------------------- compile
    def _draw_indices(self, key, size):
        """(K, B) bootstrap minibatch indices for one step.  Per-member key
        folds keep members decorrelated; ``bootstrap=False`` replays ONE
        draw to every member (same data order — the parity baseline)."""
        size_c = jnp.maximum(size, 1)
        if self.bootstrap:
            keys = jax.random.split(key, self.size)
            return jax.vmap(
                lambda k: jax.random.randint(k, (self.batch,), 0, size_c)
            )(keys)
        one = jax.random.randint(key, (self.batch,), 0, size_c)
        return jnp.tile(one[None], (self.size, 1))

    def _build_step(self):
        def member_ok(new_state, loss):
            """(K,) finite check for loss AND every post-update param leaf
            — a NaN/Inf anywhere means that member's step diverged."""
            ok = jnp.isfinite(loss)
            for leaf in jax.tree.leaves(new_state.params):
                ok = ok & jnp.all(
                    jnp.isfinite(leaf), axis=tuple(range(1, leaf.ndim)))
            return ok

        def fused(cstate, xb, yb, size, key):
            idx = self._draw_indices(key, size)             # (K, B)
            # (K, B, d) gather; cast back to fp32 ON DEVICE so a bf16
            # replay ring never leaks its storage dtype into the loss math
            mb = {"x": xb[idx].astype(jnp.float32),
                  "y": yb[idx].astype(jnp.float32)}
            new_state, metrics = jax.vmap(self._member_step)(cstate, mb)
            # per-member quarantine: a member whose step produced a
            # non-finite loss or any non-finite parameter is rolled back to
            # its pre-step state (params, Adam moments AND step counter) via
            # jnp.where inside the SAME dispatch — healthy members advance,
            # nothing extra crosses to host, no retrace
            ok = member_ok(new_state, metrics["loss"])      # (K,)

            def keep(new, old):
                sel = ok.reshape((ok.shape[0],) + (1,) * (new.ndim - 1))
                return jnp.where(sel, new, old)

            rolled = jax.tree.map(keep, new_state, cstate)
            metrics = dict(metrics)
            metrics["member_ok"] = ok
            return rolled, metrics

        kw: Dict[str, Any] = {}
        if self._donate:
            kw["donate_argnums"] = (0,)
        if self._mesh_rules is not None:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P

            from repro.sharding.rules import committee_shardings

            rep = NamedSharding(self._mesh_rules.mesh, P())
            cs = committee_shardings(self._mesh_rules, self.cstate)
            # metrics subtree: a single replicated sharding works as a
            # pytree prefix for whatever aux dict the loss emits
            kw["in_shardings"] = (cs, rep, rep, rep, rep)
            kw["out_shardings"] = (cs, rep)
        return jax.jit(fused, **kw)

    # ---------------------------------------------------------------- data
    def add_blocks(self, datapoints: Sequence[Tuple[np.ndarray, np.ndarray]]):
        """Absorb a Manager-released ``retrain_size`` block of
        (input, label) pairs into the device replay ring (one transfer).
        Safe concurrently with a running train round: the state lock keeps
        the append's buffer donation from invalidating the ring handles a
        step in flight is about to dispatch with (appends that bypass the
        trainer and hit ``replay.append`` directly do not get this
        protection)."""
        if not datapoints:
            return
        xs = [np.asarray(x, np.float32).reshape(-1) for x, _ in datapoints]
        ys = [np.asarray(y, np.float32).reshape(-1) for _, y in datapoints]
        with self._state_lock:
            self.replay.append(np.stack(xs), np.stack(ys))

    def minibatch_indices(self, step_seq: int, size: int) -> np.ndarray:
        """Host view of the (K, B) indices step ``step_seq`` draws — the
        EXACT computation the fused step runs (same key fold), so
        sequential parity baselines can replay the identical data order."""
        key = jax.random.fold_in(self._key, step_seq)
        return np.asarray(self._idx_fn(key, np.int32(size)))

    # --------------------------------------------------------------- train
    def train(self, interrupt=None, steps: Optional[int] = None
              ) -> Dict[str, np.ndarray]:
        """Advance all K members ``steps`` fused steps (default: the
        configured per-round budget).  ``interrupt`` is the transport
        Request of the NEXT pending data block — training yields early the
        moment new labels arrive, like the paper's ``retrain`` loop.
        Returns the last step's per-member metrics (host numpy)."""
        n_steps = self.steps if steps is None else int(steps)
        with self._lock:
            if len(self.replay) == 0 or n_steps <= 0:
                return {}
            metrics = None
            done = 0
            for _ in range(n_steps):
                # per-step state lock: the ring handles are re-fetched
                # inside it so a concurrent add_blocks (which donates and
                # replaces the buffers) can never leave this step holding
                # a deleted array, and a concurrent state_dict sees a
                # consistent (cstate, _step_seq) pair
                with self._state_lock:
                    xb, yb, size = self.replay.arrays()
                    key = jax.random.fold_in(self._key, self._step_seq)
                    self._step_seq += 1
                    self.cstate, metrics = self._fused(
                        self.cstate, xb, yb, np.int32(size), key)
                    self.steps_done += 1
                done += 1
                if interrupt is not None and interrupt.test():
                    break
            self.rounds += 1
            self._last_metrics = metrics
            if self.monitor is not None:
                self.monitor.incr("train.fused_steps", done)
        out = jax.tree.map(np.asarray, metrics)
        # rollback accounting rides the round's existing host conversion —
        # zero extra device syncs (the per-step mask never leaves the chip
        # mid-round; only the final step's verdict is inspected here)
        ok = out.get("member_ok") if isinstance(out, dict) else None
        if ok is not None:
            self.last_member_ok = np.asarray(ok, bool)
            bad = int((~self.last_member_ok).sum())
            if bad and self.monitor is not None:
                self.monitor.incr("train.member_rollbacks", bad)
        return out

    # ------------------------------------------------------------- weights
    @property
    def cparams(self) -> Any:
        """The live stacked committee params (leading K axis)."""
        return self.cstate.params

    def snapshot_cparams(self) -> Any:
        """Donation-safe stacked params for device-to-device handoff to the
        acquisition engine: when the train step donates its state buffers,
        the published pytree must be copied on device before the next step
        invalidates it; without donation the live buffers are immutable and
        handed out as-is.  Either way nothing touches the host."""
        with self._state_lock:
            if not self._donate:
                return self.cstate.params
            return jax.tree.map(lambda a: jnp.array(a, copy=True),
                                self.cstate.params)

    def poison_member(self, i: int):
        """Chaos/test hook: overwrite member ``i``'s parameters with NaN —
        the observable signature of a diverged member.  Downstream, the
        fused step's per-member quarantine rolls back every subsequent
        update for that member (it stays NaN, never contaminating the
        others) and the acquisition kernel's degraded-K statistics exclude
        it from scoring once the poisoned weights publish."""
        if not 0 <= int(i) < self.size:
            raise ValueError(f"member index {i} out of range 0..{self.size - 1}")
        with self._state_lock:
            onehot = jnp.arange(self.size) == int(i)
            params = jax.tree.map(
                lambda leaf: jnp.where(
                    onehot.reshape((self.size,) + (1,) * (leaf.ndim - 1)),
                    jnp.nan, leaf),
                self.cstate.params)
            self.cstate = self.cstate._replace(params=params)
        if self.monitor is not None:
            self.monitor.incr("train.members_poisoned")

    # ---------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict[str, Any]:
        """FULL training snapshot: TrainState (params + AdamW mu/nu + step),
        RNG cursor, and the replay ring — a restore continues mid-schedule
        instead of resetting Adam moments.  Takes the state lock, so a
        checkpoint fired mid-round (``PAL.checkpoint`` from the manager
        thread) snapshots a consistent (cstate, RNG-cursor) pair and the
        host conversion finishes before the next step can donate the
        buffers away."""
        with self._state_lock:
            # QTensor moments snapshot NATIVELY: tree.map hits their int8
            # ``q`` / fp32 ``scale`` leaves, never a dequantized fp32 blob
            return {
                "cstate": jax.tree.map(np.asarray, self.cstate),
                "memory_policy": dataclasses.asdict(self.policy),
                "step_seq": self._step_seq,
                "steps_done": self.steps_done,
                "rounds": self.rounds,
                "replay": self.replay.state_dict(),
            }

    @staticmethod
    def _snapshot_formats(cstate) -> Optional[Dict[str, str]]:
        """Infer {moments, params_dtype} from a snapshot's leaves (legacy
        snapshots carry no policy metadata).  None if the structure is too
        foreign to inspect — the structural check below handles that."""
        try:
            mu_leaves = jax.tree.leaves(
                cstate.opt.mu, is_leaf=lambda x: isinstance(x, QTensor))
            p_leaves = jax.tree.leaves(cstate.params)
        except AttributeError:
            return None
        if any(isinstance(l, QTensor) for l in mu_leaves):
            moments = "int8"
        elif any(np.asarray(l).dtype == jnp.bfloat16
                 for l in jax.tree.leaves(cstate.opt.mu)):
            moments = "bf16"
        else:
            moments = "fp32"
        params_dtype = ("bfloat16" if any(
            np.asarray(l).dtype == jnp.bfloat16 for l in p_leaves)
            else "float32")
        return {"moments": moments, "params_dtype": params_dtype}

    def load_state_dict(self, state: Dict[str, Any]):
        """Restore a ``state_dict`` snapshot if it structurally matches the
        current committee; mismatches (different K, param shapes, or
        optimizer layout) are skipped with a warning — training re-starts
        from the constructor state instead of crashing at trace time.

        A MEMORY-POLICY mismatch is different: the snapshot is valid data
        in another storage format, and silently re-quantizing (or worse,
        reinterpreting sqrt-space int8 nu as fp32) would corrupt the run —
        so it raises ``ValueError`` instead."""
        restored = jax.tree.map(jnp.asarray, state["cstate"])
        snap_policy = state.get("memory_policy")
        if snap_policy is None:
            snap_policy = self._snapshot_formats(restored)
        if snap_policy is not None:
            mine = {"moments": self.policy.moments,
                    "params_dtype": self.policy.params_dtype}
            bad = {k: (snap_policy[k], mine[k]) for k in mine
                   if k in snap_policy and snap_policy[k] != mine[k]}
            if bad:
                raise ValueError(
                    "committee-trainer snapshot memory policy does not "
                    "match the configured policy — refusing to silently "
                    "re-format optimizer state: "
                    + ", ".join(f"{k}: snapshot={s!r} vs config={c!r}"
                                for k, (s, c) in sorted(bad.items()))
                    + ". Restore with a matching memory_policy (or retrain "
                    "from scratch).")
        cur_leaves, cur_def = jax.tree.flatten(self.cstate)
        new_leaves, new_def = jax.tree.flatten(restored)
        if cur_def != new_def or any(
                np.shape(a) != np.shape(b)
                for a, b in zip(cur_leaves, new_leaves)):
            log.warning(
                "committee-trainer snapshot does not match the current "
                "committee (%s vs %s) — skipping restore, training state "
                "starts fresh", new_def, cur_def)
            return
        if self._mesh_rules is not None:
            from repro.sharding.rules import committee_shardings

            restored = jax.device_put(
                restored, committee_shardings(self._mesh_rules, restored))
        with self._state_lock:
            self.cstate = restored
            self._step_seq = int(state.get("step_seq", 0))
            self.steps_done = int(state.get("steps_done", 0))
            self.rounds = int(state.get("rounds", 0))
            self.replay.load_state_dict(state.get("replay", {}))
