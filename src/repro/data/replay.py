"""AL replay buffer for the LM path: oracle-labeled sequences accumulate and
are sampled into fixed-shape training batches (pads/crops to seq_len).

This is the datacenter-scale analog of the paper's training-data buffer —
the PAL Manager releases retrain_size blocks into it, and the trainer draws
uniform (or recency-weighted) minibatches.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np


class ALReplayBuffer:
    def __init__(self, capacity: int, seq_len: int, recency_bias: float = 0.0):
        self.capacity = capacity
        self.seq_len = seq_len
        self.recency_bias = recency_bias
        self._tokens: List[np.ndarray] = []
        self._lock = threading.Lock()
        self.total_added = 0
        self.evicted = 0

    def add(self, sequences: List[np.ndarray]):
        with self._lock:
            self._tokens.extend(np.asarray(s, np.int32) for s in sequences)
            self.total_added += len(sequences)
            if len(self._tokens) > self.capacity:
                k = len(self._tokens) - self.capacity
                self._tokens = self._tokens[k:]
                self.evicted += k

    def __len__(self):
        with self._lock:
            return len(self._tokens)

    def sample(self, batch: int, rng: np.random.RandomState
               ) -> Optional[Dict[str, np.ndarray]]:
        with self._lock:
            n = len(self._tokens)
            if n == 0:
                return None
            if self.recency_bias > 0:
                w = np.exp(self.recency_bias
                           * (np.arange(n) - n + 1) / max(n, 1))
                p = w / w.sum()
            else:
                p = None
            idx = rng.choice(n, size=batch, replace=n < batch, p=p)
            seqs = [self._tokens[i] for i in idx]
        out = np.zeros((batch, self.seq_len + 1), np.int32)
        for i, s in enumerate(seqs):
            L = min(len(s), self.seq_len + 1)
            out[i, :L] = s[:L]
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}
