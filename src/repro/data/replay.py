"""AL replay buffers.

``ReplayTrainingBuffer`` — the committee-training subsystem's data plane
(training/committee_trainer.py): labeled rows live in fixed-capacity DEVICE
arrays.  The PAL Manager releases ``retrain_size`` blocks; each block is
ONE host->device transfer (appended via a jitted donated
``dynamic_update_slice``, wraparound ring semantics), and every train step
gathers its per-member bootstrap minibatches on device — no per-step
host->device traffic at all.

``ALReplayBuffer`` — the LM path's host-side sequence buffer: oracle-labeled
sequences accumulate and are sampled into fixed-shape training batches
(pads/crops to seq_len), uniform or recency-weighted.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple

import numpy as np


class ReplayTrainingBuffer:
    """Fixed-capacity device-resident (x, y) training store.

    Rows are flattened 1-D per sample in a configurable storage ``dtype``
    (``float32`` default; ``bfloat16`` halves the ring's device footprint —
    the big-committee memory-diet knob).  Rows are cast to the storage
    dtype ON HOST before the block transfer (half the append bytes too) and
    the fused train step gathers minibatches back to fp32 on device, so
    the loss math never sees the narrow dtype.  Feature widths are fixed by
    the first appended block.  Appends write a contiguous block into a ring
    (oldest rows overwritten once full) through a jitted
    ``dynamic_update_slice`` whose destination buffer is DONATED where the
    backend supports aliasing — steady-state appends allocate nothing and
    the training arrays never round-trip to host.  ``arrays()`` hands the
    raw device buffers plus the valid-row count to the fused train step,
    which samples minibatches by on-device gather.

    One writer (the committee-trainer loop) is the expected pattern.  The
    internal lock serializes appends against ``arrays()``/snapshots, but
    because appends DONATE the ring buffers, an append concurrent with a
    running train round must go through ``CommitteeTrainer.add_blocks``,
    whose state lock keeps the donation from invalidating the buffer
    handles a step in flight is about to dispatch with.
    """

    def __init__(self, capacity: int, dtype: str = "float32",
                 sharding=None):
        assert capacity > 0
        self.capacity = int(capacity)
        self.dtype = str(dtype)         # storage dtype (gathers are fp32)
        # optional jax.sharding.Sharding for the ring buffers.  Without it,
        # `_write`'s jit output is COMMITTED to the default device — fine
        # single-device, but a >= 2-device CommitteeTrainer then feeds a
        # device-0-committed ring into a mesh-sharded train step and pays
        # a reshard (or placement error) per step.  The trainer passes its
        # mesh's replicated sharding so the ring lives mesh-wide from the
        # first append and every snapshot restore.
        self._sharding = sharding
        self._x = None                  # (capacity, dx) in storage dtype
        self._y = None                  # (capacity, dy) in storage dtype
        self._cursor = 0
        self._size = 0
        self._lock = threading.Lock()
        self.total_added = 0
        self.append_blocks = 0
        self.bytes_to_device = 0
        self._write = None

    def _init_write(self):
        import jax

        donate = jax.default_backend() != "cpu"
        kw = {"donate_argnums": (0,)} if donate else {}

        def write(buf, block, start):
            return jax.lax.dynamic_update_slice_in_dim(buf, block, start, 0)

        if self._sharding is not None:
            kw["out_shardings"] = self._sharding
        self._write = jax.jit(write, **kw)

    def _place(self, buf):
        if self._sharding is None:
            return buf
        import jax

        return jax.device_put(buf, self._sharding)

    def _storage_dtype(self):
        """numpy-compatible storage dtype (ml_dtypes backs bfloat16)."""
        import jax.numpy as jnp

        return jnp.dtype(self.dtype)

    def append(self, xs, ys) -> int:
        """Append matching (n, dx)/(n, dy) host blocks; returns n kept.
        Rows are cast to the storage dtype on host, so a bf16 ring also
        halves the host->device bytes of every block append."""
        import jax.numpy as jnp

        dt = self._storage_dtype()
        xs = np.asarray(xs, np.float32).reshape(len(xs), -1).astype(dt)
        ys = np.asarray(ys, np.float32).reshape(len(ys), -1).astype(dt)
        if len(xs) != len(ys):
            raise ValueError(f"x/y row mismatch: {len(xs)} vs {len(ys)}")
        if len(xs) == 0:
            return 0
        if len(xs) > self.capacity:     # only the newest rows can survive
            xs, ys = xs[-self.capacity:], ys[-self.capacity:]
        with self._lock:
            if self._x is None:
                self._init_write()
                self._x = self._place(jnp.zeros((self.capacity,
                                                 xs.shape[1]), dt))
                self._y = self._place(jnp.zeros((self.capacity,
                                                 ys.shape[1]), dt))
            if (xs.shape[1] != self._x.shape[1]
                    or ys.shape[1] != self._y.shape[1]):
                raise ValueError(
                    f"row width changed: got ({xs.shape[1]}, {ys.shape[1]}),"
                    f" buffer holds ({self._x.shape[1]}, {self._y.shape[1]})")
            n = len(xs)
            head = min(n, self.capacity - self._cursor)
            self._x = self._write(self._x, jnp.asarray(xs[:head]),
                                  self._cursor)
            self._y = self._write(self._y, jnp.asarray(ys[:head]),
                                  self._cursor)
            if head < n:                # ring wraparound: rest lands at 0
                self._x = self._write(self._x, jnp.asarray(xs[head:]), 0)
                self._y = self._write(self._y, jnp.asarray(ys[head:]), 0)
            self._cursor = (self._cursor + n) % self.capacity
            self._size = min(self.capacity, self._size + n)
            self.total_added += n
            self.append_blocks += 1
            self.bytes_to_device += xs.nbytes + ys.nbytes
            return n

    def arrays(self):
        """(x_buf, y_buf, valid_rows) — raw device buffers for the fused
        train step; rows past ``valid_rows`` are zero padding the sampler
        never indexes."""
        with self._lock:
            return self._x, self._y, self._size

    def __len__(self):
        with self._lock:
            return self._size

    def state_dict(self) -> Dict[str, np.ndarray]:
        with self._lock:
            if self._x is None:
                return {"size": 0, "dtype": self.dtype}
            # rows snapshot in the STORAGE dtype (no widen-on-save blowup)
            return {"x": np.asarray(self._x), "y": np.asarray(self._y),
                    "cursor": self._cursor, "size": self._size,
                    "total_added": self.total_added, "dtype": self.dtype}

    def load_state_dict(self, state):
        import jax.numpy as jnp

        with self._lock:
            if not state or int(state.get("size", 0)) == 0:
                return
            if self._write is None:
                self._init_write()
            # snapshot wins on resume: capacity AND storage dtype (legacy
            # f32 snapshots restore as f32 rings regardless of the knob)
            self.dtype = str(state.get("dtype",
                                       np.asarray(state["x"]).dtype))
            dt = self._storage_dtype()
            self._x = self._place(jnp.asarray(np.asarray(state["x"])
                                              .astype(dt)))
            self._y = self._place(jnp.asarray(np.asarray(state["y"])
                                              .astype(dt)))
            self.capacity = int(self._x.shape[0])
            self._cursor = int(state["cursor"])
            self._size = int(state["size"])
            self.total_added = int(state.get("total_added", self._size))


class ALReplayBuffer:
    def __init__(self, capacity: int, seq_len: int, recency_bias: float = 0.0):
        self.capacity = capacity
        self.seq_len = seq_len
        self.recency_bias = recency_bias
        self._tokens: List[np.ndarray] = []
        self._lock = threading.Lock()
        self.total_added = 0
        self.evicted = 0

    def add(self, sequences: List[np.ndarray]):
        with self._lock:
            self._tokens.extend(np.asarray(s, np.int32) for s in sequences)
            self.total_added += len(sequences)
            if len(self._tokens) > self.capacity:
                k = len(self._tokens) - self.capacity
                self._tokens = self._tokens[k:]
                self.evicted += k

    def __len__(self):
        with self._lock:
            return len(self._tokens)

    def sample(self, batch: int, rng: np.random.RandomState
               ) -> Optional[Dict[str, np.ndarray]]:
        with self._lock:
            n = len(self._tokens)
            if n == 0:
                return None
            if self.recency_bias > 0:
                w = np.exp(self.recency_bias
                           * (np.arange(n) - n + 1) / max(n, 1))
                p = w / w.sum()
            else:
                p = None
            idx = rng.choice(n, size=batch, replace=n < batch, p=p)
            seqs = [self._tokens[i] for i in idx]
        out = np.zeros((batch, self.seq_len + 1), np.int32)
        for i, s in enumerate(seqs):
            L = min(len(s), self.seq_len + 1)
            out[i, :L] = s[:L]
        return {"tokens": out[:, :-1], "labels": out[:, 1:]}
