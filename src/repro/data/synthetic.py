"""Deterministic synthetic token streams (training substrate).

Tokens are a cheap stateless hash of (seed, step, batch row, position) so
any worker can materialize its own shard without coordination, restarts are
bit-exact (resume at `step`), and per-dp-rank sharding is a pure slice.
Frontend-stub inputs (whisper frames / internvl patches) come from the same
counter-hash path as uniform floats.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

_M = np.uint64(0x9E3779B97F4A7C15)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    x = (x + _M).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _hash_grid(seed: int, step: int, rows: np.ndarray,
               cols: np.ndarray) -> np.ndarray:
    with np.errstate(over="ignore"):  # uint64 wraparound is the point
        base = np.uint64(seed) * np.uint64(0x2545F4914F6CDD1D) + \
            np.uint64(step) * np.uint64(0x100000001B3)
        grid = (rows[:, None].astype(np.uint64) << np.uint64(32)) \
            | cols[None, :].astype(np.uint64)
        return _splitmix64(grid + base)


def synthetic_tokens(seed: int, step: int, batch: int, seq: int,
                     vocab: int, row_offset: int = 0) -> np.ndarray:
    rows = np.arange(row_offset, row_offset + batch)
    cols = np.arange(seq + 1)
    h = _hash_grid(seed, step, rows, cols)
    return (h % np.uint64(vocab)).astype(np.int32)


def synthetic_floats(seed: int, step: int, shape: Tuple[int, ...],
                     scale: float = 1.0) -> np.ndarray:
    n = int(np.prod(shape))
    h = _hash_grid(seed ^ 0x5F0F, step, np.arange(1), np.arange(n))[0]
    u = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    return ((u * 2.0 - 1.0) * scale).astype(np.float32).reshape(shape)


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, step: int,
                    seed: int = 0, dp_rank: int = 0,
                    dp_size: int = 1) -> Dict[str, np.ndarray]:
    """One training batch shard for (arch, shape) at `step`.

    tokens/labels are the usual shifted pair; modality stubs are attached
    per family.  dp sharding slices the global batch.
    """
    gb = shape.global_batch
    assert gb % dp_size == 0, (gb, dp_size)
    b = gb // dp_size
    off = dp_rank * b
    seq = shape.seq_len
    if cfg.family == "vlm":
        t_text = seq - cfg.vision_tokens
        grid = synthetic_tokens(seed, step, b, t_text, cfg.vocab_size, off)
        batch = {"tokens": grid[:, :-1], "labels": grid[:, 1:]}
        batch["patch_embeds"] = synthetic_floats(
            seed, step, (b, cfg.vision_tokens, cfg.d_model), 0.02)
        return batch
    grid = synthetic_tokens(seed, step, b, seq, cfg.vocab_size, off)
    batch = {"tokens": grid[:, :-1], "labels": grid[:, 1:]}
    if cfg.family == "encdec":
        batch["enc_embeds"] = synthetic_floats(
            seed, step, (b, cfg.encoder_seq, cfg.d_model), 0.02)
    return batch


@dataclasses.dataclass
class SyntheticTokenStream:
    """Stateful iterator over synthetic_batch, resumable at any step."""

    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    dp_rank: int = 0
    dp_size: int = 1
    step: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = synthetic_batch(self.cfg, self.shape, self.step, self.seed,
                            self.dp_rank, self.dp_size)
        self.step += 1
        return b

    def state_dict(self):
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, s):
        self.step = int(s["step"])
        self.seed = int(s["seed"])
