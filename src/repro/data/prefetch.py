"""Host-side prefetch: a background thread keeps a small queue of ready
batches so input materialization overlaps the device step (double buffering
by default)."""
from __future__ import annotations

import queue
import threading
from typing import Any, Iterator, Optional


class Prefetcher:
    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._it = it
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._exc: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                while not self._stop.is_set():
                    try:
                        self._q.put(item, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
            self._q.put(StopIteration)
        except BaseException as e:  # surfaced on next()
            self._exc = e
            self._q.put(StopIteration)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is StopIteration:
            if self._exc is not None:
                raise self._exc
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
