from repro.data.synthetic import SyntheticTokenStream, synthetic_batch  # noqa: F401
from repro.data.prefetch import Prefetcher  # noqa: F401
from repro.data.replay import ALReplayBuffer  # noqa: F401
