"""Multi-process launch path: one PAL run spanning hosts via
``jax.distributed`` — the paper's MPI deployment story, re-done as
jit-native collectives.

The paper runs its four kernels as MPI ranks wired by explicit
send/recv.  Here a *process* is just more devices in the same SPMD
program: every process calls :func:`initialize` (coordinator address +
process id/count), after which ``jax.devices()`` spans all hosts and the
SAME fused dispatches (``FusedEngine.score``, ``CommitteeTrainer`` step)
lay themselves out over the global mesh — XLA inserts the cross-host
collectives, no hand-written exchange protocol.

On CPU the cross-process collectives need a backend; jax ships gloo,
which :func:`initialize` selects by default (``jax_cpu_collectives_
implementation``) — this is what the 2-process CI smoke test exercises.

Order of operations in a launcher::

    from repro.launch import distributed, platform
    platform.configure(host_devices=cfg.host_devices)   # XLA_FLAGS first
    distributed.initialize_from_config(cfg)             # before device use
    mesh = make_scaleout_mesh()                         # spans all hosts

CLI (one process of a multi-host launch; also the CI smoke worker)::

    python -m repro.launch.distributed --coordinator 127.0.0.1:9911 \
        --processes 2 --process-id 0 --demo
"""
from __future__ import annotations

import argparse
import logging
import os
from typing import Optional

log = logging.getLogger(__name__)

_initialized = False


def is_initialized() -> bool:
    return _initialized


def initialize(coordinator: str, num_processes: int, process_id: int,
               *, cpu_collectives: str = "gloo") -> None:
    """Join this process to a multi-process jax runtime.

    Must run before any jax device use (backend init binds the device
    topology).  ``coordinator`` is ``'host:port'`` of process 0 — jax's
    built-in coordination service, no external launcher needed.
    Idempotent per process; a second call with a live runtime raises
    (jax cannot re-initialize a distributed backend).
    """
    global _initialized
    if _initialized:
        raise RuntimeError("jax.distributed is already initialized in this "
                           "process")
    import jax

    if cpu_collectives:
        # CPU cross-process collectives need an explicit implementation
        # (gloo is bundled); harmless on GPU/TPU which bring their own
        jax.config.update("jax_cpu_collectives_implementation",
                          cpu_collectives)
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=int(num_processes),
        process_id=int(process_id),
    )
    _initialized = True
    log.info("jax.distributed up: process %d/%d, %d global / %d local "
             "device(s)", jax.process_index(), jax.process_count(),
             jax.device_count(), jax.local_device_count())


def _env_process_id() -> int:
    for var in ("PAL_PROCESS_ID", "JAX_PROCESS_ID"):
        v = os.environ.get(var, "")
        if v:
            return int(v)
    return -1


def initialize_from_config(run_cfg) -> bool:
    """Initialize the multi-process runtime from ``PALRunConfig`` knobs.

    Returns False (no-op) when ``dist_coordinator`` is empty — the
    single-process path stays the default and costs nothing.  The process
    id comes from ``dist_process_id`` or, when that is -1, the
    ``PAL_PROCESS_ID`` / ``JAX_PROCESS_ID`` env vars (so one config file
    serves every rank of a launch).
    """
    coordinator = getattr(run_cfg, "dist_coordinator", "") or ""
    if not coordinator:
        return False
    nproc = int(getattr(run_cfg, "dist_processes", 0))
    if nproc <= 0:
        raise ValueError("dist_coordinator is set but dist_processes is "
                         f"{nproc}; need the total process count")
    pid = int(getattr(run_cfg, "dist_process_id", -1))
    if pid < 0:
        pid = _env_process_id()
    if pid < 0:
        raise ValueError(
            "dist_process_id is -1 and neither PAL_PROCESS_ID nor "
            "JAX_PROCESS_ID is set — every rank needs a distinct id")
    initialize(coordinator, nproc, pid,
               cpu_collectives=getattr(run_cfg, "dist_cpu_collectives",
                                       "gloo"))
    return True


def demo(rows_per_process: int = 4) -> float:
    """Cross-process collective check: shard a global row batch over every
    device in the launch, reduce it inside one jit, and return the global
    sum (identical on every process).  The CI smoke test asserts the value
    so a silently-degraded launch (processes not actually joined) fails
    loudly rather than computing per-process answers.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, NamedSharding
    from jax.sharding import PartitionSpec as P

    devs = jax.devices()                      # GLOBAL device list
    mesh = Mesh(np.array(devs).reshape(len(devs), 1), ("data", "model"))
    n = rows_per_process * jax.process_count() * jax.local_device_count()
    # globally-known input: every process constructs the same array and
    # jax shards it — rank i's devices hold rows i*chunk:(i+1)*chunk
    x = jnp.arange(n, dtype=jnp.float32)
    xs = jax.device_put(x, NamedSharding(mesh, P("data")))

    total = jax.jit(jnp.sum, out_shardings=NamedSharding(mesh, P()))(xs)
    return float(total)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="one process of a multi-host PAL launch")
    ap.add_argument("--coordinator", required=True,
                    help="host:port of process 0")
    ap.add_argument("--processes", type=int, required=True)
    ap.add_argument("--process-id", type=int, default=-1,
                    help="-1: read PAL_PROCESS_ID / JAX_PROCESS_ID")
    ap.add_argument("--cpu-collectives", default="gloo")
    ap.add_argument("--demo", action="store_true",
                    help="run the cross-process collective check and print "
                         "'DIST_OK <procs> <devices> <sum>'")
    args = ap.parse_args(argv)

    pid = args.process_id if args.process_id >= 0 else _env_process_id()
    if pid < 0:
        ap.error("--process-id not given and PAL_PROCESS_ID/JAX_PROCESS_ID "
                 "unset")
    initialize(args.coordinator, args.processes, pid,
               cpu_collectives=args.cpu_collectives)
    if args.demo:
        import jax

        total = demo()
        print(f"DIST_OK {jax.process_count()} {jax.device_count()} "
              f"{total:.1f}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
