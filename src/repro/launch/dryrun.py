from repro.launch.platform import ensure_host_devices

ensure_host_devices(512)

# NOTE: the emulated-device request above MUST precede any jax import
# (device count locks on first backend init), so the docstring comes after;
# launch/platform.py is jax-import-free, keeping that ordering safe.
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with memory / cost / collective analysis.

This is how the distribution config is proven coherent without hardware
(deliverable (e)): a sharding mismatch, an unsupported collective, or an
absurd memory plan surfaces HERE as a failed compile or a pathological
analysis, not on a 512-chip reservation.

  train_4k                  -> lowers train_step (params+opt donated)
  prefill_32k               -> lowers prefill (batch -> logits + cache)
  decode_32k / long_500k    -> lowers serve_step (1 token vs seq_len cache,
                               cache donated; long_500k seq-shards the cache)

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]
"""
import argparse
import json
import os
import re
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as ax
from repro.configs import get_arch, get_shape, list_archs
from repro.configs.base import ArchSpec, ModelConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import common as cm
from repro.models import model_zoo
from repro.sharding.rules import MeshRules
from repro.training import make_train_state, make_train_step

# ---------------------------------------------------------------------------
# Sharding resolution
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def make_rules(spec: ArchSpec, shape: ShapeConfig, mesh,
               extra: Optional[Dict] = None) -> MeshRules:
    merged = dict(spec.rules)
    if shape.kind != "train":
        merged.update(spec.serve_rules)
    merged.update(shape.rule_overrides)
    if extra:
        merged.update(extra)
    return MeshRules(mesh, merged)


def spec_shardings(rules: MeshRules, specs) -> Any:
    """ParamSpec tree -> NamedSharding tree (divisibility-checked)."""
    return jax.tree.map(
        lambda s: rules.sharding(s.axes, s.shape, name=str(s.shape)),
        specs, is_leaf=cm.is_spec)


def abstract_tree(specs) -> Any:
    return jax.tree.map(lambda s: s.abstract(), specs, is_leaf=cm.is_spec)


def abstract_tree_bf16(specs) -> Any:
    """Serving-path params: inference weights ship in bf16 (fp32 master
    stays on the training side)."""
    def cast(s):
        a = s.abstract()
        if a.dtype == jnp.float32:
            return jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
        return a
    return jax.tree.map(cast, specs, is_leaf=cm.is_spec)


def batch_shardings(rules: MeshRules, batch_sds: Dict[str, Any]) -> Dict:
    out = {}
    for k, v in batch_sds.items():
        axes = (ax.BATCH,) + (None,) * (len(v.shape) - 1)
        out[k] = rules.sharding(axes, v.shape, name=k)
    return out


def state_shardings(rules: MeshRules, model, train_cfg) -> Tuple[Any, Any]:
    """(abstract TrainState, TrainState of NamedShardings)."""
    from repro.optim.adamw import QTensor
    from repro.training.train_step import TrainState
    from repro.optim.adamw import AdamWState

    specs = model.param_specs()
    p_sds = abstract_tree(specs)
    p_sh = spec_shardings(rules, specs)
    state_sds = jax.eval_shape(
        lambda p: make_train_state(p, train_cfg), p_sds)
    repl = rules.sharding((), ())

    if not train_cfg.quantized_opt_state:
        state_sh = TrainState(step=repl, params=p_sh,
                              opt=AdamWState(step=repl, mu=p_sh, nu=p_sh))
        return state_sds, state_sh

    def q_shard(spec: cm.ParamSpec):
        from repro.optim.adamw import quantize
        qt = jax.eval_shape(
            lambda: quantize(jnp.zeros(spec.shape, jnp.float32)))
        q_sh = rules.sharding(spec.axes, qt.q.shape, name="q" + str(spec.shape))
        # scale keeps the param's rank (blocked dim shrunk in place), so it
        # reuses the same logical axes; divisibility fallback handles the
        # shrunk dim when it no longer divides.
        s_axes = spec.axes if len(spec.shape) else ()
        s_sh = rules.sharding(s_axes, qt.scale.shape,
                              name="qs" + str(spec.shape))
        return QTensor(q=q_sh, scale=s_sh, block=qt.block, axis=qt.axis)

    m_sh = jax.tree.map(q_shard, specs, is_leaf=cm.is_spec)
    state_sh = TrainState(step=repl, params=p_sh,
                          opt=AdamWState(step=repl, mu=m_sh, nu=m_sh))
    return state_sds, state_sh


def committee_state_bytes(member_params, k: int, train_cfg=None,
                          policy=None) -> int:
    """Exact bytes of a K-member stacked committee ``TrainState``.

    The old estimate here was per-(single-)model only: it ignored committee
    stacking entirely and always priced fp32 moments, so a K=64 plan under-
    reported optimizer memory by K x and over-reported quantized runs ~4x.
    Delegates to ``optim/memory_policy.stacked_state_nbytes`` (eval_shape of
    the trainer's own constructor — QTensor scale arrays included).
    ``policy`` wins over ``train_cfg``; both absent means fp32."""
    from repro.optim.adamw import resolve_moments
    from repro.optim.memory_policy import (
        MemoryPolicy, resolve_policy, stacked_state_nbytes)

    p = resolve_policy(policy)
    if p is None:
        fmt = "fp32"
        if train_cfg is not None:
            fmt = resolve_moments(getattr(train_cfg, "opt_moments", ""),
                                  getattr(train_cfg, "quantized_opt_state",
                                          False))
        p = MemoryPolicy(name=fmt, moments=fmt)
    return stacked_state_nbytes(member_params, k, p)


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\ball-gather|\ball-reduce|\breduce-scatter|\ball-to-all|"
    r"\bcollective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


_TYPE_RE = re.compile(r"(\([^)]*\)|\S+)\s")


def _line_result_bytes(line: str) -> int:
    """Bytes of the result shape(s) on an HLO op line (per partition).

    Handles tuple result types — `(f32[..], f32[..]) all-reduce(...)` from
    XLA's collective combiner; naively splitting at the first '(' counted
    those as ZERO bytes (undercounting the collective term)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0
    m = _TYPE_RE.match(lhs[1])
    if not m:
        return 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Static per-device collective bytes by op kind (scan bodies count once
    — see roofline probe correction)."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m or " = " not in line:
            continue
        kind = m.group(1)
        b = _line_result_bytes(line)
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def sharded_bytes_per_device(sds_tree, sharding_tree, mesh) -> int:
    """Exact per-device resident bytes of a sharded pytree."""
    n_dev = mesh.devices.size
    leaves_s = jax.tree.leaves(sds_tree)
    leaves_sh = jax.tree.leaves(
        sharding_tree, is_leaf=lambda x: hasattr(x, "spec"))
    total = 0
    for sds, sh in zip(leaves_s, leaves_sh):
        nbytes = int(np.prod(sds.shape)) * jnp.dtype(sds.dtype).itemsize
        used = 1
        for entry in sh.spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                used *= mesh.shape[a]
        total += nbytes // max(used, 1)
    return total


def analyze_compiled(compiled, mesh) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["cost"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       ("flops" in k or "bytes" in k or "utilization" not in k)}
        out["flops"] = float(ca.get("flops", 0.0))
        out["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # noqa: BLE001
        out["cost_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        out["memory"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(ma, k)
        }
    except Exception as e:  # noqa: BLE001
        out["memory_error"] = repr(e)
    try:
        hlo = compiled.as_text()
        out["collectives"] = parse_collectives(hlo)
        out["hlo_bytes"] = len(hlo)
        out["hlo_collective_bytes_per_device"] = float(
            sum(v["bytes"] for v in out["collectives"].values()))
    except Exception as e:  # noqa: BLE001
        out["hlo_error"] = repr(e)
    return out


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def lower_cell(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mesh=None,
    rule_extra: Optional[Dict] = None,
    train_overrides: Optional[Dict] = None,
    model_overrides: Optional[Dict] = None,
    compile_it: bool = True,
) -> Dict[str, Any]:
    """Lower (and compile) one (arch x shape x mesh) cell; returns a report
    dict.  Raises on lowering/compile failure only if the failure is a bug
    (callers catch for the sweep report)."""
    spec = get_arch(arch_name)
    shape = get_shape(spec, shape_name)
    if shape_name in spec.skip_shapes:
        return {"arch": arch_name, "shape": shape_name,
                "skipped": spec.skip_shapes[shape_name]}
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    cfg = spec.model
    if model_overrides:
        cfg = cfg.replace(**model_overrides)
    train_cfg = spec.train
    if train_overrides:
        import dataclasses as _dc
        train_cfg = _dc.replace(train_cfg, **train_overrides)

    rules = make_rules(spec, shape, mesh, rule_extra)
    model = model_zoo.build_model(cfg, rules=rules, max_seq=shape.seq_len)
    report: Dict[str, Any] = {
        "arch": arch_name, "shape": shape_name,
        "mesh": dict(mesh.shape), "kind": shape.kind,
        "n_params": cm.count_params(model.param_specs()),
    }

    t0 = time.perf_counter()
    with mesh:
        if shape.kind == "train":
            state_sds, state_sh = state_shardings(rules, model, train_cfg)
            batch_sds = model_zoo.input_specs(cfg, shape)
            batch_sh = batch_shardings(rules, batch_sds)
            loss_fn = model_zoo.make_loss_fn(model)
            step = make_train_step(loss_fn, train_cfg)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
            resident = sharded_bytes_per_device(state_sds, state_sh, mesh)
        elif shape.kind == "prefill":
            specs = model.param_specs()
            p_sds, p_sh = abstract_tree_bf16(specs), spec_shardings(rules, specs)
            batch_sds = model_zoo.input_specs(cfg, shape)
            batch_sh = batch_shardings(rules, batch_sds)
            cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
            c_sds = abstract_tree(cache_specs)
            c_sh = spec_shardings(rules, cache_specs)
            fn = model_zoo.make_prefill_fn(model)
            jitted = jax.jit(fn, in_shardings=(p_sh, batch_sh, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(p_sds, batch_sds, c_sds)
            resident = (sharded_bytes_per_device(p_sds, p_sh, mesh)
                        + sharded_bytes_per_device(c_sds, c_sh, mesh))
        else:  # decode
            specs = model.param_specs()
            p_sds, p_sh = abstract_tree_bf16(specs), spec_shardings(rules, specs)
            dec = model_zoo.decode_input_specs(cfg, shape, model)
            cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
            c_sh = spec_shardings(rules, cache_specs)
            tok_sh = rules.sharding((ax.BATCH, None), dec["tokens"].shape)
            idx_sh = rules.sharding((), ())
            # keep the constraint inside the layer whenever the cache is
            # sequence-sharded (flash-decode-style distributed softmax)
            kv_seq_shard = bool(rules._mesh_axes_for(ax.CACHE_SEQ))
            fn = model_zoo.make_decode_fn(model, kv_seq_shard=kv_seq_shard)
            jitted = jax.jit(fn, in_shardings=(p_sh, tok_sh, c_sh, idx_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(p_sds, dec["tokens"], dec["cache"],
                                   dec["index"])
            resident = (sharded_bytes_per_device(p_sds, p_sh, mesh)
                        + sharded_bytes_per_device(dec["cache"], c_sh, mesh))
        report["lower_seconds"] = round(time.perf_counter() - t0, 2)
        report["resident_bytes_per_device"] = int(resident)
        report["resident_gib_per_device"] = round(resident / 2**30, 3)
        report["fallbacks"] = [
            f"{f.tensor} dim{f.dim} {f.logical}->{f.wanted}: {f.reason}"
            for f in rules.fallbacks]

        if compile_it:
            t1 = time.perf_counter()
            compiled = lowered.compile()
            report["compile_seconds"] = round(time.perf_counter() - t1, 2)
            report.update(analyze_compiled(compiled, mesh))
            report["compiled"] = True
    return report


# ---------------------------------------------------------------------------
# CLI sweep
# ---------------------------------------------------------------------------


def run_sweep(archs, shapes, multi_pod: bool, out_dir: str,
              stop_on_error: bool = False) -> Dict[str, Any]:
    os.makedirs(out_dir, exist_ok=True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_tag = "multipod" if multi_pod else "singlepod"
    results = []
    for a in archs:
        spec = get_arch(a)
        for s in shapes:
            if not any(sh.name == s for sh in spec.shapes):
                continue
            tag = f"{a}_{s}_{mesh_tag}"
            print(f"=== {tag} ===", flush=True)
            try:
                rep = lower_cell(a, s, multi_pod=multi_pod, mesh=mesh)
            except Exception as e:  # noqa: BLE001
                rep = {"arch": a, "shape": s, "error": repr(e),
                       "traceback": traceback.format_exc()}
                print(f"FAILED: {e!r}", flush=True)
                if stop_on_error:
                    raise
            results.append(rep)
            with open(os.path.join(out_dir, tag + ".json"), "w") as fh:
                json.dump(rep, fh, indent=1, default=str)
            if "skipped" in rep:
                print(f"skipped: {rep['skipped']}", flush=True)
            elif "error" not in rep:
                print(f"ok: {rep.get('resident_gib_per_device', '?')} GiB/dev, "
                      f"flops={rep.get('flops', 0):.3e}, "
                      f"lower={rep.get('lower_seconds')}s "
                      f"compile={rep.get('compile_seconds')}s", flush=True)
    summary = {
        "mesh": mesh_tag,
        "n_cells": len(results),
        "ok": sum(1 for r in results if r.get("compiled")),
        "skipped": sum(1 for r in results if "skipped" in r),
        "failed": sum(1 for r in results if "error" in r),
    }
    with open(os.path.join(out_dir, f"summary_{mesh_tag}.json"), "w") as fh:
        json.dump({"summary": summary, "results": results}, fh, indent=1,
                  default=str)
    print(json.dumps(summary))
    return summary


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default="results/dryrun")
    p.add_argument("--stop-on-error", action="store_true")
    args = p.parse_args()

    shapes = [args.shape] if args.shape else \
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = [args.arch] if args.arch else list_archs()
    if not (args.all or args.arch):
        p.error("pass --arch or --all")
    run_sweep(archs, shapes, args.multi_pod, args.out, args.stop_on_error)


if __name__ == "__main__":
    main()
