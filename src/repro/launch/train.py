"""End-to-end training driver (deliverable (b)).

Runs real steps on the host device(s): synthetic deterministic data,
AdamW + schedule, periodic async checkpoints with auto-resume, throughput
logging.  ``--preset smoke`` shrinks any assigned arch to a CPU-runnable
config; ``--preset 100m`` is the ~100M-param end-to-end run.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --preset 100m --steps 300 --batch 8 --seq 512
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer
from repro.configs import get_arch
from repro.configs.base import ShapeConfig, TrainConfig
from repro.data.prefetch import Prefetcher
from repro.data.synthetic import SyntheticTokenStream
from repro.models import model_zoo
from repro.training import TrainState, make_train_state, make_train_step

PRESETS = {
    # (layers, d_model, heads, kv, d_ff, vocab)
    "smoke": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                  d_ff=256, vocab_size=2048),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 d_ff=2048, vocab_size=32768),
}


def reduced_config(cfg, preset: str):
    if preset == "full":
        return cfg
    ov = dict(PRESETS[preset])
    ov["dtype"] = "float32"
    if cfg.family == "moe":
        ov.update(moe_num_experts=8, moe_top_k=2, moe_group_size=256,
                  moe_shared_d_ff=512)
    if cfg.family == "hybrid":
        ov.update(num_layers=8, mamba_head_dim=32, mamba_d_state=8,
                  moe_num_experts=4, moe_top_k=2, moe_group_size=256)
    if cfg.family == "rwkv6":
        d = ov["d_model"]
        ov.update(rwkv_head_dim=32, num_heads=d // 32, num_kv_heads=d // 32,
                  rwkv_lora_rank=16, rwkv_decay_lora_rank=16)
    if cfg.family == "encdec":
        ov.update(encoder_layers=2, encoder_seq=96, rope_theta=0.0)
    if cfg.family == "vlm":
        ov.update(vision_tokens=16)
    return cfg.replace(**ov)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--preset", default="smoke",
                   choices=["smoke", "100m", "full"])
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=100)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--resume", action="store_true")
    args = p.parse_args()

    spec = get_arch(args.arch)
    cfg = reduced_config(spec.model, args.preset)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    train_cfg = TrainConfig(
        learning_rate=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
        decay_steps=args.steps, schedule=spec.train.schedule,
        stable_steps=spec.train.stable_steps)

    model = model_zoo.build_model(cfg, max_seq=args.seq)
    n_params = model_zoo.count_params(cfg, max_seq=args.seq)
    print(f"arch={args.arch} preset={args.preset} params={n_params/1e6:.1f}M")

    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)
    state = make_train_state(params, train_cfg)
    step_fn = jax.jit(make_train_step(model_zoo.make_loss_fn(model),
                                      train_cfg), donate_argnums=(0,))

    ckpt = None
    start_step = 0
    if args.ckpt_dir:
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            snap = ckpt.restore_latest()
            if snap is not None:
                state = jax.tree.map(jnp.asarray, snap["tree"])
                start_step = snap["step"]
                print(f"resumed at step {start_step}")

    stream = SyntheticTokenStream(cfg, shape, seed=args.seed, step=start_step)
    it = Prefetcher(stream, depth=2)
    t0 = time.time()
    tokens_seen = 0
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        tokens_seen += args.batch * args.seq
        if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {i+1:5d} loss={loss:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"tok/s={tokens_seen/dt:,.0f}", flush=True)
        if ckpt and (i + 1) % args.ckpt_every == 0:
            ckpt.save(i + 1, state)
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.wait()
    it.close()
    print(json.dumps({"final_loss": float(metrics["loss"]),
                      "steps": args.steps,
                      "tokens_per_second": tokens_seen / (time.time() - t0)}))


if __name__ == "__main__":
    main()
