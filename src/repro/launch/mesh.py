"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import; smoke tests
see the real single device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data', 'model') = 256 chips.
    Multi-pod:  (2, 16, 16) ('pod', 'data', 'model') = 512 chips.
    `pod` acts as an outer data-parallel axis (batch sharded over
    ('pod', 'data')); params/optimizer replicate across pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real host device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))
