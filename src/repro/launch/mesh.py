"""Production mesh construction.

A FUNCTION, not a module constant — importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before any jax import; smoke tests
see the real single device).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ('data', 'model') = 256 chips.
    Multi-pod:  (2, 16, 16) ('pod', 'data', 'model') = 512 chips.
    `pod` acts as an outer data-parallel axis (batch sharded over
    ('pod', 'data')); params/optimizer replicate across pods.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real host device (smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_scaleout_mesh(data: int = 0, model: int = 1):
    """('data', 'model') mesh over the first ``data*model`` visible devices.

    Unlike ``jax.make_mesh`` this accepts a SUBSET of the device pool, which
    is what scaling curves need: the same process measures 1-, 2-, 4- and
    8-device meshes out of 8 emulated host devices without re-launching.
    ``data=0`` means "all devices on the data axis" — the default production
    scale-out for fused scoring, where rows shard over ``data`` and the
    committee replicates (see docs/scaling.md).
    """
    devs = jax.devices()
    if data <= 0:
        if len(devs) % model:
            raise ValueError(
                f"make_scaleout_mesh: {len(devs)} devices not divisible by "
                f"model={model}")
        data = len(devs) // model
    need = data * model
    if need > len(devs):
        raise ValueError(
            f"make_scaleout_mesh: need {data}x{model}={need} devices, have "
            f"{len(devs)}")
    grid = np.array(devs[:need]).reshape(data, model)
    return jax.sharding.Mesh(grid, ("data", "model"))
