"""Process-level runtime/platform configuration — the ONE place that owns
the knobs which must be set before jax initializes its backend.

Three kinds of knob live here, in order of how early they must fire:

  * **XLA_FLAGS** (``ensure_host_devices``, ``apply_gpu_autotune``) — env
    edits that only take effect if they precede the FIRST jax backend
    initialization.  The emulated-device knob
    (``--xla_force_host_platform_device_count=N``) is how CI exercises a
    REAL 8-device mesh on a CPU host: every sharding, collective, and
    donation path runs exactly as on hardware, just slower.  Editing is
    idempotent (re-applying the same count is a no-op) and guarded — a
    different count after the backend already locked raises instead of
    silently doing nothing.
  * **jax.config toggles** (``set_platform``, ``enable_x64``,
    ``set_debug_nan``) — applied through ``jax.config.update``; safe at
    any time before the relevant behavior is traced.
  * **introspection** (``describe``) — the resolved platform / device kind
    / device count / mesh-relevant process info, recorded by every
    benchmark writer so a ``BENCH_*.json`` is interpretable across
    machines (see ``benchmarks/run.py`` ``bench_meta``).

This module IMPORTS NO JAX AT MODULE SCOPE — importing it can never lock
the device count.  ``launch/roofline.py`` and ``launch/dryrun.py`` call
``ensure_host_devices(512)`` as their first statement instead of the
hand-rolled ``os.environ["XLA_FLAGS"] = ...`` strings they used to carry.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import re
import sys
from typing import Any, Dict, Optional

log = logging.getLogger(__name__)

_HOST_DEV_FLAG = "--xla_force_host_platform_device_count"
_HOST_DEV_RE = re.compile(re.escape(_HOST_DEV_FLAG) + r"=(\d+)")

# the bayespec-style GPU autotune set: triton fusions + async collectives
# + latency-hiding scheduling.  Harmless off-GPU (XLA ignores unknown
# backend flags for other platforms); applied only on request.
GPU_AUTOTUNE_FLAGS = (
    "--xla_gpu_enable_triton_softmax_fusion=true "
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_async_collectives=true "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true"
)


def backend_initialized() -> bool:
    """Whether a jax backend has already been created in this process —
    the point after which XLA_FLAGS edits are dead letters."""
    jx = sys.modules.get("jax")
    if jx is None:
        return False
    try:
        from jax._src import xla_bridge  # noqa: PLC0415

        return bool(xla_bridge._backends)  # noqa: SLF001
    except Exception:  # noqa: BLE001  — private API moved: assume locked
        return True


def requested_host_devices() -> Optional[int]:
    """The emulated-device count currently requested via XLA_FLAGS
    (None when the flag is absent)."""
    m = _HOST_DEV_RE.search(os.environ.get("XLA_FLAGS", ""))
    return int(m.group(1)) if m else None


def ensure_host_devices(n: int) -> int:
    """Idempotently request ``n`` emulated host-platform devices.

    MUST run before the first jax import in the process (the device count
    locks on first backend init).  Re-applying the already-requested count
    is a no-op — safe from module top-levels that may import each other.
    A DIFFERENT count is honored while the backend is uninitialized
    (the flag is rewritten in place) and raises once it is locked:
    silently keeping the stale count is how "works at 1x1 only" bugs
    hide.  Returns the requested count.
    """
    n = int(n)
    if n <= 0:
        raise ValueError(f"ensure_host_devices: need n >= 1, got {n}")
    current = requested_host_devices()
    if current == n:
        return n
    if backend_initialized():
        raise RuntimeError(
            f"ensure_host_devices({n}): jax backend already initialized "
            f"(current request: {current}); emulated device count can only "
            "be set before the first jax import — call this from the "
            "module top, like launch/roofline.py does")
    flags = os.environ.get("XLA_FLAGS", "")
    if current is not None:
        flags = _HOST_DEV_RE.sub(f"{_HOST_DEV_FLAG}={n}", flags)
    else:
        flags = f"{_HOST_DEV_FLAG}={n} {flags}".strip()
    os.environ["XLA_FLAGS"] = flags
    return n


def apply_gpu_autotune() -> None:
    """Append the GPU autotune XLA flag set (idempotent: flags already
    present in XLA_FLAGS are not duplicated)."""
    flags = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in GPU_AUTOTUNE_FLAGS.split()
               if f.split("=")[0] not in flags]
    if not missing:
        return
    if backend_initialized():
        log.warning("apply_gpu_autotune: jax backend already initialized — "
                    "%d flag(s) will not take effect", len(missing))
    os.environ["XLA_FLAGS"] = (flags + " " + " ".join(missing)).strip()


def set_platform(platform: str) -> None:
    """Pin the jax platform ('cpu' | 'gpu' | 'tpu').  Uses jax.config when
    jax is already importable, the JAX_PLATFORMS env var otherwise (both
    are honored at backend init)."""
    platform = str(platform).lower()
    if platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(f"set_platform: unknown platform {platform!r}")
    if backend_initialized():
        raise RuntimeError(
            f"set_platform({platform!r}): jax backend already initialized")
    if "jax" in sys.modules:
        sys.modules["jax"].config.update("jax_platform_name", platform)
    else:
        os.environ["JAX_PLATFORMS"] = platform


def enable_x64(flag: bool = True) -> None:
    """Toggle double precision (``jax_enable_x64``)."""
    if "jax" in sys.modules:
        sys.modules["jax"].config.update("jax_enable_x64", bool(flag))
    else:
        os.environ["JAX_ENABLE_X64"] = "1" if flag else "0"


def set_debug_nan(flag: bool = True) -> None:
    """Toggle automatic NaN checking (``jax_debug_nans``) — tracing aid,
    never for production loops (it forces a sync per primitive)."""
    if "jax" in sys.modules:
        sys.modules["jax"].config.update("jax_debug_nans", bool(flag))
    else:
        os.environ["JAX_DEBUG_NANS"] = "1" if flag else "0"


@dataclasses.dataclass(frozen=True)
class PlatformConfig:
    """Declarative bundle of the process-level knobs (``PALRunConfig``
    carries the same fields; ``configure`` applies them in the right
    order).  Zero values mean "leave alone"."""

    platform: str = ""          # '' | 'cpu' | 'gpu' | 'tpu'
    host_devices: int = 0       # >0: emulated host devices (CI meshes)
    x64: bool = False
    debug_nan: bool = False
    gpu_autotune: bool = False


def configure(cfg: Optional[PlatformConfig] = None, **kw: Any
              ) -> PlatformConfig:
    """Apply a ``PlatformConfig`` (or keyword overrides) in dependency
    order: XLA_FLAGS edits first (they need an uninitialized backend),
    then config toggles.  Returns the applied config."""
    cfg = dataclasses.replace(cfg or PlatformConfig(), **kw)
    if cfg.host_devices > 0:
        ensure_host_devices(cfg.host_devices)
    if cfg.gpu_autotune:
        apply_gpu_autotune()
    if cfg.platform:
        set_platform(cfg.platform)
    if cfg.x64:
        enable_x64(True)
    if cfg.debug_nan:
        set_debug_nan(True)
    return cfg


def configure_from_env(env: Optional[Dict[str, str]] = None
                       ) -> PlatformConfig:
    """Build + apply a ``PlatformConfig`` from ``REPRO_PLATFORM`` /
    ``REPRO_HOST_DEVICES`` / ``REPRO_X64`` / ``REPRO_GPU_AUTOTUNE`` —
    the launcher-script entry point (one env block instead of N ad-hoc
    ``os.environ`` edits)."""
    e = os.environ if env is None else env
    return configure(PlatformConfig(
        platform=e.get("REPRO_PLATFORM", ""),
        host_devices=int(e.get("REPRO_HOST_DEVICES", "0") or 0),
        x64=e.get("REPRO_X64", "") in ("1", "true"),
        gpu_autotune=e.get("REPRO_GPU_AUTOTUNE", "") in ("1", "true"),
    ))


def describe() -> Dict[str, Any]:
    """Resolved runtime facts for benchmark provenance (initializes the
    jax backend — never call from a module top that still wants to edit
    XLA_FLAGS): platform, device kind, device/process counts, and whether
    the devices are emulated host devices."""
    import jax  # noqa: PLC0415

    devs = jax.devices()
    return {
        "platform": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else "?",
        "device_count": jax.device_count(),
        "local_device_count": jax.local_device_count(),
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
        "emulated_host_devices": requested_host_devices() or 0,
    }
