from repro.launch.platform import ensure_host_devices

ensure_host_devices(512)   # before any jax import: emulate the 512-chip pod

"""Three-term roofline analysis from the compiled dry-run (deliverable (g)).

    compute term    = HLO_FLOPs    / (chips x 197e12 FLOP/s bf16)
    memory term     = HLO_bytes    / (chips x 819e9  B/s HBM)
    collective term = coll_bytes   / (chips x 5e10   B/s/link ICI)

XLA:CPU's cost_analysis counts a scan body ONCE (verified: L=1/4/16 report
identical flops), so per-(arch x shape x mesh) we run two UNROLLED probe
compiles at reduced depth, fit total(L) = nonlayer + L*per_layer, and
extrapolate to full depth — cross-checked against analytic MODEL_FLOPS
(6*N_active*D for training; 2*N_active per decoded token) so remat/recompute
waste is visible as the useful-flops ratio.

Per-device vs global: the partitioned module reports per-device numbers;
dividing global quantities by `chips` (prompt convention) is identical.

Usage:
  python -m repro.launch.roofline --arch rwkv6-7b --shape train_4k
  python -m repro.launch.roofline --all --out results/roofline
"""
import argparse
import json
import os
import traceback
from typing import Any, Dict, Optional

from repro.configs import get_arch, get_shape, list_archs
from repro.configs.base import ModelConfig, ShapeConfig

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # B/s
LINK_BW = 5e10             # B/s per ICI link (~50 GB/s)
HBM_BYTES = 16 * 2**30     # 16 GiB


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# ---------------------------------------------------------------------------


def _active_params(cfg: ModelConfig) -> float:
    """Non-embedding params active per token (MoE: top_k of routed)."""
    from repro.models import model_zoo
    from repro.models import common as cm

    model = model_zoo.build_model(cfg, max_seq=128)
    specs = model.param_specs()
    import numpy as np
    import jax

    total_active = 0.0
    def walk(tree, path):
        nonlocal total_active
        if cm.is_spec(tree):
            n = float(np.prod(tree.shape))
            p = "/".join(path)
            if "embedding" in p or "dec_pos" in p:
                return                      # embedding gather ~ free
            if ("/moe/" in p or p.startswith("moe/")) and (
                    "/wi" in p or "/wg" in p or "/wo" in p) and \
                    "shared" not in p:
                n *= cfg.moe_top_k / max(cfg.moe_num_experts, 1)
            total_active += n
            return
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, path + [k])

    walk(specs, [])
    if cfg.tie_embeddings:
        total_active += cfg.padded_vocab * cfg.d_model  # logits matmul
    return total_active


def _attn_flops_fwd(cfg: ModelConfig, B: int, S: int, decode: bool) -> float:
    """Score+value matmul flops (fwd), summed over attention layers.

    decode=True means ONE new token against an S-token cache/state: token
    count is 1, not S (state-recurrence archs advance the state once).
    """
    hd = cfg.resolved_head_dim
    H = cfg.num_heads
    n_tok = 1 if decode else S
    if cfg.family == "rwkv6":
        # chunked linear attention: ~4*H*N^2 per token
        N = cfg.rwkv_head_dim
        return 4.0 * B * n_tok * cfg.rwkv_num_heads * N * N * cfg.num_layers
    n_attn = sum(1 for i in range(cfg.num_layers) if cfg.is_attention_layer(i))
    ssd_fl = 0.0
    if cfg.family == "hybrid":
        n_mamba = cfg.num_layers - n_attn
        N, P = cfg.mamba_d_state, cfg.mamba_head_dim
        Hm = cfg.mamba_num_heads
        ssd_fl = 4.0 * B * n_tok * Hm * N * P * n_mamba
    if decode:
        per = 4.0 * B * S * H * hd                  # 1 token reads S cache
    else:
        kv_span = min(cfg.sliding_window or S, S)
        per = 4.0 * B * S * kv_span * H * hd * (0.5 if kv_span == S else 1.0)
    fl = per * n_attn + ssd_fl
    if cfg.family == "encdec":
        cross = 4.0 * B * n_tok * cfg.encoder_seq * H * hd * cfg.num_layers
        fl += cross
        if not decode:  # the encoder runs once per train/prefill step only
            fl += 4.0 * B * cfg.encoder_seq ** 2 * H * hd * cfg.encoder_layers
    return fl


def analytic_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global useful flops for one step of this cell."""
    B = shape.global_batch
    if shape.kind == "train":
        tokens = B * shape.seq_len
        return (6.0 * _active_params(cfg) * tokens
                + 3.0 * _attn_flops_fwd(cfg, B, shape.seq_len, False))
    if shape.kind == "prefill":
        tokens = B * shape.seq_len
        return (2.0 * _active_params(cfg) * tokens
                + _attn_flops_fwd(cfg, B, shape.seq_len, False))
    # decode: one token against a seq_len cache
    return (2.0 * _active_params(cfg) * B
            + _attn_flops_fwd(cfg, B, shape.seq_len, True))


# ---------------------------------------------------------------------------
# Probe-corrected HLO totals
# ---------------------------------------------------------------------------


def _depth_override(cfg: ModelConfig, d: int) -> Dict[str, Any]:
    ov: Dict[str, Any] = {"scan_layers": False}
    if cfg.family == "hybrid":
        ov["num_layers"] = d * 8
    else:
        ov["num_layers"] = d
    if cfg.family == "encdec":
        ov["encoder_layers"] = d
    return ov


def _layers_of(cfg: ModelConfig, d: Optional[int] = None) -> float:
    """Depth in 'probe units' (hybrid: groups; encdec: enc+dec pairs)."""
    if d is not None:
        return float(d)
    if cfg.family == "hybrid":
        return cfg.num_layers / 8.0
    return float(cfg.num_layers)


def _extract(rep: Dict[str, Any]) -> Dict[str, float]:
    return {
        "flops": float(rep.get("flops", 0.0)),
        "bytes": float(rep.get("bytes_accessed", 0.0)),
        "coll": float(rep.get("hlo_collective_bytes_per_device", 0.0)),
    }


def roofline_cell(
    arch: str, shape_name: str, *, multi_pod: bool = False,
    depths=(1, 2), mesh=None, rule_extra=None, train_overrides=None,
    model_overrides=None, full_report: Optional[Dict[str, Any]] = None,
) -> Dict[str, Any]:
    from repro.launch import dryrun

    spec = get_arch(arch)
    if shape_name in spec.skip_shapes:
        return {"arch": arch, "shape": shape_name,
                "skipped": spec.skip_shapes[shape_name]}
    shape = get_shape(spec, shape_name)
    cfg = spec.model
    if model_overrides:
        cfg = cfg.replace(**model_overrides)
    mesh = mesh or dryrun.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    # 1. full-depth scanned compile (memory + schedule evidence)
    if full_report is None:
        full_report = dryrun.lower_cell(
            arch, shape_name, mesh=mesh, rule_extra=rule_extra,
            train_overrides=train_overrides, model_overrides=model_overrides)

    # 2. unrolled probes
    probes: Dict[int, Dict[str, float]] = {}
    for d in depths:
        ov = dict(model_overrides or {})
        ov.update(_depth_override(cfg, d))
        rep = dryrun.lower_cell(
            arch, shape_name, mesh=mesh, rule_extra=rule_extra,
            train_overrides=train_overrides, model_overrides=ov)
        probes[d] = _extract(rep)

    d1, d2 = sorted(depths)[:2]
    L = _layers_of(cfg)
    out: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh_chips": chips,
        "kind": shape.kind,
        "full": _extract(full_report),
        "resident_gib_per_device": full_report.get("resident_gib_per_device"),
        "memory_analysis": full_report.get("memory"),
        "collective_detail": full_report.get("collectives"),
        "fallbacks": full_report.get("fallbacks"),
        "probes": {str(k): v for k, v in probes.items()},
    }
    terms: Dict[str, float] = {}
    for key in ("flops", "bytes", "coll"):
        per_layer = (probes[d2][key] - probes[d1][key]) / (d2 - d1)
        nonlayer = probes[d1][key] - d1 * per_layer
        terms[key] = max(nonlayer + L * per_layer, 0.0)
        out[f"per_layer_{key}"] = per_layer
        out[f"nonlayer_{key}"] = nonlayer
    out["hlo_flops_per_device"] = terms["flops"]
    out["hlo_bytes_per_device"] = terms["bytes"]
    out["coll_bytes_per_device"] = terms["coll"]

    compute_s = terms["flops"] / PEAK_FLOPS
    memory_s = terms["bytes"] / HBM_BW
    coll_s = terms["coll"] / LINK_BW
    out["compute_term_s"] = compute_s
    out["memory_term_s"] = memory_s
    out["collective_term_s"] = coll_s
    dom = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", coll_s)), key=lambda kv: kv[1])
    out["bottleneck"] = dom[0]
    out["step_time_lower_bound_s"] = dom[1]

    mf = analytic_model_flops(cfg, shape)
    out["model_flops_global"] = mf
    hlo_global = terms["flops"] * chips
    out["useful_flops_ratio"] = (mf / hlo_global) if hlo_global else 0.0
    # roofline fraction: useful model flops per second at the bound, over peak
    if dom[1] > 0:
        out["roofline_fraction"] = (mf / dom[1]) / (chips * PEAK_FLOPS)
    out["fits_hbm"] = bool(
        (full_report.get("resident_gib_per_device") or 0) * 2**30
        + (full_report.get("memory", {}) or {}).get("temp_size_in_bytes", 0)
        < HBM_BYTES)
    return out


def fmt_row(r: Dict[str, Any]) -> str:
    if "skipped" in r:
        return f"{r['arch']:22s} {r['shape']:12s} SKIP"
    return (f"{r['arch']:22s} {r['shape']:12s} "
            f"C={r['compute_term_s']:9.3e} M={r['memory_term_s']:9.3e} "
            f"X={r['collective_term_s']:9.3e} -> {r['bottleneck']:10s} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"roof={r.get('roofline_fraction', 0):.3f} "
            f"res={r.get('resident_gib_per_device')}GiB")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default="results/roofline")
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)
    shapes = [args.shape] if args.shape else \
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    archs = [args.arch] if args.arch else list_archs()
    if not (args.all or args.arch):
        p.error("pass --arch or --all")

    from repro.launch.dryrun import make_production_mesh
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    rows = []
    for a in archs:
        spec = get_arch(a)
        for s in shapes:
            if not any(sh.name == s for sh in spec.shapes):
                continue
            try:
                r = roofline_cell(a, s, multi_pod=args.multi_pod, mesh=mesh)
            except Exception as e:  # noqa: BLE001
                r = {"arch": a, "shape": s, "error": repr(e),
                     "traceback": traceback.format_exc()}
            rows.append(r)
            tag = f"{a}_{s}"
            with open(os.path.join(args.out, tag + ".json"), "w") as fh:
                json.dump(r, fh, indent=1, default=str)
            print(fmt_row(r) if "error" not in r
                  else f"{a} {s} ERROR {r['error']}", flush=True)
    with open(os.path.join(args.out, "table.json"), "w") as fh:
        json.dump(rows, fh, indent=1, default=str)


if __name__ == "__main__":
    main()
