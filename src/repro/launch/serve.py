"""Batched serving driver: prefill a prompt batch, decode N tokens, report
prefill latency / decode throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --preset smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_arch
from repro.launch.train import reduced_config
from repro.models import model_zoo
from repro.serving import ServeEngine


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="llama3.2-1b")
    p.add_argument("--preset", default="smoke", choices=["smoke", "100m",
                                                         "full"])
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args()

    spec = get_arch(args.arch)
    cfg = reduced_config(spec.model, args.preset)
    max_seq = args.prompt_len + args.gen + (
        cfg.vision_tokens if cfg.family == "vlm" else 0)
    model = model_zoo.build_model(cfg, max_seq=max_seq)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    batch = {"tokens": rng.randint(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "encdec":
        batch["enc_embeds"] = rng.randn(
            args.batch, cfg.encoder_seq, cfg.d_model).astype(np.float32) * .02
    if cfg.family == "vlm":
        batch["patch_embeds"] = rng.randn(
            args.batch, cfg.vision_tokens, cfg.d_model).astype(np.float32) * .02

    eng = ServeEngine(model, params, max_seq=max_seq, batch=args.batch,
                      temperature=args.temperature, seed=args.seed)
    res = eng.generate(batch, max_new_tokens=args.gen)
    print(json.dumps({
        "arch": args.arch, "preset": args.preset,
        "batch": args.batch, "prompt_len": args.prompt_len,
        "generated": int(res.tokens.shape[1] - args.prompt_len),
        "prefill_seconds": round(res.prefill_seconds, 4),
        "decode_seconds": round(res.decode_seconds, 4),
        "decode_tokens_per_s": round(res.decode_tokens_per_s, 1),
    }))


if __name__ == "__main__":
    main()
