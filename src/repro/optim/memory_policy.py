"""MemoryPolicy: per-member dtype/layout of the stacked committee TrainState.

Committee size K is the UQ quality lever (paper §2.1), and the stacked
``TrainState`` of ``training/committee_trainer.py`` — K x fp32 params plus
2 x fp32 AdamW moments plus the replay ring, all device-resident — is the
memory wall that caps K.  This module makes the storage format a POLICY
instead of a hard-coded fp32 stack:

  * ``moments``  — AdamW moment storage: ``fp32`` (the seed layout),
    ``bf16`` (mu/nu cast to bfloat16 between steps, math still fp32), or
    ``int8`` (per-block absmax ``QTensor`` mu + sqrt(nu) from
    ``optim/adamw.py`` — ~6x smaller than fp32 moments);
  * ``params_dtype`` — stacked parameter storage (``float32`` default;
    ``bfloat16`` halves the K x params term at the cost of master-weight
    precision — the update math stays fp32 either way);
  * ``replay_dtype`` — ``data/replay.ReplayTrainingBuffer`` row storage
    (``bfloat16`` halves the ring; minibatches are gathered back to fp32
    on device before the loss sees them).

Quantize/dequantize happens INSIDE the same single jitted vmapped train
step, so the dispatch count per step is unchanged (1) under every policy.
Checkpoints carry the quantized leaves natively — a ``QTensor`` moment is
pickled as its int8 ``q`` + fp32 ``scale``, never dequantized on save —
and restoring a snapshot whose storage format mismatches the configured
policy raises instead of silently re-formatting.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union

MOMENT_FORMATS = ("fp32", "bf16", "int8")
_STORAGE_DTYPES = ("float32", "bfloat16")


@dataclasses.dataclass(frozen=True)
class MemoryPolicy:
    """Storage policy for one committee member (applied uniformly to the
    stack).  ``named()`` gives the presets the ``PALRunConfig.
    train_memory_policy`` knob selects; fields compose freely via
    ``dataclasses.replace``."""

    name: str = "fp32"
    moments: str = "fp32"            # fp32 | bf16 | int8 (QTensor sqrt-nu)
    params_dtype: str = "float32"    # float32 | bfloat16
    replay_dtype: str = "float32"    # float32 | bfloat16

    def __post_init__(self):
        if self.moments not in MOMENT_FORMATS:
            raise ValueError(
                f"unknown moment format {self.moments!r}; expected one of "
                f"{MOMENT_FORMATS}")
        for field in ("params_dtype", "replay_dtype"):
            v = getattr(self, field)
            if v not in _STORAGE_DTYPES:
                raise ValueError(
                    f"unknown {field} {v!r}; expected one of "
                    f"{_STORAGE_DTYPES}")

    @staticmethod
    def named(name: str) -> "MemoryPolicy":
        if name not in MOMENT_FORMATS:
            raise ValueError(
                f"unknown memory policy {name!r}; expected one of "
                f"{MOMENT_FORMATS}")
        return MemoryPolicy(name=name, moments=name if name != "bf16"
                            else "bf16")

    def describe(self) -> str:
        return (f"{self.name}(moments={self.moments}, "
                f"params={self.params_dtype}, replay={self.replay_dtype})")


def resolve_policy(policy: Union[str, MemoryPolicy, None]
                   ) -> Optional[MemoryPolicy]:
    """None passes through (caller keeps legacy TrainConfig semantics);
    a string selects a named preset; a MemoryPolicy is validated as-is."""
    if policy is None:
        return None
    if isinstance(policy, str):
        return MemoryPolicy.named(policy)
    if isinstance(policy, MemoryPolicy):
        return policy
    raise TypeError(f"memory_policy must be str | MemoryPolicy | None, "
                    f"got {type(policy).__name__}")


# ---------------------------------------------------------------------------
# Footprint accounting (exact, allocation-free)
# ---------------------------------------------------------------------------


def member_state_nbytes(member_params: Any, policy: MemoryPolicy) -> int:
    """Exact per-member ``TrainState`` bytes under ``policy``, via
    ``jax.eval_shape`` of the same constructor the trainer runs — params
    (in ``params_dtype``), AdamW mu/nu in the ``moments`` format
    (including the per-block fp32 scale arrays of int8 ``QTensor``
    moments), and the two int32 step counters.  No buffers allocated."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import TrainConfig
    from repro.training.train_step import make_train_state

    pd = jnp.dtype(policy.params_dtype)

    def as_sds(p):
        shape = tuple(getattr(p, "shape", ()))
        dt = jnp.dtype(getattr(p, "dtype", jnp.float32))
        if jnp.issubdtype(dt, jnp.floating):
            dt = pd
        return jax.ShapeDtypeStruct(shape, dt)

    abstract = jax.tree.map(as_sds, member_params)
    tcfg = TrainConfig(opt_moments=policy.moments)
    sds = jax.eval_shape(lambda p: make_train_state(p, tcfg), abstract)
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(sds))


def stacked_state_nbytes(member_params: Any, k: int,
                         policy: MemoryPolicy) -> int:
    """Exact stacked K-member committee ``TrainState`` bytes: stacking
    gives every leaf (params, moments, scales, steps) a leading K axis,
    so the footprint is exactly K x the per-member state."""
    return int(k) * member_state_nbytes(member_params, policy)
