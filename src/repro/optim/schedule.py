"""LR schedules: cosine, constant, and WSD (warmup-stable-decay — the
minicpm-2b paper's schedule, wired to that arch's TrainConfig)."""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def make_schedule(
    kind: str,
    base_lr: float,
    warmup_steps: int = 0,
    decay_steps: int = 10_000,
    stable_steps: int = 0,
    min_lr_ratio: float = 0.1,
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    min_lr = base_lr * min_lr_ratio

    def warmup(step):
        if warmup_steps <= 0:
            return jnp.asarray(1.0, jnp.float32)
        return jnp.minimum(1.0, step.astype(jnp.float32)
                           / float(warmup_steps))

    if kind == "constant":
        def fn(step):
            return base_lr * warmup(step)
    elif kind == "cosine":
        def fn(step):
            s = jnp.asarray(step, jnp.float32)
            t = jnp.clip((s - warmup_steps) / max(decay_steps - warmup_steps,
                                                  1), 0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
            return warmup(step) * (min_lr + (base_lr - min_lr) * cos)
    elif kind == "wsd":
        # warmup -> stable plateau at base_lr -> linear decay to min_lr
        def fn(step):
            s = jnp.asarray(step, jnp.float32)
            decay_start = warmup_steps + stable_steps
            t = jnp.clip((s - decay_start)
                         / max(decay_steps - decay_start, 1), 0.0, 1.0)
            return warmup(step) * (base_lr - (base_lr - min_lr) * t)
    else:
        raise ValueError(f"unknown schedule {kind!r}")

    return fn
