from repro.optim.adamw import (  # noqa: F401
    AdamWState, adamw_init, adamw_update, global_norm, clip_by_global_norm,
)
from repro.optim.schedule import make_schedule  # noqa: F401
