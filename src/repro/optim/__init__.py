from repro.optim.adamw import (  # noqa: F401
    AdamWState, QTensor, adamw_init, adamw_update, clip_by_global_norm,
    dequantize, global_norm, quantize, resolve_moments,
)
from repro.optim.memory_policy import (  # noqa: F401
    MemoryPolicy, member_state_nbytes, resolve_policy, stacked_state_nbytes,
)
from repro.optim.schedule import make_schedule  # noqa: F401
