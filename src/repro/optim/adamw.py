"""AdamW with optional int8 block-quantized moments (beyond-paper).

Functional, pytree-shaped like the params, so optimizer state inherits the
parameter shardings under pjit (ZeRO-1 comes from sharding the state over
the `data` axis where divisible — sharding/rules handles the mapping).

int8 moments: per-block (128) absmax quantization of mu/nu, fp32 scales —
6 bytes/param optimizer+master state instead of 12, the difference between
fitting and not fitting jamba-398B / qwen3-235B on v5e HBM (EXPERIMENTS §Perf).
The second moment is stored as ``sqrt(nu)``: the update only ever consumes
``sqrt(vhat)``, and quantizing in sqrt space keeps the denominator's int8
error linear instead of blowing up the step size of small-|g| coordinates
that share an absmax block with a large one.  NOTE: this changes the
quantized optimizer-state format — checkpoints of quantized AdamW state
written before this change are not resumable (their nu would be
reinterpreted as sqrt(nu)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 128


# ---------------------------------------------------------------------------
# int8 blockwise quantization
# ---------------------------------------------------------------------------


class QTensor(NamedTuple):
    q: jnp.ndarray          # int8, SAME SHAPE as the source tensor
    scale: jnp.ndarray      # fp32, blocked along `axis`
    block: int              # static
    axis: int               # static: blocked dimension


def _block_for(n: int) -> int:
    b = min(BLOCK, n)
    while n % b:
        b -= 1
    return b


def _pick_axis(shape) -> int:
    """Blocked dim choice matters under sharding: if size/block on the
    blocked dim stops being divisible by the mesh (e.g. vocab 151936/128 =
    1187, prime), the scale/reshape forces an all-gather of the whole
    dequantized tensor (§Perf qwen3 iter 5).  Prefer a dim where the
    post-blocking quotient stays 16-divisible; prefer the last on ties."""
    best, best_score = len(shape) - 1, -1
    for d in range(len(shape) - 1, -1, -1):
        n = shape[d]
        b = _block_for(n)
        score = 0
        if b >= 16:
            score += 1
        if (n // b) % 16 == 0 or n // b == 1:
            score += 2
        if score > best_score:
            best, best_score = d, score
    return best


def quantize(x: jnp.ndarray, axis: Optional[int] = None) -> QTensor:
    """Shape-preserving per-block absmax int8 quantization along one dim.

    ``q`` keeps the source shape, so it inherits the parameter's sharding
    spec verbatim; ``scale`` has the blocked dim divided by the block."""
    if x.ndim == 0:
        t = quantize(x[None], axis=0)
        return QTensor(t.q[0], t.scale[0], t.block, 0)
    ax_ = _pick_axis(x.shape) if axis is None else axis
    n = x.shape[ax_]
    b = _block_for(n)
    xm = jnp.moveaxis(x.astype(jnp.float32), ax_, -1)
    xr = xm.reshape(*xm.shape[:-1], n // b, b)
    scale = jnp.max(jnp.abs(xr), axis=-1) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xr / safe[..., None]), -127, 127)
    q = jnp.moveaxis(q.reshape(xm.shape), -1, ax_).astype(jnp.int8)
    scale = jnp.moveaxis(scale, -1, ax_)   # blocked dim now n//b, in place
    return QTensor(q, scale, b, ax_)


def dequantize(t: QTensor) -> jnp.ndarray:
    shape = t.q.shape
    if len(shape) == 0:
        return t.q.astype(jnp.float32) * t.scale
    n = shape[t.axis]
    qm = jnp.moveaxis(t.q.astype(jnp.float32), t.axis, -1)
    sm = jnp.moveaxis(t.scale, t.axis, -1)
    xr = qm.reshape(*qm.shape[:-1], n // t.block, t.block) * sm[..., None]
    return jnp.moveaxis(xr.reshape(qm.shape), -1, t.axis)


jax.tree_util.register_pytree_node(
    QTensor,
    lambda t: ((t.q, t.scale), (t.block, t.axis)),
    lambda aux, ch: QTensor(ch[0], ch[1], aux[0], aux[1]),
)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


MOMENT_FORMATS = ("fp32", "bf16", "int8")


def resolve_moments(moments: str = "", quantized: bool = False) -> str:
    """Moment storage format: an explicit ``moments`` wins; the legacy
    ``quantized`` boolean maps to ``int8``; default ``fp32``."""
    m = moments or ("int8" if quantized else "fp32")
    if m not in MOMENT_FORMATS:
        raise ValueError(f"unknown moment format {m!r}; expected one of "
                         f"{MOMENT_FORMATS}")
    return m


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    quantized: bool = False          # legacy alias for moments="int8"
    moments: str = ""                # "" | fp32 | bf16 | int8

    def moment_format(self) -> str:
        return resolve_moments(self.moments, self.quantized)


def adamw_init(params: Any, quantized: bool = False,
               moments: str = "") -> AdamWState:
    fmt = resolve_moments(moments, quantized)

    def zero(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if fmt == "int8":
            return quantize(z)
        if fmt == "bf16":
            return z.astype(jnp.bfloat16)
        return z

    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zero, params),
        nu=jax.tree.map(zero, params),
    )


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    lr: jnp.ndarray,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Any, AdamWState]:
    """Returns (new_params, new_state).  Math in fp32 regardless of storage."""
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    fmt = cfg.moment_format()

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        if fmt == "int8":
            mf = dequantize(m)
            # nu is stored as sqrt(nu): the Adam denominator is sqrt(vhat),
            # so int8 error enters it linearly instead of being amplified
            # for small-magnitude entries sharing a block with a large
            # absmax.  bf16 storage keeps nu direct (no shared scale, and
            # squaring a rounded sqrt would double the relative error).
            vf = dequantize(v) ** 2
        else:
            mf = m.astype(jnp.float32)
            vf = v.astype(jnp.float32)
        mf = b1 * mf + (1 - b1) * g
        vf = b2 * vf + (1 - b2) * g * g
        mhat = mf / c1
        vhat = vf / c2
        pf = p.astype(jnp.float32)
        new_p = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * pf)
        if fmt == "int8":
            mf, vf = quantize(mf), quantize(jnp.sqrt(vf))
        elif fmt == "bf16":
            mf, vf = mf.astype(jnp.bfloat16), vf.astype(jnp.bfloat16)
        return new_p.astype(p.dtype), mf, vf

    flat_g, treedef = jax.tree.flatten(grads)
    # flatten_up_to stops at grads' leaf positions, so QTensor moment
    # subtrees come back whole.
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)

    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
