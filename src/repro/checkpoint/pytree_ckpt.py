"""Atomic, async pytree checkpoints for training state (substrate layer).

* ``save_checkpoint``: device->host transfer, pickle to tmp, atomic rename.
* ``AsyncCheckpointer``: runs the host transfer synchronously (cheap; frees
  the step loop to keep the device busy) and the serialization/fsync on a
  background thread; ``wait()`` joins before the next save or at exit.
* retention: keep the newest K checkpoints; ``latest_step``/auto-resume.

On a real multi-host cluster each process writes its own param shards
(jax.experimental.multihost_utils / array serialization); here the single
process owns all shards, so one file per step is the faithful reduction.
"""
from __future__ import annotations

import os
import pickle
import re
import tempfile
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"ckpt_(\d+)\.pkl$")


def _to_host(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(x), tree)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = {"step": step, "tree": _to_host(tree), "extra": extra or {}}
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.pkl")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, prefix=".tmp_ckpt_")
    try:
        with os.fdopen(fd, "wb") as fh:
            pickle.dump(payload, fh, protocol=pickle.HIGHEST_PROTOCOL)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    return path


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in os.listdir(ckpt_dir):
        m = _STEP_RE.search(f)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def load_checkpoint(ckpt_dir: str, step: Optional[int] = None
                    ) -> Optional[Dict[str, Any]]:
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            return None
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.pkl")
    with open(path, "rb") as fh:
        return pickle.load(fh)


class AsyncCheckpointer:
    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.saves = 0

    def save(self, step: int, tree: Any,
             extra: Optional[Dict[str, Any]] = None):
        self.wait()
        host_tree = _to_host(tree)   # synchronous D2H; serialization is async

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
                self._retain()
            except BaseException as e:  # surfaced on wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        self.saves += 1

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _retain(self):
        steps = list_steps(self.ckpt_dir)
        for s in steps[:-self.keep]:
            p = os.path.join(self.ckpt_dir, f"ckpt_{s:08d}.pkl")
            if os.path.exists(p):
                os.unlink(p)

    def restore_latest(self) -> Optional[Dict[str, Any]]:
        self.wait()
        return load_checkpoint(self.ckpt_dir)
