from repro.checkpoint.pytree_ckpt import (  # noqa: F401
    AsyncCheckpointer, load_checkpoint, save_checkpoint,
)
