"""Dense decoder-only transformer (GQA, optional SWA / qk-norm / tied embed).

This is the backbone for llama3.2-1b, minicpm-2b, h2o-danube-3-4b,
mistral-nemo-12b, and (with a patch-embedding prefix) internvl2-2b; the MoE
and hybrid families subclass/borrow its attention and embedding machinery.

Functional style: ``param_specs(cfg)`` builds a ParamSpec pytree,
``DenseLM.forward`` consumes the materialized (or abstract) tree.  Layers are
scanned (stacked params, jax.lax.scan) for O(1)-in-depth HLO; remat policy is
per-config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as ax
from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm
from repro.models.common import ParamSpec
from repro.sharding.rules import shard_constraint

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig) -> Params:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s: Params = {
        "ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "wq": ParamSpec((D, H, hd), (ax.EMBED, ax.HEADS, ax.HEAD_DIM)),
        "wk": ParamSpec((D, KV, hd), (ax.EMBED, ax.KV_HEADS, ax.HEAD_DIM)),
        "wv": ParamSpec((D, KV, hd), (ax.EMBED, ax.KV_HEADS, ax.HEAD_DIM)),
        "wo": ParamSpec((H, hd, D), (ax.HEADS, ax.HEAD_DIM, ax.EMBED)),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), (ax.HEAD_DIM,), init="ones")
        s["k_norm"] = ParamSpec((hd,), (ax.HEAD_DIM,), init="ones")
    return s


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    return {
        "ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "wi": ParamSpec((D, F), (ax.EMBED, ax.MLP)),
        "wg": ParamSpec((D, F), (ax.EMBED, ax.MLP)),
        "wo": ParamSpec((F, D), (ax.MLP, ax.EMBED)),
    }


def layer_specs(cfg: ModelConfig) -> Params:
    return {"attn": attn_specs(cfg), "mlp": mlp_specs(cfg)}


def embed_specs(cfg: ModelConfig) -> Params:
    V, D = cfg.padded_vocab, cfg.d_model
    s: Params = {
        "embedding": ParamSpec((V, D), (ax.VOCAB, ax.EMBED), scale=1.0),
        "final_ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((D, V), (ax.EMBED, ax.VOCAB))
    return s


def param_specs(cfg: ModelConfig) -> Params:
    return {
        "layers": cm.stack_tree(layer_specs(cfg), cfg.num_layers),
        **embed_specs(cfg),
    }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def attention_block(
    p: Params,
    x: jnp.ndarray,                    # (B, T, D)
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,            # (T,) or (B, T)
    cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,  # (k,v): (B,S,KV,hd)
    index: Optional[jnp.ndarray] = None,  # scalar int32 write offset (decode)
    impl: str = "xla",
    rules=None,
    kv_seq_shard: bool = False,
):
    """Pre-norm attention block.  Returns (out, new_cache)."""
    B, T, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("btd,dhk->bthk", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("btd,dhk->bthk", h, p["wk"].astype(h.dtype))
    v = jnp.einsum("btd,dhk->bthk", h, p["wv"].astype(h.dtype))
    if cfg.qk_norm:
        q = cm.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = cm.rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_theta:
        q = cm.rope(q, positions, cfg.rope_theta)
        k = cm.rope(k, positions, cfg.rope_theta)
    q = shard_constraint(q, rules, (ax.BATCH, ax.SEQ, ax.HEADS, ax.HEAD_DIM))

    new_cache = None
    if cache is not None:
        ck, cv = cache
        if index is not None:  # decode: write T new tokens at `index`
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, index, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, index, 0, 0))
            kv_len = jnp.full((B,), index + T, dtype=jnp.int32)
            o = ops.attention(
                q, ck, cv, causal=False, window=cfg.sliding_window,
                q_offset=index, kv_len=kv_len, impl=impl,
                kv_seq_shard=kv_seq_shard, rules=rules,
            )
        else:  # prefill: write at 0, causal within
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, 0, 0))
            o = ops.attention(
                q, k, v, causal=True, window=cfg.sliding_window, impl=impl,
            )
        new_cache = (ck, cv)
    else:
        o = ops.attention(
            q, k, v, causal=True, window=cfg.sliding_window, impl=impl
        )
    o = shard_constraint(o, rules, (ax.BATCH, ax.SEQ, ax.HEADS, ax.HEAD_DIM))
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(o.dtype))
    return shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED)), new_cache


def mlp_block(p: Params, x: jnp.ndarray, cfg: ModelConfig, rules=None) -> jnp.ndarray:
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    act = cm.activation(cfg.act)
    g = jnp.einsum("btd,df->btf", h, p["wg"].astype(h.dtype))
    u = jnp.einsum("btd,df->btf", h, p["wi"].astype(h.dtype))
    hh = act(g) * u
    hh = shard_constraint(hh, rules, (ax.BATCH, ax.SEQ, ax.MLP))
    out = jnp.einsum("btf,fd->btd", hh, p["wo"].astype(h.dtype))
    return shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED))


def dense_layer(
    p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
    positions, cache=None, index=None, impl="xla", rules=None,
    kv_seq_shard=False,
):
    a, new_cache = attention_block(
        p["attn"], x, cfg, positions=positions, cache=cache, index=index,
        impl=impl, rules=rules, kv_seq_shard=kv_seq_shard,
    )
    x = x + a
    x = x + mlp_block(p["mlp"], x, cfg, rules)
    return x, new_cache


# ---------------------------------------------------------------------------
# Scan-over-layers helpers (shared by all families)
# ---------------------------------------------------------------------------


def _remat(fn: Callable, mode: str) -> Callable:
    if mode == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if mode == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )
    return fn


def scan_stack(layer_fn: Callable, stacked: Params, x, *, remat: str = "none",
               scan: bool = True, length: Optional[int] = None):
    """x' = layer_fn(params_i, x) folded over the leading (layers) axis."""
    f = _remat(layer_fn, remat)
    if not scan:
        n = length or jax.tree.leaves(stacked)[0].shape[0]
        for i in range(n):
            x = f(jax.tree.map(lambda a: a[i], stacked), x)
        return x

    def body(carry, pl):
        return f(pl, carry), None

    x, _ = jax.lax.scan(body, x, stacked)
    return x


def scan_stack_cache(layer_fn: Callable, stacked: Params, caches, x, *,
                     scan: bool = True, length: Optional[int] = None):
    """Like scan_stack but threads a per-layer cache pytree (decode path).

    layer_fn(params_i, cache_i, x) -> (x, new_cache_i)
    """
    if not scan:
        n = length or jax.tree.leaves(stacked)[0].shape[0]
        new_caches = []
        for i in range(n):
            x, c = layer_fn(
                jax.tree.map(lambda a: a[i], stacked),
                jax.tree.map(lambda a: a[i], caches),
                x,
            )
            new_caches.append(c)
        stacked_cache = jax.tree.map(
            lambda *cs: jnp.stack(cs, axis=0), *new_caches
        )
        return x, stacked_cache

    def body(carry, inputs):
        pl, cl = inputs
        y, new_c = layer_fn(pl, cl, carry)
        return y, new_c

    x, new_caches = jax.lax.scan(body, x, (stacked, caches))
    return x, new_caches


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


def unembed(p: Params, x: jnp.ndarray, cfg: ModelConfig, rules=None) -> jnp.ndarray:
    x = cm.rms_norm(x, p["final_ln"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, p["embedding"].astype(x.dtype))
    else:
        logits = jnp.einsum("btd,dv->btv", x, p["lm_head"].astype(x.dtype))
    logits = cm.softcap(logits, cfg.logit_softcap)
    return shard_constraint(logits, rules, (ax.BATCH, ax.SEQ, ax.VOCAB))


def embed(p: Params, tokens: jnp.ndarray, cfg: ModelConfig, rules=None) -> jnp.ndarray:
    x = cm.take_embedding(p["embedding"], tokens).astype(cfg.dtype)
    return shard_constraint(x, rules, (ax.BATCH, ax.SEQ, ax.EMBED))


@dataclasses.dataclass
class DenseLM:
    """Decoder-only dense LM.  ``rules`` (MeshRules) enables sharding hints."""

    cfg: ModelConfig
    impl: str = "xla"
    rules: Any = None

    # ------------------------------------------------------------- specs
    def param_specs(self) -> Params:
        return param_specs(self.cfg)

    def init(self, rng) -> Params:
        return cm.init_params(self.param_specs(), rng)

    def _layer_fn(self, positions):
        cfg, impl, rules = self.cfg, self.impl, self.rules

        def fn(pl, x):
            y, _ = dense_layer(pl, x, cfg, positions=positions, impl=impl,
                               rules=rules)
            return y

        return fn

    # ------------------------------------------------------------- forward
    def forward(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = embed(params, tokens, cfg, self.rules)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        x = scan_stack(
            self._layer_fn(positions), params["layers"], x,
            remat=cfg.remat, scan=cfg.scan_layers, length=cfg.num_layers,
        )
        return unembed(params, x, cfg, self.rules)

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int) -> Params:
        cfg = self.cfg
        kv_axes = (ax.LAYERS, ax.BATCH, ax.CACHE_SEQ, ax.KV_HEADS, ax.HEAD_DIM)
        shape = (cfg.num_layers, batch, max_seq, cfg.num_kv_heads,
                 cfg.resolved_head_dim)
        return {
            "k": ParamSpec(shape, kv_axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
            "v": ParamSpec(shape, kv_axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
        }

    def init_cache(self, batch: int, max_seq: int) -> Params:
        return cm.init_params(self.cache_specs(batch, max_seq), jax.random.PRNGKey(0))

    def prefill(self, params: Params, tokens: jnp.ndarray, cache: Params):
        """Fill the cache with T prompt tokens; return (last_logits, cache)."""
        cfg = self.cfg
        x = embed(params, tokens, cfg, self.rules)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def fn(pl, cl, h):
            y, new_c = dense_layer(
                pl, h, cfg, positions=positions,
                cache=(cl["k"], cl["v"]), index=None, impl=self.impl,
                rules=self.rules,
            )
            return y, {"k": new_c[0], "v": new_c[1]}

        x, cache = scan_stack_cache(fn, params["layers"], cache, x,
                                    scan=cfg.scan_layers, length=cfg.num_layers)
        logits = unembed(params, x[:, -1:, :], cfg, self.rules)
        return logits[:, 0, :], cache

    def decode_step(self, params: Params, tokens: jnp.ndarray, cache: Params,
                    index: jnp.ndarray, *, kv_seq_shard: bool = False):
        """One decode step: tokens (B, 1) written at `index` (scalar int32)."""
        cfg = self.cfg
        x = embed(params, tokens, cfg, self.rules)
        positions = index + jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def fn(pl, cl, h):
            y, new_c = dense_layer(
                pl, h, cfg, positions=positions,
                cache=(cl["k"], cl["v"]), index=index, impl=self.impl,
                rules=self.rules, kv_seq_shard=kv_seq_shard,
            )
            return y, {"k": new_c[0], "v": new_c[1]}

        x, cache = scan_stack_cache(fn, params["layers"], cache, x,
                                    scan=cfg.scan_layers, length=cfg.num_layers)
        logits = unembed(params, x, cfg, self.rules)
        return logits[:, -1, :], cache


# ---------------------------------------------------------------------------
# Loss (shared by the whole zoo)
# ---------------------------------------------------------------------------


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None, z_loss_coef: float = 0.0):
    """Next-token cross entropy in fp32.  labels: (B, T) int32; -1 = ignore."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    valid = labels >= 0
    if mask is not None:
        valid = valid & (mask > 0)
    w = valid.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)
    loss = (nll * w).sum() / denom
    metrics = {"nll": loss, "tokens": w.sum()}
    if z_loss_coef:
        zl = z_loss_coef * ((lse * w) ** 2).sum() / denom
        loss = loss + zl
        metrics["z_loss"] = zl
    return loss, metrics
