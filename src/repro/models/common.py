"""Shared model machinery: ParamSpec trees, init, norms, RoPE, embeddings.

Models are functional: a module is a pair (param_specs, apply).  ParamSpec
carries shape, logical sharding axes, and an init distribution, so the same
tree drives real init (smoke tests / CPU training), abstract init (dry-run),
and sharding resolution.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as ax


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]       # logical axis per dim
    init: str = "normal"                  # normal | zeros | ones | uniform
    scale: float = 1.0                    # stddev multiplier (normal) / bound
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key) -> jnp.ndarray:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "uniform":
            return jax.random.uniform(
                key, self.shape, self.dtype, -self.scale, self.scale
            )
        # fan-in scaled normal
        fan_in = self.shape[-2] if len(self.shape) >= 2 else self.shape[-1]
        std = self.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, self.shape) * std).astype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, rng) -> Dict:
    """Materialize a ParamSpec tree with per-leaf folded keys (deterministic
    regardless of tree iteration order)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [s.materialize(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    return jax.tree.map(lambda s: s.abstract(), specs, is_leaf=is_spec)


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def count_params(specs) -> int:
    return sum(
        int(np.prod(s.shape)) for s in jax.tree.leaves(specs, is_leaf=is_spec)
    )


def stacked(spec: ParamSpec, n: int) -> ParamSpec:
    """Prepend a scan ('layers') axis."""
    return dataclasses.replace(
        spec, shape=(n,) + spec.shape, axes=(ax.LAYERS,) + spec.axes
    )


def stack_tree(specs, n: int):
    return jax.tree.map(lambda s: stacked(s, n), specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def group_norm(x, weight, bias, groups: int, eps: float = 1e-5):
    """Per-head group norm over the last dim (rwkv6 output norm)."""
    dt = x.dtype
    *lead, D = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, groups, D // groups)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(*lead, D)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding. x: (..., T, H, D) with positions (..., T) or (T,)."""
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(angles)[..., None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jnp.ndarray:
    """Whisper-style sinusoidal table (n, d)."""
    half = d // 2
    log_timescale = math.log(10000.0) / max(half - 1, 1)
    inv = np.exp(-log_timescale * np.arange(half))
    pos = np.arange(n)[:, None] * inv[None, :]
    return jnp.asarray(
        np.concatenate([np.sin(pos), np.cos(pos)], axis=1), jnp.float32
    )


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu_sq":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def take_embedding(table, tokens):
    """Gather rows; fp32 table -> activation dtype downstream."""
    return jnp.take(table, tokens, axis=0)


def softcap(logits, cap: float):
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)
