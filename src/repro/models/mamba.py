"""Mamba mixer in the SSD-chunked TPU form (DESIGN.md §6).

Jamba uses Mamba-1; the CUDA-idiomatic selective scan (per-channel decay held
in SM shared memory) is deliberately adapted to the Mamba-2/SSD scalar-decay-
per-head formulation so the intra-chunk work is MXU matmuls (kernels/ops.ssd).
Structure kept from Mamba-1: in_proj -> (x, z), causal depthwise conv, silu,
data-dependent (dt, B, C), SSM, D-skip, silu(z) gating, out_proj.

Decode state: conv tail (B, d_conv-1, d_inner) + SSD state (B, H, N, P).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as ax
from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm
from repro.models.common import ParamSpec
from repro.sharding.rules import shard_constraint

Params = Dict[str, Any]


def mamba_specs(cfg: ModelConfig) -> Params:
    D = cfg.d_model
    Di = cfg.mamba_d_inner
    N = cfg.mamba_d_state
    Kc = cfg.mamba_d_conv
    H = cfg.mamba_num_heads
    return {
        "ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "in_proj": ParamSpec((D, 2 * Di), (ax.EMBED, ax.MLP)),
        "conv_w": ParamSpec((Kc, Di), (ax.CONV, ax.MLP), scale=0.5),
        "conv_b": ParamSpec((Di,), (ax.MLP,), init="zeros"),
        "w_dt": ParamSpec((Di, H), (ax.MLP, ax.HEADS), scale=0.1),
        "dt_bias": ParamSpec((H,), (ax.HEADS,), init="uniform", scale=1.0),
        "A_log": ParamSpec((H,), (ax.HEADS,), init="uniform", scale=1.0),
        "w_B": ParamSpec((Di, N), (ax.MLP, ax.STATE), scale=0.5),
        "w_C": ParamSpec((Di, N), (ax.MLP, ax.STATE), scale=0.5),
        "D_skip": ParamSpec((H,), (ax.HEADS,), init="ones"),
        "norm_w": ParamSpec((Di,), (ax.MLP,), init="ones"),
        "out_proj": ParamSpec((Di, D), (ax.MLP, ax.EMBED)),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 tail: Optional[jnp.ndarray] = None):
    """Depthwise causal conv.  x: (B,T,Di); w: (K,Di).  Returns (y, new_tail).

    `tail` is the last K-1 inputs of the previous segment (decode carry).
    Realized as K shifted adds — K is 4, cheaper and more fusible than a
    grouped-conv call at feature_group_count=Di on TPU.
    """
    B, T, Di = x.shape
    K = w.shape[0]
    if tail is None:
        tail = jnp.zeros((B, K - 1, Di), x.dtype)
    ext = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, T+K-1, Di)
    y = jnp.zeros_like(x)
    for i in range(K):
        y = y + ext[:, i:i + T, :] * w[i].astype(x.dtype)
    new_tail = ext[:, -(K - 1):, :]
    return y + b.astype(x.dtype), new_tail


def mamba_mixer(
    p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
    states: Optional[Dict[str, jnp.ndarray]] = None,
    impl: str = "xla", rules=None, chunk: int = 64,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """x: (B,T,D) -> (out, new_states).  states: {"conv": ..., "ssd": ...}."""
    B, T, D = x.shape
    Di, N = cfg.mamba_d_inner, cfg.mamba_d_state
    H, P = cfg.mamba_num_heads, cfg.mamba_head_dim

    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    xz = jnp.einsum("btd,de->bte", h, p["in_proj"].astype(h.dtype))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard_constraint(xin, rules, (ax.BATCH, ax.SEQ, ax.MLP))

    conv_tail = states["conv"] if states else None
    xc, new_conv = _causal_conv(xin, p["conv_w"], p["conv_b"], conv_tail)
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(
        jnp.einsum("bte,eh->bth", xc, p["w_dt"].astype(xc.dtype))
        .astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (H,) negative
    a = jnp.exp(dt * A[None, None, :])                     # (B,T,H) in (0,1)

    Bm = jnp.einsum("bte,en->btn", xc, p["w_B"].astype(xc.dtype))
    Cm = jnp.einsum("bte,en->btn", xc, p["w_C"].astype(xc.dtype))
    Bm4 = jnp.broadcast_to(Bm[:, :, None, :], (B, T, H, N))
    Cm4 = jnp.broadcast_to(Cm[:, :, None, :], (B, T, H, N))

    xh = xc.reshape(B, T, H, P)
    vals = xh * dt.astype(xh.dtype)[..., None]             # dt-discretized input

    ssd_state = states["ssd"] if states else None
    if T == 1 and ssd_state is not None:
        y4, new_ssd = ops.ssd_decode(
            vals[:, 0], a[:, 0], Bm4[:, 0], Cm4[:, 0], ssd_state)
        y4 = y4[:, None]
    else:
        y4, new_ssd = ops.ssd(vals, a.astype(vals.dtype), Bm4, Cm4, ssd_state,
                              impl=impl, chunk=min(chunk, T))
    y4 = y4 + p["D_skip"].astype(y4.dtype)[None, None, :, None] * xh
    y = y4.reshape(B, T, Di)
    y = y * jax.nn.silu(z)
    y = cm.rms_norm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(y.dtype))
    out = shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED))

    new_states = None
    if states is not None:
        new_states = {"conv": new_conv.astype(states["conv"].dtype),
                      "ssd": new_ssd}
    return out, new_states


def mamba_state_specs(cfg: ModelConfig, batch: int) -> Params:
    Di, N = cfg.mamba_d_inner, cfg.mamba_d_state
    H, P = cfg.mamba_num_heads, cfg.mamba_head_dim
    Kc = cfg.mamba_d_conv
    return {
        "conv": ParamSpec((batch, Kc - 1, Di), (ax.BATCH, None, ax.MLP),
                          init="zeros", dtype=jnp.dtype(cfg.dtype)),
        "ssd": ParamSpec((batch, H, N, P), (ax.BATCH, ax.HEADS, ax.STATE, None),
                         init="zeros", dtype=jnp.float32),
    }
