"""The paper's own domain: committee MLP potentials on radial-basis
descriptors (PAL §3.1–3.3).

Energy model: Behler-style per-atom MLP over symmetric radial-basis features
of pairwise distances; total energy = sum of atomic energies; forces =
-grad_R E via jax.grad.  A committee of K such potentials (stacked params +
vmap, DESIGN.md §2) provides query-by-committee uncertainty.

Also ships two analytic "oracles" (Lennard-Jones and Morse cluster
potentials) used as the DFT stand-in ground truth in examples and tests.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.pal_potential import PotentialConfig
from repro.models.common import ParamSpec, init_params

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Descriptors
# ---------------------------------------------------------------------------


def _pair_distances(coords: jnp.ndarray) -> jnp.ndarray:
    """coords (A, 3) -> (A, A) distances with safe diagonal."""
    diff = coords[:, None, :] - coords[None, :, :]
    d2 = jnp.sum(diff * diff, axis=-1)
    a = coords.shape[0]
    d2 = d2 + jnp.eye(a) * 1e6          # mask self-distance out of the RBFs
    return jnp.sqrt(d2 + 1e-12)


def descriptors(coords: jnp.ndarray, cfg: PotentialConfig) -> jnp.ndarray:
    """(A, 3) -> (A, n_rbf) summed Gaussian RBFs with cosine cutoff."""
    d = _pair_distances(coords)                       # (A, A)
    centers = jnp.linspace(0.5, cfg.r_cut, cfg.n_rbf)
    gamma = (cfg.n_rbf / cfg.r_cut) ** 2
    rbf = jnp.exp(-gamma * (d[..., None] - centers) ** 2)   # (A, A, n_rbf)
    fcut = 0.5 * (jnp.cos(jnp.pi * jnp.clip(d / cfg.r_cut, 0, 1)) + 1.0)
    return jnp.sum(rbf * fcut[..., None], axis=1)     # (A, n_rbf)


# ---------------------------------------------------------------------------
# MLP potential
# ---------------------------------------------------------------------------


def param_specs(cfg: PotentialConfig) -> Params:
    dims = (cfg.n_rbf,) + tuple(cfg.hidden) + (1,)
    s: Params = {}
    for i in range(len(dims) - 1):
        s[f"w{i}"] = ParamSpec((dims[i], dims[i + 1]), (None, None))
        s[f"b{i}"] = ParamSpec((dims[i + 1],), (None,), init="zeros")
    return s


def init(cfg: PotentialConfig, rng) -> Params:
    return init_params(param_specs(cfg), rng)


def init_committee(cfg: PotentialConfig, rng) -> Params:
    keys = jax.random.split(rng, cfg.committee_size)
    members = [init(cfg, k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *members)


def energy(params: Params, coords: jnp.ndarray, cfg: PotentialConfig):
    """(A, 3) -> scalar energy."""
    h = descriptors(coords, cfg)
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        h = h @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            h = jnp.tanh(h)
    return jnp.sum(h)


def energy_forces(params: Params, coords: jnp.ndarray, cfg: PotentialConfig):
    e, g = jax.value_and_grad(energy, argnums=1)(params, coords, cfg)
    return e, -g


def committee_energy_forces(cparams: Params, coords: jnp.ndarray,
                            cfg: PotentialConfig):
    """Stacked params (K, ...) -> (E (K,), F (K, A, 3))."""
    return jax.vmap(lambda p: energy_forces(p, coords, cfg))(cparams)


def batched_committee_energy_forces(cparams: Params, coords: jnp.ndarray,
                                    cfg: PotentialConfig):
    """coords (B, A, 3) -> (E (B, K), F (B, K, A, 3))."""
    def one(c):
        return committee_energy_forces(cparams, c, cfg)
    e, f = jax.vmap(one)(coords)
    return e, f


# ---------------------------------------------------------------------------
# Analytic oracles (ground-truth stand-ins for DFT; see DESIGN.md §2)
# ---------------------------------------------------------------------------


def lennard_jones(coords: jnp.ndarray, eps: float = 1.0, sigma: float = 1.0):
    d = _pair_distances(coords)
    a = coords.shape[0]
    mask = 1.0 - jnp.eye(a)
    sr6 = (sigma / d) ** 6
    e = 0.5 * jnp.sum(mask * 4.0 * eps * (sr6 ** 2 - sr6))
    return e


def lj_energy_forces(coords: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    e, g = jax.value_and_grad(lennard_jones)(coords)
    return e, -g


def morse(coords: jnp.ndarray, de: float = 1.0, a: float = 1.2,
          r0: float = 1.2):
    d = _pair_distances(coords)
    n = coords.shape[0]
    mask = 1.0 - jnp.eye(n)
    e = 0.5 * jnp.sum(mask * de * (1.0 - jnp.exp(-a * (d - r0))) ** 2)
    return e


def morse_energy_forces(coords):
    e, g = jax.value_and_grad(morse)(coords)
    return e, -g


# ---------------------------------------------------------------------------
# Training-side loss (energy + force matching, the standard MLP-potential fit)
# ---------------------------------------------------------------------------


def potential_loss(params: Params, batch, cfg: PotentialConfig,
                   force_weight: float = 10.0):
    """batch: {"coords": (B,A,3), "energy": (B,), "forces": (B,A,3)}."""
    def one(c):
        return energy_forces(params, c, cfg)

    e, f = jax.vmap(one)(batch["coords"])
    e_loss = jnp.mean((e - batch["energy"]) ** 2)
    f_loss = jnp.mean((f - batch["forces"]) ** 2)
    return e_loss + force_weight * f_loss, {"e_mse": e_loss, "f_mse": f_loss}
