"""RWKV6 "Finch" — attention-free LM with data-dependent decay
[arXiv:2404.05892].

Faithful block structure: time-mix with ddlerp token-shift LoRAs, per-channel
data-dependent decay w_t (via a decay LoRA), bonus u, the WKV6 recurrence
(kernels/ops.wkv6 — chunked linear-attention form on TPU, DESIGN.md §6),
per-head group-norm and silu(g) gating; channel-mix with squared-ReLU.

O(1) decode state: (wkv state (B,H,N,N) fp32, token-shift states (B,D)).
`long_500k` runs for this family.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import base as ax
from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.common import ParamSpec
from repro.sharding.rules import shard_constraint

Params = Dict[str, Any]

_DDLERP = ("w", "k", "v", "r", "g")


def time_mix_specs(cfg: ModelConfig) -> Params:
    D = cfg.d_model
    R = cfg.rwkv_lora_rank
    Rd = cfg.rwkv_decay_lora_rank
    H = cfg.rwkv_num_heads
    N = cfg.rwkv_head_dim
    return {
        "ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "mu_x": ParamSpec((D,), (ax.EMBED,), init="uniform", scale=0.5),
        "mu": ParamSpec((5, D), (None, ax.EMBED), init="uniform", scale=0.5),
        "lora_a": ParamSpec((D, 5, R), (ax.EMBED, None, None), scale=0.1),
        "lora_b": ParamSpec((5, R, D), (None, None, ax.EMBED), scale=0.1),
        "w0": ParamSpec((D,), (ax.EMBED,), init="uniform", scale=1.0),
        "decay_a": ParamSpec((D, Rd), (ax.EMBED, None), scale=0.1),
        "decay_b": ParamSpec((Rd, D), (None, ax.EMBED), scale=0.1),
        "u": ParamSpec((H, N), (ax.HEADS, ax.HEAD_DIM), init="uniform", scale=0.5),
        "wr": ParamSpec((D, D), (ax.EMBED, ax.MLP)),   # head dim sharded as mlp
        "wk": ParamSpec((D, D), (ax.EMBED, ax.MLP)),
        "wv": ParamSpec((D, D), (ax.EMBED, ax.MLP)),
        "wg": ParamSpec((D, D), (ax.EMBED, ax.MLP)),
        "wo": ParamSpec((D, D), (ax.MLP, ax.EMBED)),
        "gn_w": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "gn_b": ParamSpec((D,), (ax.EMBED,), init="zeros"),
    }


def channel_mix_specs(cfg: ModelConfig) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "mu_k": ParamSpec((D,), (ax.EMBED,), init="uniform", scale=0.5),
        "mu_r": ParamSpec((D,), (ax.EMBED,), init="uniform", scale=0.5),
        "wk": ParamSpec((D, F), (ax.EMBED, ax.MLP)),
        "wv": ParamSpec((F, D), (ax.MLP, ax.EMBED)),
        "wr": ParamSpec((D, D), (ax.EMBED, None)),
    }


def layer_specs(cfg: ModelConfig) -> Params:
    return {"tmix": time_mix_specs(cfg), "cmix": channel_mix_specs(cfg)}


def param_specs(cfg: ModelConfig) -> Params:
    return {
        "layers": cm.stack_tree(layer_specs(cfg), cfg.num_layers),
        **tfm.embed_specs(cfg),
    }


# ---------------------------------------------------------------------------
# Blocks.  `shift_state` is the last token of the previous segment (B, D);
# None during full-sequence training (zero-pad shift).
# ---------------------------------------------------------------------------


def _token_shift(x: jnp.ndarray, shift_state: Optional[jnp.ndarray]):
    """Returns x_{t-1} (same shape as x)."""
    if x.shape[1] == 1 and shift_state is not None:
        return shift_state[:, None, :]
    prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if shift_state is not None:
        prev = prev.at[:, 0].set(shift_state)
    return prev


def time_mix(
    p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
    wkv_state: Optional[jnp.ndarray] = None,
    shift_state: Optional[jnp.ndarray] = None,
    impl: str = "xla", rules=None, chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (out, new_wkv_state, new_shift_state)."""
    B, T, D = x.shape
    H, N = cfg.rwkv_num_heads, cfg.rwkv_head_dim
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    prev = _token_shift(h, shift_state)
    delta = prev - h

    xxx = h + delta * p["mu_x"].astype(h.dtype)
    lo = jnp.einsum("btd,dir->btir", xxx, p["lora_a"].astype(h.dtype))
    adj = jnp.einsum("btir,ird->btid", jnp.tanh(lo), p["lora_b"].astype(h.dtype))
    mixed = (h[:, :, None, :]
             + delta[:, :, None, :] * (p["mu"].astype(h.dtype) + adj))
    xw, xk, xv, xr, xg = [mixed[:, :, i, :] for i in range(5)]

    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(h.dtype))
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(h.dtype))
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(h.dtype))
    g = jnp.einsum("btd,de->bte", xg, p["wg"].astype(h.dtype))

    dlo = jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["decay_a"].astype(h.dtype)))
    dlog = p["w0"].astype(jnp.float32) + jnp.einsum(
        "btr,rd->btd", dlo.astype(jnp.float32), p["decay_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(dlog))                     # (B,T,D) in (0,1)

    hd = lambda z: z.reshape(B, T, H, N)
    r4, k4, v4, w4 = hd(r), hd(k), hd(v), hd(w.astype(h.dtype))
    r4 = shard_constraint(r4, rules, (ax.BATCH, ax.SEQ, ax.HEADS, ax.HEAD_DIM))
    if T == 1 and wkv_state is not None:
        y4, new_state = ops.wkv6_decode(
            r4[:, 0], k4[:, 0], v4[:, 0], w4[:, 0], p["u"], wkv_state)
        y4 = y4[:, None]
    else:
        y4, new_state = ops.wkv6(r4, k4, v4, w4, p["u"], wkv_state,
                                 impl=impl, chunk=min(chunk, T))
    y = y4.reshape(B, T, D)
    y = cm.group_norm(y, p["gn_w"], p["gn_b"], groups=H, eps=64e-5)
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bte,ed->btd", y, p["wo"].astype(h.dtype))
    out = shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED))
    return out, new_state, h[:, -1, :]


def channel_mix(
    p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
    shift_state: Optional[jnp.ndarray] = None, rules=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    prev = _token_shift(h, shift_state)
    delta = prev - h
    xk = h + delta * p["mu_k"].astype(h.dtype)
    xr = h + delta * p["mu_r"].astype(h.dtype)
    k = jnp.einsum("btd,df->btf", xk, p["wk"].astype(h.dtype))
    k = jnp.square(jax.nn.relu(k))
    k = shard_constraint(k, rules, (ax.BATCH, ax.SEQ, ax.MLP))
    kv = jnp.einsum("btf,fd->btd", k, p["wv"].astype(h.dtype))
    rg = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"].astype(h.dtype)))
    out = rg * kv
    return shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED)), h[:, -1, :]


def rwkv_layer(p: Params, x, cfg: ModelConfig, *, states=None, impl="xla",
               rules=None, chunk: int = 64):
    """states: None (train) or dict(wkv, tshift, cshift)."""
    wkv_s = states["wkv"] if states else None
    t_s = states["tshift"] if states else None
    c_s = states["cshift"] if states else None
    a, new_wkv, new_tshift = time_mix(
        p["tmix"], x, cfg, wkv_state=wkv_s, shift_state=t_s, impl=impl,
        rules=rules, chunk=chunk)
    x = x + a
    c, new_cshift = channel_mix(p["cmix"], x, cfg, shift_state=c_s, rules=rules)
    x = x + c
    new_states = {"wkv": new_wkv, "tshift": new_tshift, "cshift": new_cshift}
    return x, new_states


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RWKV6LM(tfm.DenseLM):
    wkv_chunk: int = 64

    def param_specs(self) -> Params:
        return param_specs(self.cfg)

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray]):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = tfm.embed(params, tokens, cfg, self.rules)
        impl, rules, chunk = self.impl, self.rules, self.wkv_chunk

        def fn(pl, h):
            y, _ = rwkv_layer(pl, h, cfg, impl=impl, rules=rules, chunk=chunk)
            return y

        x = tfm.scan_stack(fn, params["layers"], x, remat=cfg.remat,
                           scan=cfg.scan_layers, length=cfg.num_layers)
        return tfm.unembed(params, x, cfg, self.rules)

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int) -> Params:
        cfg = self.cfg
        L, D = cfg.num_layers, cfg.d_model
        H, N = cfg.rwkv_num_heads, cfg.rwkv_head_dim
        return {
            "wkv": ParamSpec((L, batch, H, N, N),
                             (ax.LAYERS, ax.BATCH, ax.HEADS, ax.HEAD_DIM, None),
                             init="zeros", dtype=jnp.float32),
            "tshift": ParamSpec((L, batch, D), (ax.LAYERS, ax.BATCH, ax.EMBED),
                                init="zeros", dtype=jnp.dtype(cfg.dtype)),
            "cshift": ParamSpec((L, batch, D), (ax.LAYERS, ax.BATCH, ax.EMBED),
                                init="zeros", dtype=jnp.dtype(cfg.dtype)),
        }

    def _run_with_state(self, params, tokens, cache):
        cfg = self.cfg
        x = tfm.embed(params, tokens, cfg, self.rules)
        impl, rules, chunk = self.impl, self.rules, self.wkv_chunk

        def fn(pl, cl, h):
            y, new_s = rwkv_layer(pl, h, cfg, states=cl, impl=impl,
                                  rules=rules, chunk=chunk)
            new_s = {
                "wkv": new_s["wkv"],
                "tshift": new_s["tshift"].astype(cl["tshift"].dtype),
                "cshift": new_s["cshift"].astype(cl["cshift"].dtype),
            }
            return y, new_s

        x, cache = tfm.scan_stack_cache(fn, params["layers"], cache, x,
                                        scan=cfg.scan_layers,
                                        length=cfg.num_layers)
        return x, cache

    def prefill(self, params, tokens, cache):
        x, cache = self._run_with_state(params, tokens, cache)
        logits = tfm.unembed(params, x[:, -1:, :], self.cfg, self.rules)
        return logits[:, 0, :], cache

    def decode_step(self, params, tokens, cache, index, *, kv_seq_shard=False):
        del index, kv_seq_shard  # recurrent: position-free, O(1) state
        x, cache = self._run_with_state(params, tokens, cache)
        logits = tfm.unembed(params, x, self.cfg, self.rules)
        return logits[:, -1, :], cache
