"""Jamba-1.5-large — hybrid Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].

72 layers = 9 period-8 groups.  Within a group (offsets 0..7): offset 4 is a
GQA attention layer, the other 7 are Mamba mixers (SSD form, models/mamba.py);
FFN is MoE (16e top-2) on odd offsets and dense on even offsets.  The model
scans over the 9 groups (uniform super-layer structure -> O(1)-in-depth HLO).

`long_500k` RUNS: mamba state is O(1); the 9 attention layers' KV cache is
sharded on the cache-sequence axis over `data` (rule override in the spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as ax
from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import transformer as tfm
from repro.models.common import ParamSpec

Params = Dict[str, Any]

PERIOD = 8


def _offsets(cfg: ModelConfig):
    attn_o = cfg.attn_layer_offset          # 4
    mamba_os = [o for o in range(PERIOD) if o != attn_o]
    moe_os = [o for o in range(PERIOD)
              if o % cfg.moe_layer_period == cfg.moe_layer_offset]
    dense_os = [o for o in range(PERIOD) if o not in moe_os]
    return attn_o, mamba_os, moe_os, dense_os


def group_specs(cfg: ModelConfig) -> Params:
    _, mamba_os, moe_os, dense_os = _offsets(cfg)
    return {
        "attn": tfm.attn_specs(cfg),
        "mamba": cm.stack_tree(mb.mamba_specs(cfg), len(mamba_os)),
        "moe": cm.stack_tree(moe_mod.moe_ffn_specs(cfg), len(moe_os)),
        "dense": cm.stack_tree(tfm.mlp_specs(cfg), len(dense_os)),
    }


def param_specs(cfg: ModelConfig) -> Params:
    assert cfg.num_layers % PERIOD == 0
    groups = cfg.num_layers // PERIOD
    return {
        "layers": cm.stack_tree(group_specs(cfg), groups),
        **tfm.embed_specs(cfg),
    }


def _sub(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def group_forward(
    gp: Params, x: jnp.ndarray, cfg: ModelConfig, *,
    positions, cache: Optional[Params] = None, index=None,
    impl="xla", rules=None, kv_seq_shard=False, with_aux=False,
):
    """One period-8 super-layer.  cache: {"k","v","conv","ssd"} (stacked 7 for
    mamba states).  Returns (x, new_cache, aux)."""
    attn_o, mamba_os, moe_os, dense_os = _offsets(cfg)
    aux = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}
    new_mamba_states = []
    m_i = 0
    for o in range(PERIOD):
        if o == attn_o:
            c = (cache["k"], cache["v"]) if cache is not None else None
            a, nc = tfm.attention_block(
                gp["attn"], x, cfg, positions=positions, cache=c, index=index,
                impl=impl, rules=rules, kv_seq_shard=kv_seq_shard)
            x = x + a
            if nc is not None:
                new_cache["k"], new_cache["v"] = nc
        else:
            st = None
            if cache is not None:
                st = {"conv": cache["conv"][m_i], "ssd": cache["ssd"][m_i]}
            a, ns = mb.mamba_mixer(_sub(gp["mamba"], m_i), x, cfg, states=st,
                                   impl=impl, rules=rules)
            x = x + a
            if ns is not None:
                new_mamba_states.append(ns)
            m_i += 1
        if o in moe_os:
            e_i = moe_os.index(o)
            if with_aux:
                m, a_l = moe_mod.moe_ffn(_sub(gp["moe"], e_i), x, cfg, rules,
                                         return_aux=True)
                aux = aux + a_l
            else:
                m = moe_mod.moe_ffn(_sub(gp["moe"], e_i), x, cfg, rules)
            x = x + m
        else:
            d_i = dense_os.index(o)
            x = x + tfm.mlp_block(_sub(gp["dense"], d_i), x, cfg, rules)
    if cache is not None:
        new_cache["conv"] = jnp.stack([s["conv"] for s in new_mamba_states])
        new_cache["ssd"] = jnp.stack([s["ssd"] for s in new_mamba_states])
    return x, (new_cache if cache is not None else None), aux


@dataclasses.dataclass
class JambaLM(tfm.DenseLM):
    def param_specs(self) -> Params:
        return param_specs(self.cfg)

    @property
    def num_groups(self) -> int:
        return self.cfg.num_layers // PERIOD

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray],
                return_aux: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = tfm.embed(params, tokens, cfg, self.rules)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        impl, rules = self.impl, self.rules

        def fn(gp, carry):
            x, aux = carry
            y, _, a = group_forward(gp, x, cfg, positions=positions, impl=impl,
                                    rules=rules, with_aux=True)
            return (y, aux + a)

        f = tfm._remat(fn, cfg.remat)
        if cfg.scan_layers:
            def body(carry, gp):
                return f(gp, carry), None
            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       params["layers"])
        else:
            carry = (x, jnp.float32(0.0))
            for i in range(self.num_groups):
                carry = f(_sub(params["layers"], i), carry)
            x, aux = carry
        logits = tfm.unembed(params, x, cfg, self.rules)
        if return_aux:
            n_moe = self.num_groups * len(_offsets(cfg)[2])
            return logits, cfg.moe_router_aux_coef * aux / n_moe
        return logits

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int) -> Params:
        cfg = self.cfg
        G = self.num_groups
        n_mamba = PERIOD - 1
        kv_axes = (ax.LAYERS, ax.BATCH, ax.CACHE_SEQ, ax.KV_HEADS, ax.HEAD_DIM)
        kv_shape = (G, batch, max_seq, cfg.num_kv_heads, cfg.resolved_head_dim)
        ms = mb.mamba_state_specs(cfg, batch)
        stack2 = lambda s: dataclasses.replace(
            s, shape=(G, n_mamba) + s.shape,
            axes=(ax.LAYERS, None) + s.axes)
        return {
            "k": ParamSpec(kv_shape, kv_axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
            "v": ParamSpec(kv_shape, kv_axes, init="zeros", dtype=jnp.dtype(cfg.dtype)),
            "conv": stack2(ms["conv"]),
            "ssd": stack2(ms["ssd"]),
        }

    def _serve(self, params, tokens, cache, index, kv_seq_shard):
        cfg = self.cfg
        x = tfm.embed(params, tokens, cfg, self.rules)
        if index is None:
            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        else:
            positions = index + jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def fn(gp, cl, h):
            y, nc, _ = group_forward(
                gp, h, cfg, positions=positions, cache=cl, index=index,
                impl=self.impl, rules=self.rules, kv_seq_shard=kv_seq_shard)
            return y, nc

        x, cache = tfm.scan_stack_cache(fn, params["layers"], cache, x,
                                        scan=cfg.scan_layers,
                                        length=self.num_groups)
        return x, cache

    def prefill(self, params, tokens, cache):
        x, cache = self._serve(params, tokens, cache, None, False)
        logits = tfm.unembed(params, x[:, -1:, :], self.cfg, self.rules)
        return logits[:, 0, :], cache

    def decode_step(self, params, tokens, cache, index, *, kv_seq_shard=False):
        x, cache = self._serve(params, tokens, cache, index, kv_seq_shard)
        logits = tfm.unembed(params, x, self.cfg, self.rules)
        return logits[:, -1, :], cache
