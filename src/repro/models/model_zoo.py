"""Model zoo: uniform build/loss/serve API over the ten assigned
architectures.

``build_model(cfg)`` dispatches on ``cfg.family`` and returns an LM object
exposing: param_specs / init / forward / loss-compatible logits /
cache_specs / prefill / decode_step.  ``input_specs(spec, shape)`` yields the
ShapeDtypeStruct stand-ins the dry-run lowers against (weak-type-correct, no
allocation); ``make_loss_fn`` builds the training loss including MoE aux.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchSpec, ModelConfig, ShapeConfig
from repro.models import common as cm
from repro.models import internvl as internvl_mod
from repro.models import jamba as jamba_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv6_mod
from repro.models import transformer as tfm
from repro.models import whisper as whisper_mod


def build_model(cfg: ModelConfig, *, impl: str = "xla", rules=None,
                max_seq: int = 4096):
    if cfg.family == "dense":
        return tfm.DenseLM(cfg, impl=impl, rules=rules)
    if cfg.family == "moe":
        return moe_mod.MoELM(cfg, impl=impl, rules=rules)
    if cfg.family == "rwkv6":
        return rwkv6_mod.RWKV6LM(cfg, impl=impl, rules=rules)
    if cfg.family == "hybrid":
        return jamba_mod.JambaLM(cfg, impl=impl, rules=rules)
    if cfg.family == "encdec":
        return whisper_mod.WhisperLM(cfg, impl=impl, rules=rules,
                                     max_seq=max_seq)
    if cfg.family == "vlm":
        return internvl_mod.InternVLM(cfg, impl=impl, rules=rules)
    raise ValueError(f"unknown family {cfg.family!r}")


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training / prefill batch stand-ins for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if cfg.family == "encdec":
        return {
            "tokens": _sds((B, S), tok),
            "labels": _sds((B, S), tok),
            "enc_embeds": _sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
        }
    if cfg.family == "vlm":
        t_text = S - cfg.vision_tokens
        return {
            "tokens": _sds((B, t_text), tok),
            "labels": _sds((B, t_text), tok),
            "patch_embeds": _sds((B, cfg.vision_tokens, cfg.d_model),
                                 jnp.bfloat16),
        }
    return {"tokens": _sds((B, S), tok), "labels": _sds((B, S), tok)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       model) -> Dict[str, Any]:
    """serve_step stand-ins: one new token against a seq_len cache."""
    B, S = shape.global_batch, shape.seq_len
    cache_specs = model.cache_specs(B, S)
    cache = jax.tree.map(lambda s: s.abstract(), cache_specs,
                         is_leaf=cm.is_spec)
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "cache": cache,
        "index": _sds((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Loss builders
# ---------------------------------------------------------------------------


def make_loss_fn(model, z_loss_coef: float = 0.0):
    cfg = model.cfg
    has_aux = cfg.family in ("moe", "hybrid") and cfg.moe_num_experts > 0

    def loss_fn(params, batch):
        if has_aux:
            logits, aux = model.forward(params, batch, return_aux=True)
        else:
            logits, aux = model.forward(params, batch), 0.0
        loss, metrics = tfm.lm_loss(logits, batch["labels"],
                                    z_loss_coef=z_loss_coef)
        loss = loss + aux
        if has_aux:
            metrics["moe_aux"] = aux
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


# ---------------------------------------------------------------------------
# Serve-step builders (what the decode/long dry-run cells lower)
# ---------------------------------------------------------------------------


def make_prefill_fn(model):
    cfg = model.cfg

    def prefill_fn(params, batch, cache):
        if cfg.family == "encdec":
            return model.prefill(params, batch["tokens"], cache,
                                 enc_embeds=batch["enc_embeds"])
        if cfg.family == "vlm":
            return model.prefill(params, batch["tokens"], cache,
                                 patch_embeds=batch["patch_embeds"])
        return model.prefill(params, batch["tokens"], cache)

    return prefill_fn


def make_decode_fn(model, kv_seq_shard: bool = False):
    def decode_fn(params, tokens, cache, index):
        return model.decode_step(params, tokens, cache, index,
                                 kv_seq_shard=kv_seq_shard)

    return decode_fn


def count_params(cfg: ModelConfig, max_seq: int = 4096) -> int:
    model = build_model(cfg, max_seq=max_seq)
    return cm.count_params(model.param_specs())


def active_param_ratio(cfg: ModelConfig) -> float:
    """Fraction of MoE expert params active per token (for MODEL_FLOPS)."""
    if not cfg.moe_num_experts:
        return 1.0
    return cfg.moe_top_k / cfg.moe_num_experts
