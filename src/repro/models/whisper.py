"""Whisper-small backbone — encoder-decoder with STUB conv frontend
[arXiv:2212.04356].

Per the assignment, the mel+conv frontend is a stub: ``input_specs()``
supplies precomputed frame embeddings (B, 1500, 768); the encoder is 12
bidirectional layers over those frames, the decoder is 12 causal layers with
cross-attention.  seq_len applies to the decoder token stream.  MLPs are
non-gated (fc1 -> gelu -> fc2), positions are sinusoidal (encoder) and
learned (decoder), sized to the shape's max_seq at build time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as ax
from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.common import ParamSpec
from repro.sharding.rules import shard_constraint

Params = Dict[str, Any]


def _ffn_specs(cfg: ModelConfig) -> Params:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "wi": ParamSpec((D, F), (ax.EMBED, ax.MLP)),
        "wo": ParamSpec((F, D), (ax.MLP, ax.EMBED)),
    }


def _ffn(p: Params, x, cfg: ModelConfig, rules=None):
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    hh = jax.nn.gelu(jnp.einsum("btd,df->btf", h, p["wi"].astype(h.dtype)))
    hh = shard_constraint(hh, rules, (ax.BATCH, ax.SEQ, ax.MLP))
    out = jnp.einsum("btf,fd->btd", hh, p["wo"].astype(h.dtype))
    return shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED))


def enc_layer_specs(cfg: ModelConfig) -> Params:
    return {"attn": tfm.attn_specs(cfg), "ffn": _ffn_specs(cfg)}


def dec_layer_specs(cfg: ModelConfig) -> Params:
    return {
        "self_attn": tfm.attn_specs(cfg),
        "cross_attn": tfm.attn_specs(cfg),
        "ffn": _ffn_specs(cfg),
    }


def param_specs(cfg: ModelConfig, max_seq: int) -> Params:
    D = cfg.d_model
    return {
        "encoder": cm.stack_tree(enc_layer_specs(cfg), cfg.encoder_layers),
        "enc_final_ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "decoder": cm.stack_tree(dec_layer_specs(cfg), cfg.num_layers),
        "dec_pos": ParamSpec((max_seq, D), (None, ax.EMBED), scale=0.02),
        "embedding": ParamSpec((cfg.padded_vocab, D), (ax.VOCAB, ax.EMBED)),
        "final_ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
    }


def _cross_attention(p: Params, x, enc_kv, cfg: ModelConfig, impl, rules):
    """Cross-attn: q from decoder x, (k,v) precomputed from encoder output."""
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("btd,dhk->bthk", h, p["wq"].astype(h.dtype))
    k, v = enc_kv
    from repro.kernels import ops
    o = ops.attention(q, k, v, causal=False, impl=impl)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(o.dtype))
    return shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED))


def _enc_kv(p: Params, enc_out, cfg: ModelConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


@dataclasses.dataclass
class WhisperLM(tfm.DenseLM):
    max_seq: int = 4096

    def param_specs(self) -> Params:
        return param_specs(self.cfg, self.max_seq)

    # ------------------------------------------------------------ encoder
    def encode(self, params: Params, enc_embeds: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        B, S, D = enc_embeds.shape
        x = enc_embeds.astype(cfg.dtype) + cm.sinusoidal_positions(S, D).astype(
            cfg.dtype)[None]
        positions = jnp.arange(S, dtype=jnp.int32)
        impl, rules = self.impl, self.rules

        def fn(pl, h):
            # bidirectional self-attention
            hn = cm.rms_norm(h, pl["attn"]["ln"], cfg.norm_eps)
            q = jnp.einsum("btd,dhk->bthk", hn, pl["attn"]["wq"].astype(hn.dtype))
            k = jnp.einsum("btd,dhk->bthk", hn, pl["attn"]["wk"].astype(hn.dtype))
            v = jnp.einsum("btd,dhk->bthk", hn, pl["attn"]["wv"].astype(hn.dtype))
            from repro.kernels import ops
            o = ops.attention(q, k, v, causal=False, impl=impl)
            h = h + jnp.einsum("bthk,hkd->btd", o,
                               pl["attn"]["wo"].astype(o.dtype))
            return h + _ffn(pl["ffn"], h, cfg, rules)

        x = tfm.scan_stack(fn, params["encoder"], x, remat=cfg.remat,
                           scan=cfg.scan_layers, length=cfg.encoder_layers)
        return cm.rms_norm(x, params["enc_final_ln"], cfg.norm_eps)

    # ------------------------------------------------------------ decoder
    def _dec_embed(self, params, tokens, offset):
        cfg = self.cfg
        x = cm.take_embedding(params["embedding"], tokens).astype(cfg.dtype)
        T = tokens.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], offset, T, axis=0) if not isinstance(offset, int) \
            else params["dec_pos"][offset:offset + T]
        return x + pos.astype(cfg.dtype)[None]

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray]):
        cfg = self.cfg
        tokens = batch["tokens"]
        enc_out = self.encode(params, batch["enc_embeds"])
        x = self._dec_embed(params, tokens, 0)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        impl, rules = self.impl, self.rules

        def fn(pl, h):
            a, _ = tfm.attention_block(pl["self_attn"], h, cfg,
                                       positions=positions, impl=impl,
                                       rules=rules)
            h = h + a
            kv = _enc_kv(pl["cross_attn"], enc_out, cfg)
            h = h + _cross_attention(pl["cross_attn"], h, kv, cfg, impl, rules)
            return h + _ffn(pl["ffn"], h, cfg, rules)

        x = tfm.scan_stack(fn, params["decoder"], x, remat=cfg.remat,
                           scan=cfg.scan_layers, length=cfg.num_layers)
        x = cm.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = jnp.einsum("btd,vd->btv", x,
                            params["embedding"].astype(x.dtype))
        return shard_constraint(logits, rules, (ax.BATCH, ax.SEQ, ax.VOCAB))

    # ------------------------------------------------------------- serving
    def cache_specs(self, batch: int, max_seq: int) -> Params:
        cfg = self.cfg
        kv_axes = (ax.LAYERS, ax.BATCH, ax.CACHE_SEQ, ax.KV_HEADS, ax.HEAD_DIM)
        ca_axes = (ax.LAYERS, ax.BATCH, ax.ENC_SEQ, ax.KV_HEADS, ax.HEAD_DIM)
        L, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "k": ParamSpec((L, batch, max_seq, KV, hd), kv_axes, init="zeros",
                           dtype=jnp.dtype(cfg.dtype)),
            "v": ParamSpec((L, batch, max_seq, KV, hd), kv_axes, init="zeros",
                           dtype=jnp.dtype(cfg.dtype)),
            "cross_k": ParamSpec((L, batch, cfg.encoder_seq, KV, hd), ca_axes,
                                 init="zeros", dtype=jnp.dtype(cfg.dtype)),
            "cross_v": ParamSpec((L, batch, cfg.encoder_seq, KV, hd), ca_axes,
                                 init="zeros", dtype=jnp.dtype(cfg.dtype)),
        }

    def _dec_run(self, params, tokens, cache, index, kv_seq_shard=False):
        cfg = self.cfg
        offset = 0 if index is None else index
        x = self._dec_embed(params, tokens, offset)
        if index is None:
            positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        else:
            positions = index + jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def fn(pl, cl, h):
            a, nc = tfm.attention_block(
                pl["self_attn"], h, cfg, positions=positions,
                cache=(cl["k"], cl["v"]), index=index, impl=self.impl,
                rules=self.rules, kv_seq_shard=kv_seq_shard)
            h = h + a
            h = h + _cross_attention(pl["cross_attn"], h,
                                     (cl["cross_k"], cl["cross_v"]), cfg,
                                     self.impl, self.rules)
            h = h + _ffn(pl["ffn"], h, cfg, self.rules)
            out_c = {"k": nc[0], "v": nc[1],
                     "cross_k": cl["cross_k"], "cross_v": cl["cross_v"]}
            return h, out_c

        x, cache = tfm.scan_stack_cache(fn, params["decoder"], cache, x,
                                        scan=cfg.scan_layers,
                                        length=cfg.num_layers)
        x = cm.rms_norm(x, params["final_ln"], cfg.norm_eps)
        logits = jnp.einsum("btd,vd->btv", x,
                            params["embedding"].astype(x.dtype))
        return logits, cache

    def prefill(self, params, tokens, cache, enc_embeds=None):
        """Runs the encoder, fills cross-attn caches, then decodes prompt."""
        cfg = self.cfg
        if enc_embeds is not None:
            enc_out = self.encode(params, enc_embeds)

            def fill(pl, cl):
                k, v = _enc_kv(pl["cross_attn"], enc_out, cfg)
                cl = dict(cl)
                cl["cross_k"] = k.astype(cl["cross_k"].dtype)
                cl["cross_v"] = v.astype(cl["cross_v"].dtype)
                return cl

            # per-layer cross kv (unstacked map over the layer axis)
            cache = jax.vmap(fill, in_axes=(0, 0))(params["decoder"], cache)
        logits, cache = self._dec_run(params, tokens, cache, None)
        return logits[:, -1, :], cache

    def decode_step(self, params, tokens, cache, index, *, kv_seq_shard=False):
        logits, cache = self._dec_run(params, tokens, cache, index,
                                      kv_seq_shard)
        return logits[:, -1, :], cache
