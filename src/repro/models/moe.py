"""Mixture-of-Experts LM (qwen2-moe-a2.7b, qwen3-moe-235b-a22b).

TPU-native dispatch (DESIGN.md §6): GShard/Switch-style *capacity-factor*
routing realized as dense one-hot einsums over fixed shapes — no dynamic
gather/scatter in the compiled path.  Tokens are grouped (``moe_group_size``)
so dispatch tensors are (groups, group, experts, capacity) with bounded
memory; experts run as a single batched einsum that shards over the mesh
(EP when `experts` maps to a mesh axis, per-expert TP otherwise).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as ax
from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import transformer as tfm
from repro.models.common import ParamSpec
from repro.sharding.rules import shard_constraint

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def moe_ffn_specs(cfg: ModelConfig) -> Params:
    D, E, F = cfg.d_model, cfg.moe_num_experts, cfg.d_ff
    s: Params = {
        "ln": ParamSpec((D,), (ax.EMBED,), init="ones"),
        "router": ParamSpec((D, E), (ax.EMBED, ax.EXPERTS), scale=0.1),
        "wi": ParamSpec((E, D, F), (ax.EXPERTS, ax.EMBED, ax.EXPERT_MLP)),
        "wg": ParamSpec((E, D, F), (ax.EXPERTS, ax.EMBED, ax.EXPERT_MLP)),
        "wo": ParamSpec((E, F, D), (ax.EXPERTS, ax.EXPERT_MLP, ax.EMBED)),
    }
    if cfg.moe_num_shared_experts:
        Fs = cfg.moe_shared_d_ff or cfg.moe_num_shared_experts * cfg.d_ff
        s["shared"] = {
            "wi": ParamSpec((D, Fs), (ax.EMBED, ax.MLP)),
            "wg": ParamSpec((D, Fs), (ax.EMBED, ax.MLP)),
            "wo": ParamSpec((Fs, D), (ax.MLP, ax.EMBED)),
            "gate": ParamSpec((D, 1), (ax.EMBED, None), scale=0.1),
        }
    return s


def layer_specs(cfg: ModelConfig) -> Params:
    return {"attn": tfm.attn_specs(cfg), "moe": moe_ffn_specs(cfg)}


def param_specs(cfg: ModelConfig) -> Params:
    return {
        "layers": cm.stack_tree(layer_specs(cfg), cfg.num_layers),
        **tfm.embed_specs(cfg),
    }


# ---------------------------------------------------------------------------
# Routing + expert compute
# ---------------------------------------------------------------------------


def _top_k_one_hot(gates: jnp.ndarray, k: int):
    """gates: (..., E) -> (weights (..., k), one-hot (..., k, E))."""
    vals, idx = jax.lax.top_k(gates, k)
    oh = jax.nn.one_hot(idx, gates.shape[-1], dtype=gates.dtype)
    return vals, oh


def moe_ffn(p: Params, x: jnp.ndarray, cfg: ModelConfig, rules=None,
            return_aux: bool = False):
    """Capacity-factor MoE FFN.  x: (B, T, D) -> (B, T, D)[, aux_loss].

    Grouped dispatch: flatten (B*T) -> (G, S) groups of moe_group_size; per
    group build a (S, E, C) dispatch/combine tensor via cumulative positions
    inside each expert (deterministic shapes, MXU-friendly einsums).
    """
    B, T, D = x.shape
    E, K = cfg.moe_num_experts, cfg.moe_top_k
    h = cm.rms_norm(x, p["ln"], cfg.norm_eps)
    flat = h.reshape(B * T, D)
    S = min(cfg.moe_group_size, B * T)
    while (B * T) % S != 0:   # largest divisor of B*T <= moe_group_size
        S -= 1
    G = (B * T) // S
    xs = flat.reshape(G, S, D)

    gates = jnp.einsum("gsd,de->gse", xs.astype(jnp.float32),
                       p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(gates, axis=-1)
    top_vals, top_oh = _top_k_one_hot(probs, K)           # (G,S,K), (G,S,K,E)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    # Reduce over k BEFORE the capacity one-hot (a token reaches an expert at
    # most once), keeping peak dispatch tensors at (G,S,E,C) — the K-expanded
    # (G,S,K,E,C) form is a memory blowup at 1M tokens.
    sel = top_oh.sum(axis=2)                               # (G,S,E) in {0,1}
    w_se = (top_vals[..., None] * top_oh).sum(axis=2)      # (G,S,E)

    # capacity per expert per group
    C = max(int(S * K * cfg.moe_capacity_factor / E), 1)
    C = min(C, S)
    pos = jnp.cumsum(sel, axis=1) - sel                    # (G,S,E) queue pos
    in_cap = (sel > 0) & (pos < C)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=xs.dtype)
    disp = jnp.where(in_cap[..., None], pos_oh, 0.0)       # (G,S,E,C)
    comb = disp * w_se[..., None].astype(xs.dtype)
    # Notes from the perf loop (EXPERIMENTS.md §Perf):
    # * G (token groups) is a batch dimension — constraining it replicated
    #   forces XLA to all-gather and compute EVERY group on EVERY device
    #   (measured 16x expert-compute waste; iter 1).
    # * expert-major (E leading) operand order lets the expert matmuls run
    #   as batched dots without transposing the (E,*,D) activations
    #   (iter 3: transpose/copy traffic down).
    expert_in = jnp.einsum("gsec,gsd->egcd", disp, xs)     # (E,G,C,D)
    expert_in = shard_constraint(
        expert_in, rules, (ax.EXPERTS, ax.BATCH, None, ax.EMBED))

    act = cm.activation(cfg.act)
    wi = p["wi"].astype(expert_in.dtype)
    wg = p["wg"].astype(expert_in.dtype)
    wo = p["wo"].astype(expert_in.dtype)
    gph = jnp.einsum("egcd,edf->egcf", expert_in, wg)
    uph = jnp.einsum("egcd,edf->egcf", expert_in, wi)
    hh = act(gph) * uph
    hh = shard_constraint(hh, rules,
                          (ax.EXPERTS, ax.BATCH, None, ax.EXPERT_MLP))
    expert_out = jnp.einsum("egcf,efd->egcd", hh, wo)      # (E,G,C,D)
    out = jnp.einsum("gsec,egcd->gsd", comb, expert_out)   # (G,S,D)
    out = out.reshape(B, T, D).astype(x.dtype)

    if "shared" in p:
        sp = p["shared"]
        g = jnp.einsum("btd,df->btf", h, sp["wg"].astype(h.dtype))
        u = jnp.einsum("btd,df->btf", h, sp["wi"].astype(h.dtype))
        sh = act(g) * u
        sh = shard_constraint(sh, rules, (ax.BATCH, ax.SEQ, ax.MLP))
        so = jnp.einsum("btf,fd->btd", sh, sp["wo"].astype(h.dtype))
        sg = jax.nn.sigmoid(
            jnp.einsum("btd,do->bto", h, sp["gate"].astype(h.dtype)))
        out = out + sg * so

    out = shard_constraint(out, rules, (ax.BATCH, ax.SEQ, ax.EMBED))
    if not return_aux:
        return out
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    frac = top_oh.sum(axis=2).mean(axis=(0, 1))            # tokens/expert (E,)
    mean_p = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac * mean_p)
    return out, aux


def moe_layer(p: Params, x, cfg: ModelConfig, *, positions, cache=None,
              index=None, impl="xla", rules=None, kv_seq_shard=False,
              with_aux=False):
    a, new_cache = tfm.attention_block(
        p["attn"], x, cfg, positions=positions, cache=cache, index=index,
        impl=impl, rules=rules, kv_seq_shard=kv_seq_shard,
    )
    x = x + a
    if with_aux:
        m, aux = moe_ffn(p["moe"], x, cfg, rules, return_aux=True)
        return x + m, new_cache, aux
    m = moe_ffn(p["moe"], x, cfg, rules)
    return x + m, new_cache


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MoELM(tfm.DenseLM):
    """Every layer: attention + MoE FFN (qwen MoE family)."""

    def param_specs(self) -> Params:
        return param_specs(self.cfg)

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray],
                return_aux: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = tfm.embed(params, tokens, cfg, self.rules)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
        impl, rules = self.impl, self.rules

        def fn(pl, carry):
            x, aux = carry
            y, _, a = moe_layer(pl, x, cfg, positions=positions, impl=impl,
                                rules=rules, with_aux=True)
            return (y, aux + a)

        f = tfm._remat(fn, cfg.remat)
        if cfg.scan_layers:
            def body(carry, pl):
                return f(pl, carry), None
            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       params["layers"])
        else:
            carry = (x, jnp.float32(0.0))
            for i in range(cfg.num_layers):
                carry = f(jax.tree.map(lambda a: a[i], params["layers"]), carry)
            x, aux = carry
        logits = tfm.unembed(params, x, cfg, self.rules)
        if return_aux:
            return logits, cfg.moe_router_aux_coef * aux / cfg.num_layers
        return logits

    def prefill(self, params: Params, tokens: jnp.ndarray, cache: Params):
        cfg = self.cfg
        x = tfm.embed(params, tokens, cfg, self.rules)
        positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def fn(pl, cl, h):
            y, new_c = moe_layer(
                pl, h, cfg, positions=positions, cache=(cl["k"], cl["v"]),
                impl=self.impl, rules=self.rules,
            )
            return y, {"k": new_c[0], "v": new_c[1]}

        x, cache = tfm.scan_stack_cache(fn, params["layers"], cache, x,
                                        scan=cfg.scan_layers,
                                        length=cfg.num_layers)
        logits = tfm.unembed(params, x[:, -1:, :], cfg, self.rules)
        return logits[:, 0, :], cache

    def decode_step(self, params: Params, tokens: jnp.ndarray, cache: Params,
                    index: jnp.ndarray, *, kv_seq_shard: bool = False):
        cfg = self.cfg
        x = tfm.embed(params, tokens, cfg, self.rules)
        positions = index + jnp.arange(tokens.shape[1], dtype=jnp.int32)

        def fn(pl, cl, h):
            y, new_c = moe_layer(
                pl, h, cfg, positions=positions, cache=(cl["k"], cl["v"]),
                index=index, impl=self.impl, rules=self.rules,
                kv_seq_shard=kv_seq_shard,
            )
            return y, {"k": new_c[0], "v": new_c[1]}

        x, cache = tfm.scan_stack_cache(fn, params["layers"], cache, x,
                                        scan=cfg.scan_layers,
                                        length=cfg.num_layers)
        logits = tfm.unembed(params, x, cfg, self.rules)
        return logits[:, -1, :], cache
