"""InternVL2-2B backbone — InternLM2-style dense LM with a STUB ViT frontend
[arXiv:2404.16821].

Per the assignment, the InternViT is a stub: ``input_specs()`` supplies
(B, 256, 2048) precomputed patch embeddings used as a sequence prefix; text
tokens fill the remaining positions.  The backbone is llama-like GQA (kv=8).
Loss is computed on text positions only (the prefix is sliced off).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax.numpy as jnp

from repro.configs import base as ax
from repro.models import transformer as tfm
from repro.models.common import ParamSpec

Params = Dict[str, Any]


@dataclasses.dataclass
class InternVLM(tfm.DenseLM):
    def param_specs(self) -> Params:
        s = tfm.param_specs(self.cfg)
        D = self.cfg.d_model
        # learned projector from (stub) ViT patch space into the LM embedding
        s["mm_proj"] = ParamSpec((D, D), (ax.EMBED, ax.EMBED))
        return s

    def _prefix_embed(self, params, batch):
        cfg = self.cfg
        tok_x = tfm.embed(params, batch["tokens"], cfg, self.rules)
        patch = batch["patch_embeds"].astype(cfg.dtype)
        patch = jnp.einsum("bpd,de->bpe", patch,
                           params["mm_proj"].astype(cfg.dtype))
        return jnp.concatenate([patch, tok_x], axis=1)

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray]):
        """Returns logits for TEXT positions only: (B, T_text, V)."""
        cfg = self.cfg
        x = self._prefix_embed(params, batch)
        n_patch = batch["patch_embeds"].shape[1]
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)
        x = tfm.scan_stack(
            self._layer_fn(positions), params["layers"], x,
            remat=cfg.remat, scan=cfg.scan_layers, length=cfg.num_layers)
        x = x[:, n_patch:, :]
        return tfm.unembed(params, x, cfg, self.rules)

    def prefill(self, params, tokens, cache, patch_embeds=None):
        if patch_embeds is None:
            return super().prefill(params, tokens, cache)
        x = self._prefix_embed(params, {"tokens": tokens,
                                        "patch_embeds": patch_embeds})
        cfg = self.cfg
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        def fn(pl, cl, h):
            y, nc = tfm.dense_layer(
                pl, h, cfg, positions=positions, cache=(cl["k"], cl["v"]),
                impl=self.impl, rules=self.rules)
            return y, {"k": nc[0], "v": nc[1]}

        x, cache = tfm.scan_stack_cache(fn, params["layers"], cache, x,
                                        scan=cfg.scan_layers,
                                        length=cfg.num_layers)
        logits = tfm.unembed(params, x[:, -1:, :], cfg, self.rules)
        return logits[:, 0, :], cache
