"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every parameter / activation dimension in the model zoo is annotated with a
*logical* axis name (configs/base.py).  A rules table maps each logical axis
to a tuple of physical mesh axes.  The resolver drops a mapping (axis ->
replicated) whenever the dimension size is not divisible by the product of
the mapped mesh axis sizes, or when a mesh axis is already consumed by an
earlier dimension of the same tensor — recording the fallback so the
dry-run can report it instead of failing to compile.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import base as axes

log = logging.getLogger(__name__)

# logical axis -> physical mesh axes.  () means explicitly replicated.
Rules = Mapping[str, Tuple[str, ...]]

DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    axes.BATCH: ("pod", "data"),
    axes.SEQ: (),
    axes.EMBED: (),
    axes.HEADS: ("model",),
    axes.KV_HEADS: ("model",),
    axes.HEAD_DIM: (),
    axes.MLP: ("model",),
    axes.VOCAB: ("model",),
    axes.EXPERTS: ("model",),
    axes.EXPERT_MLP: (),
    axes.LAYERS: (),
    axes.STATE: (),
    axes.CONV: (),
    axes.COMMITTEE: ("model",),
    axes.CACHE_SEQ: (),
    axes.ENC_SEQ: (),
}


def merged_rules(*overrides: Optional[Rules]) -> Dict[str, Tuple[str, ...]]:
    out = dict(DEFAULT_RULES)
    for ov in overrides:
        if ov:
            out.update({k: tuple(v) for k, v in ov.items()})
    return out


@dataclasses.dataclass
class FallbackRecord:
    tensor: str
    dim: int
    logical: str
    wanted: Tuple[str, ...]
    reason: str
    chosen: Tuple[str, ...] = ()   # mesh axes actually kept for this dim


class MeshRules:
    """Resolves logical-axis tuples to PartitionSpecs on a concrete mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[Rules] = None):
        self.mesh = mesh
        self.rules = merged_rules(rules)
        self.fallbacks: List[FallbackRecord] = []

    def _mesh_axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        if logical is None:
            return ()
        wanted = self.rules.get(logical, ())
        # drop mesh axes that don't exist on this mesh (e.g. 'pod' single-pod)
        return tuple(a for a in wanted if a in self.mesh.shape)

    def pspec(
        self,
        logical_axes: Sequence[Optional[str]],
        dims: Optional[Sequence[int]] = None,
        name: str = "?",
    ) -> P:
        """PartitionSpec for a tensor with the given logical axes.

        `dims` (concrete sizes) enables the divisibility fallback; without it
        the mapping is trusted.
        """
        used: set = set()
        entries = []
        for i, logical in enumerate(logical_axes):
            mesh_axes = self._mesh_axes_for(logical)
            if not mesh_axes:
                entries.append(None)
                continue
            # greedy subset fallback: keep every axis that is still free and
            # keeps the dim divisible, instead of dropping the whole mapping
            # (e.g. mlp -> ('model','data') with 'data' taken by batch must
            # degrade to ('model',), not to replicated).
            chosen = []
            prod = 1
            dropped_reasons = []
            for a in mesh_axes:
                if a in used:
                    dropped_reasons.append(f"{a}: mesh axis reuse")
                    continue
                sz = self.mesh.shape[a]
                if dims is not None and dims[i] % (prod * sz) != 0:
                    dropped_reasons.append(
                        f"{a}: dim {dims[i]} % {prod * sz} != 0")
                    continue
                chosen.append(a)
                prod *= sz
            if dropped_reasons:
                self.fallbacks.append(
                    FallbackRecord(name, i, logical or "?", mesh_axes,
                                   "; ".join(dropped_reasons),
                                   chosen=tuple(chosen)))
            if not chosen:
                entries.append(None)
                continue
            used.update(chosen)
            entries.append(tuple(chosen) if len(chosen) > 1 else chosen[0])
        return P(*entries)

    def sharding(
        self,
        logical_axes: Sequence[Optional[str]],
        dims: Optional[Sequence[int]] = None,
        name: str = "?",
    ) -> NamedSharding:
        return NamedSharding(self.mesh, self.pspec(logical_axes, dims, name))

    # ------------------------------------------------------------- pytrees
    def tree_pspecs(self, axes_tree, shape_tree=None):
        """Map a pytree of logical-axis tuples (+ optional ShapeDtypeStructs)
        to a pytree of PartitionSpecs."""
        if shape_tree is None:
            return jax.tree.map(
                lambda ax: self.pspec(ax),
                axes_tree,
                is_leaf=lambda x: isinstance(x, tuple),
            )
        paths = {}

        def resolve(ax, sds):
            return self.pspec(ax, sds.shape, name=str(sds.shape))

        return jax.tree.map(
            resolve, axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple)
        )

    def tree_shardings(self, axes_tree, shape_tree=None):
        ps = self.tree_pspecs(axes_tree, shape_tree)
        return jax.tree.map(lambda p: NamedSharding(self.mesh, p), ps,
                            is_leaf=lambda x: isinstance(x, P))


def logical_to_pspec(mesh: Mesh, logical_axes, rules: Optional[Rules] = None,
                     dims=None) -> P:
    return MeshRules(mesh, rules).pspec(logical_axes, dims)


def logical_sharding(mesh: Mesh, logical_axes, rules: Optional[Rules] = None,
                     dims=None) -> NamedSharding:
    return MeshRules(mesh, rules).sharding(logical_axes, dims)


def committee_shardings(mesh_rules: "MeshRules", cparams):
    """NamedShardings for a stacked-committee pytree (leading K axis).

    The leading axis follows the COMMITTEE logical-axis rules
    (``COMMITTEE -> ('model',)`` by default) and every other dimension is
    replicated: per-member parameters are small, it is the K-way ensemble
    that scales out over the mesh.  The standard divisibility fallback
    applies — a committee whose K does not divide the mapped mesh axes
    (e.g. K=4 on a 16-way model axis) degrades to replicated, recorded in
    ``mesh_rules.fallbacks`` instead of failing to compile.  Used by
    ``core/acquisition.FusedEngine``'s mesh-parallel construction path.
    """
    def leaf(a):
        shape = tuple(int(s) for s in getattr(a, "shape", ()))
        if not shape:                       # 0-d leaf: replicate
            return mesh_rules.sharding((), (), name="cparams")
        logical = (axes.COMMITTEE,) + (None,) * (len(shape) - 1)
        return mesh_rules.sharding(logical, shape, name="cparams")

    return jax.tree.map(leaf, cparams)


def warn_fallbacks(mesh_rules: Optional["MeshRules"], context: str,
                   *, start: int = 0) -> int:
    """Log a WARNING for every divisibility/axis-reuse fallback recorded on
    ``mesh_rules`` since ``start``, naming the layout actually chosen.

    A fallback is legal (the program still compiles, just with less
    parallelism than the rules asked for) but silently losing e.g. the
    committee axis on a K=3 committee over an 8-way mesh is exactly the
    kind of perf cliff that hides until someone profiles — so mesh
    consumers (``FusedEngine``, ``CommitteeTrainer``) surface it once at
    construction.  Returns the new high-water mark into
    ``mesh_rules.fallbacks`` so repeated calls don't re-warn old records.
    """
    if mesh_rules is None:
        return start
    recs = mesh_rules.fallbacks[start:]
    for r in recs:
        chosen = ",".join(r.chosen) if r.chosen else "replicated"
        log.warning(
            "%s: sharding fallback on %s dim %d (logical %s): wanted "
            "mesh axes (%s) -> using (%s) [%s]",
            context, r.tensor, r.dim, r.logical, ",".join(r.wanted),
            chosen, r.reason)
    return len(mesh_rules.fallbacks)


def shard_constraint(x, mesh_rules: Optional["MeshRules"], logical_axes):
    """with_sharding_constraint keyed by logical axes; no-op outside a mesh."""
    if mesh_rules is None:
        return x
    spec = mesh_rules.pspec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh_rules.mesh, spec)
    )
