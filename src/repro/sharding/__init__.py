from repro.sharding.rules import (  # noqa: F401
    DEFAULT_RULES,
    MeshRules,
    logical_to_pspec,
    logical_sharding,
    merged_rules,
    shard_constraint,
)
