"""Device-resident exploration: stacked walker fleets advanced, scored,
and selected in one fused dispatch (``exploration.fleet.WalkerFleet``)."""

from repro.exploration.fleet import (  # noqa: F401
    FleetConfig, PatienceRestart, WalkerFleet, make_sampler,
)
