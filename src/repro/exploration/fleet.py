"""Device-resident exploration fleet — the paper's generator processes,
vectorized.

The paper (§2.2) runs each MD walker as a host process: propose on host,
ship to the prediction kernel, wait for the committee mean, react to the
uncertainty flag.  ``WalkerFleet`` replaces N of those processes with ONE
stacked, device-resident walker state (positions, velocities, per-walker
RNG keys, patience counters) advanced by a jitted vmapped sampler step
that is FUSED with acquisition: walker advance → committee forward →
Welford UQ → selection-rule pipeline compile into a single device program
per shape bucket (``FusedEngine.score_after``).  Per-walker restart /
patience becomes a device rule (``PatienceRestart`` — the ``jnp.where``
realization of ``core/selection.PatienceTracker``), so the exchange loop
collapses to explore→score→select with the selected oracle candidates as
the only per-iteration host traffic.

Sampler protocol
----------------
A sampler is ``sample(x, v, f, keys) -> (x', v')`` in pure jnp over the
stacked ``(nb, d)`` state, with one PRNG key per walker.  Two built-ins:

  'euler'     — ``x + dt * clip(f, ±clip) + noise * N(0, 1)``; with
                ``noise=0`` this reproduces the host ``MDGenerator``
                update exactly (the parity tests drive it).
  'langevin'  — damped velocity dynamics: ``v' = (1-friction) v +
                dt * clip(f) + noise * N(0,1)``, ``x' = x + dt * v'``.

The force driving the advance is the committee MEAN from the PREVIOUS
fused round (``stats.mean`` folded back into the carry by the react step)
— the same information a host generator receives from the exchange
scatter, with zero host round trip.

Restart semantics
-----------------
``PatienceRestart`` applies the host tracker's exact update on device:
counts increment while a walker stays selected (uncertain), a count
exceeding ``patience`` flags the walker, flagged walkers reset to their
trusted state ``x0`` at the START of the next step (mirroring the host
path, where the generator receives ``None`` and restarts on its next
call).  Non-finite walkers (diverged dynamics, chaos ``nan_walker``)
reset through the same gate instead of crashing the loop.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.acquisition import FusedStepOut
from repro.core.committee import shape_bucket

_FLEET_IDS = itertools.count()


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Knobs for one walker fleet (``PALRunConfig.fleet_*`` plumbs these).

    ``patience`` follows the host semantics: a walker may stay uncertain
    for up to ``patience`` consecutive steps; the step AFTER that resets
    it to its trusted state.  ``max_steps`` (0 = unbounded) stops the
    exchange loop after that many fleet steps.
    """

    dt: float = 0.002
    clip: float = 20.0
    noise: float = 0.01
    friction: float = 0.1
    sampler: str = "euler"
    patience: int = 5
    max_steps: int = 0
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class PatienceRestart:
    """Device realization of ``selection.PatienceTracker`` — identical
    update, expressed as ``jnp.where`` over the stacked counters:

        counts'   = where(uncertain, counts + 1, 0)
        flag      = counts' > patience
        restarts' = restarts + flag
        counts''  = where(flag, 0, counts')

    ``flag`` marks walkers that must reset to their trusted state on the
    next advance (the host path realizes the same flag as a ``None``
    scatter the generator reacts to one call later)."""

    patience: int

    def apply(self, counts, restarts, uncertain):
        counts = jnp.where(uncertain, counts + 1, 0)
        flag = counts > self.patience
        restarts = restarts + flag.astype(restarts.dtype)
        counts = jnp.where(flag, 0, counts)
        return counts, restarts, flag


def make_sampler(cfg: FleetConfig) -> Callable:
    """Build the stacked sampler step ``(x, v, f, keys) -> (x', v')``."""
    dt = jnp.float32(cfg.dt)
    clip = jnp.float32(cfg.clip)
    noise = jnp.float32(cfg.noise)
    friction = jnp.float32(cfg.friction)

    def _noise(keys, d):
        return jax.vmap(lambda k: jax.random.normal(k, (d,)))(keys)

    if cfg.sampler == "euler":
        def sample(x, v, f, keys):
            fx = jnp.clip(f, -clip, clip)
            return x + dt * fx + noise * _noise(keys, x.shape[-1]), v
    elif cfg.sampler == "langevin":
        def sample(x, v, f, keys):
            fx = jnp.clip(f, -clip, clip)
            v2 = (1.0 - friction) * v + dt * fx \
                + noise * _noise(keys, x.shape[-1])
            return x + dt * v2, v2
    else:
        raise ValueError(
            f"fleet sampler {cfg.sampler!r}: expected 'euler' or 'langevin'")
    return sample


class WalkerFleet:
    """N stacked device-resident walkers, one fused dispatch per step.

    The carry pytree never leaves the device on the hot path:

        x          (nb, d)  walker positions (the proposal batch)
        v          (nb, d)  walker velocities ('langevin' sampler)
        f          (nb, d)  committee-mean force from the previous round
        key        (nb, 2)  per-walker PRNG keys (uint32)
        counts     (nb,)    consecutive-uncertain counters (PatienceRestart)
        restarts   (nb,)    realized patience restarts per walker
        flag       (nb,)    walkers that must reset on the next advance
        x0         (nb, d)  trusted restart states
        step       scalar   fleet step counter (first-call semantics)
        nan_resets scalar   walkers reset because they went non-finite

    ``step()`` calls ``engine.score_after``: the sampler advance, the
    committee forward, the Welford UQ, the rule pipeline, and the
    patience/restart react all run inside ONE compiled program; the host
    receives the selected oracle candidates and one int32 count.  The
    committee output dimension must equal the walker dimension (forces).

    ``engine`` must be a ``FusedEngine`` — the legacy per-member backend
    has no fused step entry point (the runtime enforces this).
    """

    def __init__(self, engine, x0: np.ndarray, cfg: FleetConfig,
                 monitor=None, chaos=None):
        if not hasattr(engine, "score_after"):
            raise ValueError(
                "WalkerFleet needs a fused acquisition engine "
                "(FusedEngine.score_after); the legacy per-member backend "
                "cannot fuse the walker advance with scoring")
        x0 = np.asarray(x0, np.float32)
        if x0.ndim != 2:
            raise ValueError(
                f"fleet x0 must be (n_walkers, dim), got {x0.shape}")
        self.engine = engine
        self.cfg = cfg
        self.monitor = monitor
        self.chaos = chaos
        self.n_walkers, self.dim = int(x0.shape[0]), int(x0.shape[1])
        self.nb = shape_bucket(self.n_walkers, engine.min_bucket)
        self.restart_rule = PatienceRestart(cfg.patience)
        self._sampler = make_sampler(cfg)
        # one jit-cache key per fleet instance: different fleets (different
        # sampler/patience closures) on the same engine must not collide
        self._cache_key = f"fleet{next(_FLEET_IDS)}"
        self.steps_done = 0
        self.last: Optional[FusedStepOut] = None

        pad = np.zeros((self.nb, self.dim), np.float32)
        pad[:self.n_walkers] = x0
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            jax.random.PRNGKey(cfg.seed), jnp.arange(self.nb))
        self._carry: Dict[str, Any] = {
            "x": jnp.asarray(pad),
            "v": jnp.zeros((self.nb, self.dim), jnp.float32),
            "f": jnp.zeros((self.nb, self.dim), jnp.float32),
            "key": keys,
            "counts": jnp.zeros((self.nb,), jnp.int32),
            "restarts": jnp.zeros((self.nb,), jnp.int32),
            "flag": jnp.zeros((self.nb,), bool),
            "x0": jnp.asarray(pad),
            "step": jnp.zeros((), jnp.int32),
            "nan_resets": jnp.zeros((), jnp.int32),
        }
        # on a mesh, per-walker state shards rows over the 'data' axis
        # alongside the proposal batch (scalars replicate) — without this
        # the first score_after output commits the carry to device 0 and
        # subsequent sharded dispatches reshard it every iteration
        self._carry = engine.place_carry(self._carry, self.nb)

    # ------------------------------------------------------------- device fns
    def _step_fn(self, carry):
        """Advance all walkers (traced into the fused dispatch).

        Order matches the host generator's reaction protocol: first react
        to LAST round's outcome (restart flagged walkers to x0), then
        advance with the sampler.  The very first step proposes the
        initial states unchanged — the host generators' first-call
        semantics, so scoring starts from the trusted configurations."""
        first = carry["step"] == 0
        keys = jax.vmap(jax.random.split)(carry["key"])
        sub, nxt = keys[:, 0], keys[:, 1]

        bad = ~jnp.all(jnp.isfinite(carry["x"]), axis=-1)
        reset = carry["flag"] | bad
        x = jnp.where(reset[:, None], carry["x0"], carry["x"])
        v = jnp.where(reset[:, None], 0.0, carry["v"])
        f = jnp.where(reset[:, None], 0.0, carry["f"])

        x_adv, v_adv = self._sampler(x, v, f, sub)
        # a freshly restarted (or first-step) walker proposes its trusted
        # state itself, exactly like a host generator receiving None
        skip = first | reset
        x = jnp.where(skip[:, None], x, x_adv)
        v = jnp.where(skip[:, None], v, v_adv)
        # dynamics can still diverge within the advance itself
        blown = ~jnp.all(jnp.isfinite(x), axis=-1)
        x = jnp.where(blown[:, None], carry["x0"], x)
        v = jnp.where(blown[:, None], 0.0, v)
        nan_hits = jnp.sum(bad | blown).astype(jnp.int32)

        mid = dict(
            carry, x=x, v=v, key=nxt,
            counts=jnp.where(reset, 0, carry["counts"]),
            flag=jnp.zeros_like(carry["flag"]),
            nan_resets=carry["nan_resets"] + nan_hits)
        return x, mid

    def _react_fn(self, mid, stats, mask):
        """Fold the round's outcome back into the carry (traced): patience
        counters advance on the selection mask, the committee mean becomes
        next step's driving force."""
        counts, restarts, flag = self.restart_rule.apply(
            mid["counts"], mid["restarts"], mask)
        return dict(mid, counts=counts, restarts=restarts, flag=flag,
                    f=stats.mean, step=mid["step"] + 1)

    # ------------------------------------------------------------------ step
    def step(self) -> FusedStepOut:
        """One fused explore→score→select round.  Host traffic: the
        selected oracle candidates plus one int32 count — nothing for
        unselected walkers."""
        if self.chaos is not None:
            ev = self.chaos.take("fleet.step")
            if ev is not None:
                if ev.kind == "nan_walker":
                    self.poison_walker(int(ev.arg))
                else:
                    self.chaos.execute(ev)
        carry, out = self.engine.score_after(
            self._step_fn, self._carry, self.n_walkers, self.nb,
            react_fn=self._react_fn, cache_key=self._cache_key)
        self._carry = carry
        self.steps_done += 1
        self.last = out
        return out

    # ------------------------------------------------------------ inspection
    def positions(self) -> np.ndarray:
        """(n_walkers, d) host snapshot of walker positions — diagnostics
        and tests only; the hot loop never calls this."""
        return np.asarray(self._carry["x"][:self.n_walkers])

    def stats(self) -> Dict[str, Any]:
        """Host snapshot of fleet health (PAL.report) — one transfer per
        call, off the hot path."""
        c = self._carry
        return {
            "walkers": self.n_walkers,
            "steps": int(c["step"]),
            "restarts": int(np.sum(
                np.asarray(c["restarts"][:self.n_walkers]))),
            "nan_resets": int(c["nan_resets"]),
            "uncertain_streak_max": int(np.max(
                np.asarray(c["counts"][:self.n_walkers]))),
        }

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> Dict[str, np.ndarray]:
        """Full host-numpy snapshot of the carry — including the per-walker
        RNG keys and step counter, so a restored fleet replays the exact
        trajectory (bit-identical resume)."""
        return {k: np.asarray(v) for k, v in self._carry.items()}

    def load_state_dict(self, state: Dict[str, np.ndarray]):
        if set(state) != set(self._carry):
            raise ValueError(
                f"fleet snapshot keys {sorted(state)} do not match the "
                f"carry {sorted(self._carry)}")
        self._carry = self.engine.place_carry(
            {k: jnp.asarray(v) for k, v in state.items()}, self.nb)

    # ----------------------------------------------------------------- chaos
    def poison_walker(self, i: int):
        """Set walker i's position non-finite (chaos ``nan_walker``): the
        next fused step routes it through the restart gate — reset to its
        trusted state, never a crash."""
        self._carry = dict(
            self._carry, x=self._carry["x"].at[i].set(jnp.nan))
