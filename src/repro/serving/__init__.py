from repro.serving.engine import ServeEngine, GenerationResult  # noqa: F401
