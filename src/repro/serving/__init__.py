from repro.serving.cache import LSHAnswerCache  # noqa: F401
from repro.serving.engine import (  # noqa: F401
    CommitteeServer, GenerationResult, ServeEngine,
)
from repro.serving.queue import (  # noqa: F401
    CircuitOpen, QueueConfig, QueueOverloaded, RateLimited,
    ServingQueue, ServingRejected,
)
