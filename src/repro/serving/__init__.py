from repro.serving.engine import (  # noqa: F401
    CommitteeServer, GenerationResult, ServeEngine,
)
from repro.serving.queue import QueueConfig, ServingQueue  # noqa: F401
