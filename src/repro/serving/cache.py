"""LSH near-duplicate answer cache for the serving tier (ISSUE 9).

Production serving traffic is heavily repetitive: the same (or a nearly
identical) input arrives again and again — the thermo-fluid surrogate in
the paper's SI serves grids of operating points, LM distillation replays
prompts.  When the committee was CONFIDENT about an input the last time
it saw it, re-dispatching the committee for a near-duplicate buys
nothing: the answer cannot change until the weights do.  This cache
short-circuits those requests before they reach the device.

Mechanics — the same locality-sensitive bucketing as
``core/budget.RollingReweightRule`` (``lsh_projection``: a fixed seeded
random projection, quantized and folded into ``n_buckets``), with two
serving-specific hardenings:

* **multiple projections** (``n_proj``, default 4) combined into one
  bucket id — single-projection buckets collide far too often for an
  answer cache (the re-weight rule WANTS coarse regions; a cache wants
  near-duplicates);
* **verification against the stored key row** — a bucket match alone is
  never trusted: the candidate must be within ``tol`` (L-inf) of the row
  that produced the cached answer.  ``tol=0`` (default) means
  bit-identical rows only, which makes a cache hit *bit-identical to a
  fresh dispatch* for deterministic committees (row-wise independent
  forward — tested).

Only LOW-UNCERTAINTY answers are cached: a row the rule pipeline
selected (``mask=True``) or whose ``scalar_std`` exceeds ``std_max``
must keep reaching the device (and, through it, the oracle-routing
path) — caching it would hide exactly the traffic active learning wants
to see.  The cache is GENERATION-TAGGED: ``ServingQueue`` stamps every
fill with the serving engine's weight generation (``version`` +
``device_refreshes``) and the whole cache invalidates the moment a
``refresh_from_device``/``refresh_from`` lands, because every cached
answer is stale under new weights.

Counters (read under the owner's lock via ``stats()``): ``hits`` /
``misses`` are per-row lookup outcomes; ``bypass`` counts rows that
were *deliberately not served from cache* — the caller opted out
(``use_cache=False``), or a row's hit could not be used because a
sibling row in the same request missed (requests are atomic: they are
served entirely from cache or entirely fresh); ``insertions`` and
``invalidations`` complete the picture.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.budget import lsh_projection


class _Entry:
    __slots__ = ("key", "mean", "scalar_std", "component_std", "finite")

    def __init__(self, key, mean, scalar_std, component_std, finite):
        self.key = key
        self.mean = mean
        self.scalar_std = scalar_std
        self.component_std = component_std
        self.finite = finite


class LSHAnswerCache:
    """Near-duplicate answer cache keyed by LSH bucket + verified row.

    ``n_buckets``     hash-space size (entries bounded by
                      ``n_buckets * depth``).
    ``std_max``       only answers with ``scalar_std <= std_max`` AND
                      ``mask=False`` are cached (confident answers only).
    ``tol``           L-inf verification radius around the stored key row;
                      0 = exact (bit-identical) match only.
    ``bucket_width``  projection quantization step (same role as in
                      ``RollingReweightRule``).
    ``depth``         entries kept per bucket (LRU within the bucket).
    ``seed``          projection seed — shared scheme with
                      ``lsh_projection``.

    Thread-safe; all methods take the internal lock.  ``lookup`` returns
    per-row entries or None; ``fill`` inserts eligible rows after a
    dispatch; ``note_generation`` drops everything when the weight
    generation moves.
    """

    def __init__(self, n_buckets: int = 4096, *, std_max: float,
                 tol: float = 0.0, bucket_width: float = 1.0,
                 depth: int = 4, n_proj: int = 4, seed: int = 0):
        if n_buckets < 1:
            raise ValueError("n_buckets must be >= 1")
        self.n_buckets = int(n_buckets)
        self.std_max = float(std_max)
        self.tol = float(tol)
        self.bucket_width = float(bucket_width)
        self.depth = max(int(depth), 1)
        self.n_proj = max(int(n_proj), 1)
        self.seed = int(seed)
        self._proj: Optional[np.ndarray] = None  # lazy (in_dim, n_proj)
        self._mix: Optional[np.ndarray] = None
        self._buckets: Dict[int, List[_Entry]] = {}
        self._generation: Optional[Tuple[int, ...]] = None
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.bypass = 0
        self.insertions = 0
        self.invalidations = 0

    # ------------------------------------------------------------- hashing
    def _bucket_id(self, row: np.ndarray) -> int:
        x = np.asarray(row, np.float32).reshape(-1)
        if self._proj is None or self._proj.shape[0] != x.shape[0]:
            self._proj = lsh_projection(x.shape[0], self.seed, self.n_proj)
            # odd mixing multipliers fold the n_proj quantized coordinates
            # into one bucket id (deterministic in the seed)
            self._mix = (2 * np.random.RandomState(self.seed + 1)
                         .randint(0, 2**15, self.n_proj) + 1).astype(np.int64)
        z = np.floor(x @ self._proj / self.bucket_width).astype(np.int64)
        return int((z @ self._mix) % self.n_buckets)

    # -------------------------------------------------------------- lookup
    def lookup(self, rows: Sequence[np.ndarray]) -> List[Optional[_Entry]]:
        """Per-row cached entries (None = miss).  Counts ONE hit/miss per
        row; the caller decides whether a partial-hit request can use its
        hits (ServingQueue cannot — it re-counts those as bypass via
        :meth:`note_bypass`)."""
        out: List[Optional[_Entry]] = []
        with self._lock:
            for row in rows:
                x = np.asarray(row, np.float32).reshape(-1)
                ent = self._find_locked(x)
                if ent is None:
                    self.misses += 1
                else:
                    self.hits += 1
                out.append(ent)
        return out

    def _find_locked(self, x: np.ndarray) -> Optional[_Entry]:
        chain = self._buckets.get(self._bucket_id(x))
        if not chain:
            return None
        for i, ent in enumerate(chain):
            key = ent.key
            if key.shape != x.shape:
                continue
            if self.tol <= 0.0:
                ok = np.array_equal(key, x)
            else:
                ok = bool(np.max(np.abs(key - x), initial=0.0) <= self.tol)
            if ok:
                if i != 0:                      # LRU within the bucket
                    chain.insert(0, chain.pop(i))
                return ent
        return None

    def note_bypass(self, n: int = 1):
        """Rows that had a usable hit (already counted) but were served
        fresh anyway — a request-mate missed, or the caller opted out."""
        with self._lock:
            self.bypass += int(n)

    # ---------------------------------------------------------------- fill
    def fill(self, rows: Sequence[np.ndarray], uq,
             generation: Tuple[int, ...]):
        """Insert the confident rows of one dispatched microbatch.

        ``uq`` is the dispatch's UQResult; rows with ``mask=True`` or
        ``scalar_std > std_max`` are skipped (they must keep reaching the
        device).  ``generation`` is the engine weight generation the
        answers were computed under — a fill from an older generation
        than the cache has seen is dropped entirely."""
        with self._lock:
            # weights may have moved between dispatch and fill: a moved
            # generation drops the old entries before inserting
            self._note_generation_locked(generation)
            fin = getattr(uq, "finite_members", None)
            for i, row in enumerate(rows):
                if bool(uq.mask[i]) or float(uq.scalar_std[i]) > self.std_max:
                    continue
                x = np.asarray(row, np.float32).reshape(-1)
                ent = _Entry(
                    x.copy(),
                    np.asarray(uq.mean[i]).copy(),
                    np.asarray(uq.scalar_std[i]).copy(),
                    np.asarray(uq.component_std[i]).copy(),
                    (np.asarray(fin[i]).copy() if fin is not None else None))
                chain = self._buckets.setdefault(self._bucket_id(x), [])
                # replace an existing entry for the same key (fresh answer)
                chain[:] = [e for e in chain
                            if not (e.key.shape == x.shape
                                    and np.array_equal(e.key, x))]
                chain.insert(0, ent)
                del chain[self.depth:]
                self.insertions += 1

    # -------------------------------------------------------- invalidation
    def note_generation(self, generation: Tuple[int, ...]):
        """Invalidate everything when the serving engine's weight
        generation moved (refresh_from_device / refresh_from landed):
        every cached answer is stale under new weights."""
        with self._lock:
            self._note_generation_locked(generation)

    def _note_generation_locked(self, generation: Tuple[int, ...]):
        if self._generation is not None and generation != self._generation:
            if self._buckets:
                self.invalidations += 1
            self._buckets.clear()
        self._generation = generation

    def invalidate(self):
        with self._lock:
            if self._buckets:
                self.invalidations += 1
            self._buckets.clear()

    # ---------------------------------------------------------------- stats
    def __len__(self) -> int:
        with self._lock:
            return sum(len(c) for c in self._buckets.values())

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "hits": self.hits,
                "misses": self.misses,
                "bypass": self.bypass,
                "insertions": self.insertions,
                "invalidations": self.invalidations,
                "entries": sum(len(c) for c in self._buckets.values()),
            }
