"""Multi-tenant queue-batched committee serving (ISSUE 9 tentpole; PR 4
built the microbatcher, this grows it into the production serving front).

``CommitteeServer.predict`` scores whatever batch each caller happens to
hand in — at request scale (many clients, tiny batches) that caps served
throughput at one engine dispatch per request.  ``ServingQueue`` turns N
tiny requests into ONE fused dispatch, and on top of the PR-4
microbatcher adds the three things a multi-tenant front needs:

**Per-client fairness** — ``submit(..., client=)`` tags every request
with its tenant.  Requests land in per-client FIFO queues and a
deficit-round-robin (DRR) scheduler composes each microbatch: every
backlogged client earns a row quantum per scheduling pass and spends it
on its head-of-line requests, so one flooding tenant can fill at most
its share of a microbatch and no tenant starves (a client's OWN requests
still resolve in submission order).  Per-client token buckets
(``rate_limit`` rows/s, ``rate_burst`` capacity) shed excess demand with
a typed ``RateLimited`` rejection before it ever queues.

**Adaptive latency** — instead of a statically tuned ``max_wait_ms``,
``latency_target_ms > 0`` installs a :class:`core.budget.
LatencyController`: the same multiplicative-PI controller that steers
the oracle budget, re-aimed at the observed per-request p99.  Every
``latency_window`` served requests the queue measures p99 and the
controller moves the effective deadline multiplicatively — p99 over
target shrinks it (smaller batches, less queueing), p99 under target
grows it (bigger batches, better amortization) — bounded to
``[wait_min_ms, wait_max_ms]``.  The queue trades batch size for
deadline automatically as load shifts.

**LSH answer cache** — a :class:`serving.cache.LSHAnswerCache` (same
fixed-random-projection bucketing as ``RollingReweightRule``)
short-circuits low-uncertainty repeat requests at ``submit`` time:
a request whose every row verifies against a cached confident answer
resolves immediately, paying zero device dispatches — and keeps being
served even while the circuit breaker is open.  The cache is
generation-tagged against the serving engine's weight version and
invalidates wholesale on ``refresh_from_device`` (stale answers never
outlive a weight refresh).  Uncertain rows (selected by the rule
pipeline, or ``scalar_std`` above the gate) are never cached — they must
keep reaching the device and, through it, the oracle-routing path.

Request boundaries are never split across dispatches (a request's rows
stay contiguous in one microbatch), and the scatter is by construction
order-preserving per client.  Uncertain-request routing to the oracle
buffer and budget-controller metering (``STREAM_SERVE`` rounds) happen
inside the wrapped ``CommitteeServer``, once per microbatch.

``health()`` snapshots the breaker state and EVERY counter — global and
per-client (``served`` / ``shed`` / ``cache_hits``) — under one lock, so
``PAL.report()['serve_queue_health']`` is a consistent picture, not a
torn read (ISSUE 9 satellite fix).  ``benchmarks/serving_tier.py``
measures sustained requests/s, per-tenant fairness, and p99-vs-target
under a Zipf-skewed multi-tenant load.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


class ServingRejected(RuntimeError):
    """Base of the typed fast-fail rejections the queue can raise from
    ``submit`` — callers distinguish "the service said no, retry later /
    elsewhere" from a real engine error delivered through the Future."""


class QueueOverloaded(ServingRejected):
    """Load shedding: the pending backlog exceeds ``shed_pending`` rows.
    Raised immediately instead of blocking the caller (degradation-aware
    serving sheds excess traffic rather than growing tail latency)."""


class CircuitOpen(ServingRejected):
    """Circuit breaker: ``breaker_failures`` consecutive dispatch failures
    opened the circuit; requests fail fast until the ``breaker_reset_s``
    cooldown elapses and a half-open probe succeeds."""


class RateLimited(ServingRejected):
    """Per-client token-bucket limit: this client's demand exceeded its
    ``rate_limit`` rows/s (burst ``rate_burst``).  Raised immediately —
    one tenant's burst is shed at ITS bucket instead of inflating every
    other tenant's latency."""


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """Dispatch trigger + multi-tenant policy knobs.

    ``max_batch``   rows per microbatch; a flush takes whole pending
                    requests while they fit (a single request larger than
                    ``max_batch`` is dispatched alone — the engine's shape
                    buckets absorb it).  Best chosen as a power of two
                    matching ``FusedEngine``'s buckets so the queue creates
                    no new traces.
    ``max_wait_ms`` deadline: the oldest pending request is dispatched at
                    the latest this many ms after it was enqueued.  With
                    ``latency_target_ms`` set this is only the INITIAL
                    deadline — the controller steers it afterwards.
    ``max_pending`` backpressure bound: ``submit`` BLOCKS while the
                    pending backlog holds this many rows.  0 disables.
    ``shed_pending`` load-shedding bound: when the backlog already holds
                    this many rows, ``submit`` raises ``QueueOverloaded``
                    immediately instead of blocking.  0 disables.
    ``breaker_failures`` circuit breaker: after this many CONSECUTIVE
                    dispatch failures the circuit opens and ``submit``
                    raises ``CircuitOpen`` without enqueueing; after
                    ``breaker_reset_s`` one half-open probe is admitted.
                    0 disables.
    ``breaker_reset_s`` open-state cooldown before the half-open probe.
    ``rate_limit``  per-client token-bucket refill, rows/second; a submit
                    that finds its client's bucket short raises
                    ``RateLimited``.  0 disables rate limiting.
    ``rate_burst``  bucket capacity (rows); 0 defaults to
                    ``max(rate_limit, 1)`` — one second of burst.
    ``latency_target_ms`` served-p99 target; > 0 installs the adaptive
                    deadline controller (``core/budget.LatencyController``
                    — the oracle-budget multiplicative PI on latency).
                    0 keeps the static ``max_wait_ms``.
    ``wait_min_ms``/``wait_max_ms`` the controller's authority bounds on
                    the effective deadline.
    ``latency_window`` served requests per p99 measurement / controller
                    update.
    """

    max_batch: int = 64
    max_wait_ms: float = 2.0
    max_pending: int = 4096
    shed_pending: int = 0
    breaker_failures: int = 0
    breaker_reset_s: float = 5.0
    rate_limit: float = 0.0
    rate_burst: float = 0.0
    latency_target_ms: float = 0.0
    wait_min_ms: float = 0.05
    wait_max_ms: float = 50.0
    latency_window: int = 64


class _Pending:
    __slots__ = ("rows", "future", "t_enqueue", "client")

    def __init__(self, rows: List[np.ndarray], future: Future,
                 t_enqueue: float, client: str):
        self.rows = rows
        self.future = future
        self.t_enqueue = t_enqueue
        self.client = client


class _TokenBucket:
    """Per-client rate limiter: ``rate`` rows/s refill, ``burst`` cap.
    Deterministic given an injected clock (tests drive virtual time)."""

    __slots__ = ("rate", "burst", "tokens", "t_last")

    def __init__(self, rate: float, burst: float, now: float):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)          # starts full
        self.t_last = now

    def try_take(self, n: int, now: float) -> bool:
        self.tokens = min(self.burst,
                          self.tokens + (now - self.t_last) * self.rate)
        self.t_last = now
        if n > self.tokens:
            return False
        self.tokens -= n
        return True


class ServingQueue:
    """Multi-tenant microbatching front of a
    :class:`repro.serving.engine.CommitteeServer`.

    One dispatcher thread owns the server call; submitters only enqueue
    (or resolve straight from the answer cache).  ``close()`` (or
    context-manager exit) drains pending requests, then stops the
    dispatcher.

    ``cache=`` an optional :class:`repro.serving.cache.LSHAnswerCache`;
    ``clock=`` overrides the token-bucket clock (monotonic seconds) for
    deterministic rate-limit tests.

    Counters (all mutated and snapshotted under ONE lock — ``health()``
    is a consistent picture): ``dispatches`` / ``batched_requests``
    (realized amortization), ``shed_requests`` / ``rate_limited`` /
    ``cache_hit_requests``, the breaker state, and per-client
    ``served`` / ``shed`` / ``cache_hits``.
    """

    def __init__(self, server, cfg: Optional[QueueConfig] = None, *,
                 monitor=None, cache=None, clock=time.monotonic):
        self.server = server
        self.cfg = cfg or QueueConfig()
        if self.cfg.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.monitor = monitor
        self.cache = cache
        self._clock = clock
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)       # dispatcher wakeup
        self._space = threading.Condition(self._lock)    # submitter wakeup
        # per-client FIFO queues + DRR scheduling state (under self._lock)
        self._queues: Dict[str, collections.deque] = {}
        self._rr: List[str] = []               # client rotation order
        self._rr_pos = 0
        self._deficit: Dict[str, float] = {}
        self._buckets: Dict[str, _TokenBucket] = {}
        self._per_client: Dict[str, Dict[str, int]] = {}
        self._pending_rows = 0
        self._n_pending = 0
        self._closed = False
        self.dispatches = 0
        self.batched_requests = 0
        # circuit breaker + shedding state (under self._lock)
        self._breaker_state = "closed"         # closed | open | half_open
        self._consec_failures = 0
        self._opened_at = 0.0
        self.breaker_opens = 0
        self.shed_requests = 0
        self.rate_limited = 0
        self.cache_hit_requests = 0
        self.dispatch_failures = 0
        # adaptive deadline (latency PI controller on observed p99)
        self._wait_ms = float(self.cfg.max_wait_ms)
        self._lat_ctrl = None
        self._lat_state = None
        self._lat_samples: List[float] = []
        self._p99_last: Optional[float] = None
        if self.cfg.latency_target_ms > 0.0:
            from repro.core.budget import LatencyController

            self._lat_ctrl = LatencyController(
                target_ms=float(self.cfg.latency_target_ms),
                wait_min_ms=float(self.cfg.wait_min_ms),
                wait_max_ms=float(self.cfg.wait_max_ms))
            self._lat_state = self._lat_ctrl.init_state(self._wait_ms)
            self._wait_ms = self._lat_ctrl.wait_ms(self._lat_state)
        self._worker = threading.Thread(
            target=self._run, name="serving-queue", daemon=True)
        self._worker.start()

    # ---------------------------------------------------------------- API
    def submit(self, batch_inputs: Sequence[np.ndarray], *,
               client: str = "", use_cache: bool = True) -> Future:
        """Enqueue one request (a sequence of input rows) for ``client``.
        Returns a Future resolving to ``(mean, UQResult)`` covering
        exactly these rows, in submission order.

        Raises the typed ``ServingRejected`` subclasses instead of
        queueing when degradation policy says no: ``CircuitOpen`` (engine
        failing), ``RateLimited`` (this client over its token bucket),
        ``QueueOverloaded`` (global backlog past the shed bound) — in
        that order.  A full answer-cache hit resolves immediately,
        bypassing every policy gate except the cache's own freshness
        (cached answers stay servable while the circuit is open: the
        device is what's broken, not the cached confident answers).

        Empty requests ride the queue like any other — they keep FIFO
        order with their submitter's non-empty requests and resolve to a
        zero-row result whose ``mean`` width matches their microbatch.
        Zero rows never pay an engine dispatch."""
        rows = [np.asarray(r) for r in batch_inputs]
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        # --- LSH answer cache: full-hit requests never reach the queue ----
        if self.cache is not None and rows:
            if not use_cache:
                self.cache.note_bypass(len(rows))
            else:
                hit = self._try_cache(rows, fut, client)
                if hit is not None:
                    return hit
        with self._cv:
            # circuit breaker: fail fast while open; one request through
            # as the half-open probe once the cooldown elapses
            if self._breaker_state == "open":
                if (time.monotonic() - self._opened_at
                        < self.cfg.breaker_reset_s):
                    if self.monitor is not None:
                        self.monitor.incr("serve.rejected_circuit_open")
                    raise CircuitOpen(
                        f"serving circuit open after "
                        f"{self._consec_failures} consecutive dispatch "
                        f"failures (cooldown {self.cfg.breaker_reset_s}s)")
                self._breaker_state = "half_open"
            # per-client token bucket: shed THIS client's excess before it
            # costs anyone else queue space
            if self.cfg.rate_limit > 0.0 and rows:
                bucket = self._buckets.get(client)
                if bucket is None:
                    burst = self.cfg.rate_burst or max(self.cfg.rate_limit,
                                                       1.0)
                    bucket = _TokenBucket(self.cfg.rate_limit, burst,
                                          self._clock())
                    self._buckets[client] = bucket
                if not bucket.try_take(len(rows), self._clock()):
                    self.rate_limited += 1
                    self._client_stat(client)["shed"] += 1
                    if self.monitor is not None:
                        self.monitor.incr("serve.rejected_rate_limited")
                    raise RateLimited(
                        f"client {client!r} over rate limit "
                        f"({self.cfg.rate_limit:g} rows/s, burst "
                        f"{bucket.burst:g}; request {len(rows)} rows)")
            # load shedding: typed fast-fail instead of queueing when the
            # backlog is already past the shed bound
            shed = self.cfg.shed_pending
            if shed > 0 and self._pending_rows >= shed:
                self.shed_requests += 1
                self._client_stat(client)["shed"] += 1
                if self.monitor is not None:
                    self.monitor.incr("serve.rejected_overload")
                raise QueueOverloaded(
                    f"serving backlog {self._pending_rows} rows >= "
                    f"shed_pending {shed}")
            # backpressure: block while the backlog is at the bound (an
            # oversized request is admitted once the queue is empty, so it
            # can never wait forever)
            bound = self.cfg.max_pending
            while (not self._closed and bound > 0 and self._pending_rows > 0
                   and self._pending_rows + len(rows) > bound):
                self._space.wait()
            if self._closed:
                raise RuntimeError("ServingQueue is closed")
            q = self._queues.get(client)
            if q is None:
                q = collections.deque()
                self._queues[client] = q
                self._rr.append(client)
                self._deficit.setdefault(client, 0.0)
            q.append(_Pending(rows, fut, time.perf_counter(), client))
            self._pending_rows += len(rows)
            self._n_pending += 1
            self._cv.notify()
        return fut

    def predict(self, batch_inputs: Sequence[np.ndarray], *,
                client: str = "") -> Tuple[np.ndarray, Any]:
        """Blocking convenience: ``submit(...).result()``."""
        return self.submit(batch_inputs, client=client).result()

    # --------------------------------------------------------------- cache
    def _generation(self) -> Tuple[int, ...]:
        gen_fn = getattr(self.server, "weights_generation", None)
        return gen_fn() if gen_fn is not None else (0,)

    def _try_cache(self, rows, fut: Future, client: str) -> Optional[Future]:
        """Resolve ``fut`` from the cache when EVERY row hits (requests
        are atomic: all-cached or all-fresh).  Returns the resolved
        future, or None on any miss (partial hits are re-counted as
        bypass — those rows dispatch fresh with their request-mates)."""
        from repro.core.acquisition import UQResult

        self.cache.note_generation(self._generation())
        entries = self.cache.lookup(rows)
        n_hit = sum(e is not None for e in entries)
        if n_hit < len(rows):
            if n_hit:
                self.cache.note_bypass(n_hit)
            return None
        mean = np.stack([e.mean for e in entries])
        sstd = np.stack([e.scalar_std for e in entries])
        cstd = np.stack([e.component_std for e in entries])
        fin = None
        if all(e.finite is not None for e in entries):
            fin = np.stack([e.finite for e in entries])
        uq = UQResult(mean, sstd, cstd, np.zeros(len(rows), bool), fin)
        with self._lock:
            self.cache_hit_requests += 1
            st = self._client_stat(client)
            st["cache_hits"] += 1
            st["served"] += 1
        if self.monitor is not None:
            self.monitor.incr("serve.cache_hits")
        fut.set_result((uq.mean, uq))
        return fut

    # ------------------------------------------------------------ lifecycle
    def close(self, timeout: Optional[float] = None):
        """Flush everything still pending, then stop the dispatcher.

        ``timeout`` bounds the wait for the drain (seconds; None = wait
        for it) — a caller with its own shutdown deadline (PAL.shutdown)
        must not hang behind a wedged dispatch.  The dispatcher is a
        daemon thread, so an abandoned drain cannot keep the process
        alive."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
            self._space.notify_all()     # unblock backpressured submitters
        self._worker.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):  # best-effort: don't leak the dispatcher thread
        try:
            self.close()
        except BaseException:  # noqa: BLE001  (interpreter teardown)
            pass

    # --------------------------------------------------------- dispatcher
    def _oldest_enqueue_locked(self) -> Optional[float]:
        heads = [q[0].t_enqueue for q in self._queues.values() if q]
        return min(heads) if heads else None

    def _deadline_left_locked(self) -> Optional[float]:
        """Seconds until the oldest pending request's deadline (None when
        nothing is pending).  Uses the EFFECTIVE deadline — static
        ``max_wait_ms`` or the controller-steered value."""
        oldest = self._oldest_enqueue_locked()
        if oldest is None:
            return None
        return self._wait_ms / 1e3 - (time.perf_counter() - oldest)

    def _due_locked(self) -> bool:
        if self._n_pending == 0:
            return False
        if self._pending_rows >= self.cfg.max_batch:
            return True
        left = self._deadline_left_locked()
        return left is not None and left <= 0.0

    def _take_locked(self) -> List[_Pending]:
        """Compose one microbatch by deficit round-robin over the
        backlogged clients: each scheduling pass credits every open
        client a row quantum (its share of ``max_batch``), which it
        spends on whole head-of-line requests — so a flooding tenant is
        bounded to its share while idle tenants' credit never hoards
        (deficit resets when a client's queue empties).  A request larger
        than ``max_batch`` is dispatched alone when it reaches the front
        (the engine's shape buckets absorb it)."""
        max_b = self.cfg.max_batch
        order = [c for c in self._rr if self._queues.get(c)]
        if not order:
            return []
        start = self._rr_pos % len(order)
        order = order[start:] + order[:start]    # rotate the head client
        self._rr_pos += 1
        quantum = max(1, max_b // len(order))
        took: List[_Pending] = []
        rows = 0
        open_ = set(order)
        while rows < max_b and open_:
            for c in order:
                if c not in open_:
                    continue
                q = self._queues[c]
                # credit capped at max_batch: enough to afford any request
                # that can fit, never an unbounded hoard
                self._deficit[c] = min(self._deficit[c] + quantum,
                                       float(max_b))
                while q:
                    need = len(q[0].rows)
                    if took and rows + need > max_b:
                        open_.discard(c)      # no space left this batch
                        break
                    if took and need > self._deficit[c]:
                        break                 # share spent; next pass
                    p = q.popleft()
                    took.append(p)
                    rows += need
                    self._deficit[c] -= need
                    if rows >= max_b:
                        break
                if not q:
                    self._deficit[c] = 0.0    # idle clients don't hoard
                    open_.discard(c)
                if rows >= max_b:
                    break
        self._pending_rows -= rows
        self._n_pending -= len(took)
        return took

    def _run(self):
        while True:
            with self._cv:
                while not self._closed and not self._due_locked():
                    self._cv.wait(self._deadline_left_locked())
                if self._closed and self._n_pending == 0:
                    return
                took = self._take_locked()
                if took:
                    self._space.notify_all()     # backlog shrank
            if took:
                self._dispatch(took)

    def _dispatch(self, took: List[_Pending]):
        from repro.core.acquisition import UQResult

        merged = [r for p in took for r in p.rows]
        # generation BEFORE the dispatch: if a weight refresh lands while
        # we compute, the fill is tagged stale and the next lookup's
        # note_generation drops it
        gen = self._generation() if self.cache is not None else None
        try:
            if not merged:      # all-empty microbatch: server short-circuit
                res = self.server.predict([])
                for p in took:
                    p.future.set_result(res)
                return          # no engine dispatch -> not a dispatch
            _, uq = self.server.predict(merged)
        except BaseException as e:  # noqa: BLE001 — deliver, don't die
            self._note_dispatch_failure()
            for p in took:
                p.future.set_exception(e)
            return
        self._note_dispatch_success(took)
        if self.monitor is not None:
            self.monitor.incr("serve.queue_dispatches")
            self.monitor.incr("serve.queue_batched_requests", len(took))
        if self.cache is not None:
            self.cache.fill(merged, uq, gen)
        fin = uq.finite_members
        off = 0
        now = time.perf_counter()
        lats = []
        for p in took:
            n = len(p.rows)
            sl = slice(off, off + n)
            part = UQResult(uq.mean[sl], uq.scalar_std[sl],
                            uq.component_std[sl], uq.mask[sl],
                            fin[sl] if fin is not None else None)
            p.future.set_result((part.mean, part))
            if n:
                lats.append((now - p.t_enqueue) * 1e3)
            off += n
        if self._lat_ctrl is not None and lats:
            self._observe_latency(lats)

    def _observe_latency(self, lats_ms: List[float]):
        """Feed served-request latencies to the deadline controller; one
        PI update per ``latency_window`` samples (the jnp scalar math runs
        in the dispatcher thread, off the submit path)."""
        self._lat_samples.extend(lats_ms)
        if len(self._lat_samples) < self.cfg.latency_window:
            return
        samples, self._lat_samples = self._lat_samples, []
        p99 = float(np.percentile(samples, 99))
        self._lat_state = self._lat_ctrl.update(self._lat_state, p99)
        new_wait = self._lat_ctrl.wait_ms(self._lat_state)
        with self._lock:
            self._p99_last = p99
            self._wait_ms = new_wait
        if self.monitor is not None:
            self.monitor.incr("serve.latency_updates")

    # ----------------------------------------------------- circuit breaker
    def _client_stat(self, client: str) -> Dict[str, int]:
        st = self._per_client.get(client)
        if st is None:
            st = {"served": 0, "shed": 0, "cache_hits": 0}
            self._per_client[client] = st
        return st

    def _note_dispatch_failure(self):
        with self._lock:
            self.dispatch_failures += 1
            if self.cfg.breaker_failures <= 0:
                return
            self._consec_failures += 1
            if (self._breaker_state == "half_open"
                    or self._consec_failures >= self.cfg.breaker_failures):
                if self._breaker_state != "open":
                    self.breaker_opens += 1
                    if self.monitor is not None:
                        self.monitor.incr("serve.breaker_opens")
                self._breaker_state = "open"
                self._opened_at = time.monotonic()

    def _note_dispatch_success(self, took: List[_Pending]):
        """Breaker reset + dispatch/amortization/per-client counters, all
        under the one lock ``health()`` snapshots — the report can never
        observe a dispatch count without its request counts (the ISSUE 9
        non-atomic-snapshot fix)."""
        with self._lock:
            self._consec_failures = 0
            if self._breaker_state != "closed":
                self._breaker_state = "closed"
            self.dispatches += 1
            self.batched_requests += len(took)
            for p in took:
                self._client_stat(p.client)["served"] += 1

    def health(self) -> dict:
        """Degradation-aware serving health (surfaced in ``PAL.report()``):
        breaker state plus every counter that explains it — taken under
        ONE lock so the snapshot is consistent.  ``clients`` maps tenant
        -> ``{served, shed, cache_hits}``; ``effective_wait_ms`` /
        ``p99_ms`` expose the adaptive-deadline controller; ``cache`` is
        the answer cache's own counters when one is installed."""
        with self._lock:
            h = {
                "breaker_state": self._breaker_state,
                "consecutive_failures": self._consec_failures,
                "breaker_opens": self.breaker_opens,
                "dispatch_failures": self.dispatch_failures,
                "shed_requests": self.shed_requests,
                "rate_limited": self.rate_limited,
                "cache_hit_requests": self.cache_hit_requests,
                "pending_rows": self._pending_rows,
                "dispatches": self.dispatches,
                "batched_requests": self.batched_requests,
                "effective_wait_ms": self._wait_ms,
                "p99_ms": self._p99_last,
                "clients": {c: dict(st)
                            for c, st in self._per_client.items()},
            }
        if self.cache is not None:
            h["cache"] = self.cache.stats()
        return h
