"""Queue-batched committee serving (ROADMAP: "Serving at scale").

``CommitteeServer.predict`` scores whatever batch each caller happens to
hand in — at request scale (many clients, tiny batches) that caps served
throughput at one engine dispatch per request, with the per-dispatch
overhead (host->device transfer, program launch, result sync) dominating
the actual committee math.  ``ServingQueue`` turns N tiny requests into
ONE fused dispatch:

  * callers ``submit(rows) -> Future[(mean, UQResult)]`` (or the blocking
    ``predict``) from any number of threads;
  * a dispatcher thread accumulates pending requests into a microbatch and
    fires on a size-OR-deadline trigger — ``max_batch`` rows ready, or the
    OLDEST pending request has waited ``max_wait_ms``;
  * the merged rows go through ``CommitteeServer.predict`` — i.e. the same
    unified acquisition engine dispatch as the exchange hot loop, padded
    into the engine's power-of-two shape buckets (pick ``max_batch`` as a
    bucket size and steady-state traffic compiles exactly once) — and the
    per-request slices of ``(mean, UQResult)`` are scattered back onto the
    callers' futures.

Request boundaries are never split across dispatches (a request's rows
stay contiguous in one microbatch), and the scatter is by construction
order-preserving: every caller gets exactly its own rows back, in the
order it submitted them, no matter how many submitters race.  Uncertain-
request routing to the oracle buffer and the budget controller metering
(``STREAM_SERVE`` rounds) happen inside the wrapped ``CommitteeServer``,
once per microbatch instead of once per request.

Latency/throughput trade-off: ``max_wait_ms`` bounds the extra latency a
sparse request can pay (it never waits longer than the deadline);
``max_batch`` bounds how much traffic one dispatch amortizes.  Under load
the queue fills ``max_batch`` before the deadline and the deadline never
fires; at low traffic requests ride the deadline and pay at most
``max_wait_ms`` over the bare per-call path.  ``benchmarks/serving_queue.py``
measures both ends (requests/s, p50/p99).
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np


class ServingRejected(RuntimeError):
    """Base of the typed fast-fail rejections the queue can raise from
    ``submit`` — callers distinguish "the service said no, retry later /
    elsewhere" from a real engine error delivered through the Future."""


class QueueOverloaded(ServingRejected):
    """Load shedding: the pending backlog exceeds ``shed_pending`` rows.
    Raised immediately instead of blocking the caller (degradation-aware
    serving sheds excess traffic rather than growing tail latency)."""


class CircuitOpen(ServingRejected):
    """Circuit breaker: ``breaker_failures`` consecutive dispatch failures
    opened the circuit; requests fail fast until the ``breaker_reset_s``
    cooldown elapses and a half-open probe succeeds."""


@dataclasses.dataclass(frozen=True)
class QueueConfig:
    """Size-or-deadline dispatch trigger.

    ``max_batch``   rows per microbatch; a flush takes whole pending
                    requests while they fit (a single request larger than
                    ``max_batch`` is dispatched alone — the engine's shape
                    buckets absorb it).  Best chosen as a power of two
                    matching ``FusedEngine``'s buckets so the queue creates
                    no new traces.
    ``max_wait_ms`` deadline: the oldest pending request is dispatched at
                    the latest this many ms after it was enqueued.
    ``max_pending`` backpressure bound: ``submit`` BLOCKS while the
                    pending backlog holds this many rows (so sustained
                    overload slows callers down instead of growing the
                    backlog — and per-request latency — without bound).
                    A request larger than the bound is admitted once the
                    queue is empty.  0 disables (unbounded).
    ``shed_pending`` load-shedding bound: when the backlog already holds
                    this many rows, ``submit`` raises ``QueueOverloaded``
                    immediately instead of blocking — the degradation-
                    aware alternative to backpressure for callers that
                    would rather fail fast than queue.  0 disables.
    ``breaker_failures`` circuit breaker: after this many CONSECUTIVE
                    dispatch failures the circuit opens and ``submit``
                    raises ``CircuitOpen`` without enqueueing.  After
                    ``breaker_reset_s`` the next request is admitted as a
                    half-open probe; its dispatch closing cleanly closes
                    the circuit, failing re-opens it.  0 disables.
    ``breaker_reset_s`` open-state cooldown before the half-open probe.
    """

    max_batch: int = 64
    max_wait_ms: float = 2.0
    max_pending: int = 4096
    shed_pending: int = 0
    breaker_failures: int = 0
    breaker_reset_s: float = 5.0


class _Pending:
    __slots__ = ("rows", "future", "t_enqueue")

    def __init__(self, rows: List[np.ndarray], future: Future,
                 t_enqueue: float):
        self.rows = rows
        self.future = future
        self.t_enqueue = t_enqueue


class ServingQueue:
    """Microbatching front of a :class:`repro.serving.engine.CommitteeServer`.

    One dispatcher thread owns the server call; submitters only enqueue.
    ``close()`` (or context-manager exit) drains pending requests with a
    final flush, then stops the dispatcher.

    Counters: ``dispatches`` (microbatches fired), ``batched_requests``
    (requests those carried) — ``batched_requests / dispatches`` is the
    realized amortization factor.
    """

    def __init__(self, server, cfg: Optional[QueueConfig] = None, *,
                 monitor=None):
        self.server = server
        self.cfg = cfg or QueueConfig()
        if self.cfg.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.monitor = monitor
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)       # dispatcher wakeup
        self._space = threading.Condition(self._lock)    # submitter wakeup
        self._pending: collections.deque = collections.deque()
        self._pending_rows = 0
        self._closed = False
        self.dispatches = 0
        self.batched_requests = 0
        # circuit breaker + shedding state (under self._lock)
        self._breaker_state = "closed"         # closed | open | half_open
        self._consec_failures = 0
        self._opened_at = 0.0
        self.breaker_opens = 0
        self.shed_requests = 0
        self.dispatch_failures = 0
        self._worker = threading.Thread(
            target=self._run, name="serving-queue", daemon=True)
        self._worker.start()

    # ---------------------------------------------------------------- API
    def submit(self, batch_inputs: Sequence[np.ndarray]) -> Future:
        """Enqueue one request (a sequence of input rows).  Returns a
        Future resolving to ``(mean, UQResult)`` covering exactly these
        rows, in submission order.

        Empty requests ride the queue like any other — they keep FIFO
        order with their submitter's non-empty requests and resolve to a
        zero-row result whose ``mean`` width matches their microbatch
        (resolving them eagerly here would hand back a width-0 result
        when earlier non-empty requests are still in flight).  Zero rows
        never pay an engine dispatch: an all-empty microbatch falls
        through to ``CommitteeServer.predict([])``'s short-circuit."""
        rows = [np.asarray(r) for r in batch_inputs]
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        with self._cv:
            # circuit breaker: fail fast while open; one request through
            # as the half-open probe once the cooldown elapses
            if self._breaker_state == "open":
                if (time.monotonic() - self._opened_at
                        < self.cfg.breaker_reset_s):
                    if self.monitor is not None:
                        self.monitor.incr("serve.rejected_circuit_open")
                    raise CircuitOpen(
                        f"serving circuit open after "
                        f"{self._consec_failures} consecutive dispatch "
                        f"failures (cooldown {self.cfg.breaker_reset_s}s)")
                self._breaker_state = "half_open"
            # load shedding: typed fast-fail instead of queueing when the
            # backlog is already past the shed bound
            shed = self.cfg.shed_pending
            if shed > 0 and self._pending_rows >= shed:
                self.shed_requests += 1
                if self.monitor is not None:
                    self.monitor.incr("serve.rejected_overload")
                raise QueueOverloaded(
                    f"serving backlog {self._pending_rows} rows >= "
                    f"shed_pending {shed}")
            # backpressure: block while the backlog is at the bound (an
            # oversized request is admitted once the queue is empty, so it
            # can never wait forever)
            bound = self.cfg.max_pending
            while (not self._closed and bound > 0 and self._pending_rows > 0
                   and self._pending_rows + len(rows) > bound):
                self._space.wait()
            if self._closed:
                raise RuntimeError("ServingQueue is closed")
            self._pending.append(_Pending(rows, fut, time.perf_counter()))
            self._pending_rows += len(rows)
            self._cv.notify()
        return fut

    def predict(self, batch_inputs: Sequence[np.ndarray]
                ) -> Tuple[np.ndarray, Any]:
        """Blocking convenience: ``submit(...).result()``."""
        return self.submit(batch_inputs).result()

    def close(self, timeout: Optional[float] = None):
        """Flush everything still pending, then stop the dispatcher.

        ``timeout`` bounds the wait for the drain (seconds; None = wait
        for it) — a caller with its own shutdown deadline (PAL.shutdown)
        must not hang behind a wedged dispatch.  The dispatcher is a
        daemon thread, so an abandoned drain cannot keep the process
        alive."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
            self._cv.notify_all()
            self._space.notify_all()     # unblock backpressured submitters
        self._worker.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __del__(self):  # best-effort: don't leak the dispatcher thread
        try:
            self.close()
        except BaseException:  # noqa: BLE001  (interpreter teardown)
            pass

    # --------------------------------------------------------- dispatcher
    def _deadline_left_locked(self) -> Optional[float]:
        """Seconds until the oldest pending request's deadline (None when
        nothing is pending)."""
        if not self._pending:
            return None
        age = time.perf_counter() - self._pending[0].t_enqueue
        return self.cfg.max_wait_ms / 1e3 - age

    def _due_locked(self) -> bool:
        if not self._pending:
            return False
        if self._pending_rows >= self.cfg.max_batch:
            return True
        left = self._deadline_left_locked()
        return left is not None and left <= 0.0

    def _take_locked(self) -> List[_Pending]:
        """Pop whole requests for one microbatch: while they fit in
        ``max_batch`` (an oversized first request goes out alone)."""
        took: List[_Pending] = []
        rows = 0
        while self._pending:
            nxt = self._pending[0]
            if took and rows + len(nxt.rows) > self.cfg.max_batch:
                break
            took.append(self._pending.popleft())
            rows += len(nxt.rows)
            if rows >= self.cfg.max_batch:
                break
        self._pending_rows -= rows
        return took

    def _run(self):
        while True:
            with self._cv:
                while not self._closed and not self._due_locked():
                    self._cv.wait(self._deadline_left_locked())
                if self._closed and not self._pending:
                    return
                took = self._take_locked()
                if took:
                    self._space.notify_all()     # backlog shrank
            if took:
                self._dispatch(took)

    def _dispatch(self, took: List[_Pending]):
        from repro.core.acquisition import UQResult

        merged = [r for p in took for r in p.rows]
        try:
            if not merged:      # all-empty microbatch: server short-circuit
                res = self.server.predict([])
                for p in took:
                    p.future.set_result(res)
                return          # no engine dispatch -> not a dispatch
            _, uq = self.server.predict(merged)
        except BaseException as e:  # noqa: BLE001 — deliver, don't die
            self._note_dispatch_failure()
            for p in took:
                p.future.set_exception(e)
            return
        self._note_dispatch_success()
        self.dispatches += 1
        self.batched_requests += len(took)
        if self.monitor is not None:
            self.monitor.incr("serve.queue_dispatches")
            self.monitor.incr("serve.queue_batched_requests", len(took))
        fin = uq.finite_members
        off = 0
        for p in took:
            n = len(p.rows)
            sl = slice(off, off + n)
            part = UQResult(uq.mean[sl], uq.scalar_std[sl],
                            uq.component_std[sl], uq.mask[sl],
                            fin[sl] if fin is not None else None)
            p.future.set_result((part.mean, part))
            off += n

    # ----------------------------------------------------- circuit breaker
    def _note_dispatch_failure(self):
        with self._lock:
            self.dispatch_failures += 1
            if self.cfg.breaker_failures <= 0:
                return
            self._consec_failures += 1
            if (self._breaker_state == "half_open"
                    or self._consec_failures >= self.cfg.breaker_failures):
                if self._breaker_state != "open":
                    self.breaker_opens += 1
                    if self.monitor is not None:
                        self.monitor.incr("serve.breaker_opens")
                self._breaker_state = "open"
                self._opened_at = time.monotonic()

    def _note_dispatch_success(self):
        with self._lock:
            self._consec_failures = 0
            if self._breaker_state != "closed":
                self._breaker_state = "closed"

    def health(self) -> dict:
        """Degradation-aware serving health (surfaced in ``PAL.report()``):
        breaker state plus the shed/failure counters that explain it."""
        with self._lock:
            return {
                "breaker_state": self._breaker_state,
                "consecutive_failures": self._consec_failures,
                "breaker_opens": self.breaker_opens,
                "dispatch_failures": self.dispatch_failures,
                "shed_requests": self.shed_requests,
                "pending_rows": self._pending_rows,
                "dispatches": self.dispatches,
            }
