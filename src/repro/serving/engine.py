"""Batched serving engines.

``ServeEngine`` — LM prefill + decode loop over the model zoo's uniform
cache API (KV caches for attention archs, recurrent states for rwkv6/mamba
— the engine is agnostic).  ``generate`` runs greedy / temperature sampling
with jitted prefill and decode-step closures; used by examples/serve_lm.py
and the serving smoke tests.  The decode step is the same function the
decode/long dry-run cells lower at the production mesh.

``CommitteeServer`` — served committee ensembles with batch-level UQ
(ROADMAP: "wire the acquisition engine into the serving engine's committee
path").  Every request batch is scored through the SAME unified
``core/acquisition.UQEngine`` the exchange loop uses (one fused dispatch:
committee forward + Welford statistics + rule pipeline), so serving returns
a ``UQResult`` per batch and — when given an oracle buffer — routes
high-uncertainty requests to labeling through the same cross-round budget
controller (``core/budget.BudgetRule``) that meters the exchange loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model_zoo


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, prompt+gen)
    prefill_seconds: float
    decode_seconds: float
    steps: int

    @property
    def decode_tokens_per_s(self) -> float:
        if self.decode_seconds == 0:
            return float("inf")
        return self.tokens.shape[0] * self.steps / self.decode_seconds


class CommitteeServer:
    """Serve a committee ensemble through the unified acquisition engine.

    ``predict(batch) -> (mean, UQResult)``: the committee mean is the
    served answer; the ``UQResult`` (scalar/component std + selection mask)
    is the per-request reliability signal — nothing larger than the four
    small UQ arrays ever crosses to host, exactly as on the exchange hot
    path, because it IS the exchange hot path (same engine, same compiled
    dispatch, same shape-bucketed jit cache).

    ``oracle_buffer``: when given, requests the engine's rule pipeline
    selects (``uq.mask``) are queued for labeling — online serving traffic
    becomes acquisition.  ``advance`` controls whether served batches
    advance cross-round rule state (the budget controller): True (default)
    means serving shares the oracle budget with the exchange loop — the
    controller sees and meters the TOTAL labeling demand; False makes
    serving a read-only consumer of the current threshold (it still routes,
    but never spends controller rounds).
    """

    def __init__(self, engine, oracle_buffer=None, *,
                 route_uncertain: bool = True, advance: bool = True,
                 monitor=None, out_dim: int = 0):
        self.engine = engine
        self.oracle_buffer = oracle_buffer
        self.route_uncertain = route_uncertain
        self.advance = advance
        self.monitor = monitor
        self.requests = 0
        self.routed = 0
        # output width for EMPTY results: the committee's width is only
        # observable from a scored batch, so before any non-empty traffic
        # an empty predict returns (0, out_dim) with this seed — pass
        # ``out_dim=`` if callers vstack a stream that may START empty
        self._out_dim = int(out_dim)

    def weights_generation(self) -> Tuple[int, ...]:
        """Identity of the weights currently answering requests: the
        engine's ``refresh_from`` version plus its ``refresh_from_device``
        count.  Moves exactly when a weight refresh lands — the serving
        tier's ``LSHAnswerCache`` tags every fill with this and drops
        everything the moment it changes (a cached answer never outlives
        the weights that produced it)."""
        eng = self.engine
        return (int(getattr(eng, "version", 0)),
                int(getattr(eng, "device_refreshes", 0)))

    def predict(self, batch_inputs: Sequence[np.ndarray]
                ) -> Tuple[np.ndarray, Any]:
        """Score one request batch: rows of shape (in_dim,) (or anything
        the engine's ``apply_fn`` flattens).  Returns ``(mean, UQResult)``.

        An empty batch short-circuits to an empty result — no engine
        dispatch (a zero-row score would still pad to a full shape bucket
        and pay a device program), no request/routing counters, and no
        budget-controller round.  The empty mean keeps the 2-D (0, d)
        shape of non-empty results, with d from the last non-empty batch
        — so aggregating callers can vstack across batches once any real
        traffic has flowed.  Before that, d falls back to the ``out_dim``
        constructor seed (0 if unset: the width is simply unknown).
        """
        from repro.core import acquisition as acq

        rows = [np.asarray(r) for r in batch_inputs]
        if not rows:
            zf = np.zeros(0, np.float32)
            mean = np.zeros((0, self._out_dim), np.float32)
            return mean, acq.UQResult(mean, zf, zf.copy(),
                                      np.zeros(0, bool),
                                      np.zeros(0, np.int32))
        uq = self.engine.score(rows, advance=self.advance,
                               stream=acq.STREAM_SERVE)
        self._out_dim = int(uq.mean.shape[-1])
        self.requests += len(rows)
        if self.monitor is not None:
            self.monitor.incr("serve.requests", len(rows))
        if (self.oracle_buffer is not None and self.route_uncertain
                and uq.mask.any()):
            picked = [rows[int(i)] for i in np.where(uq.mask)[0]]
            self.oracle_buffer.put(picked)
            self.routed += len(picked)
            if self.monitor is not None:
                self.monitor.incr("serve.routed_to_oracle", len(picked))
        return uq.mean, uq


class ServeEngine:
    def __init__(self, model, params, max_seq: int, batch: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        cfg = model.cfg
        self._n_prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
        self._prefill = jax.jit(model_zoo.make_prefill_fn(model))
        decode_fn = model_zoo.make_decode_fn(model)
        self._decode = jax.jit(decode_fn, donate_argnums=(2,))

    def _sample(self, logits: jnp.ndarray) -> jnp.ndarray:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.rng, k = jax.random.split(self.rng)
        return jax.random.categorical(
            k, logits / self.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch_inputs: Dict[str, np.ndarray],
                 max_new_tokens: int) -> GenerationResult:
        tokens = jnp.asarray(batch_inputs["tokens"], jnp.int32)
        B, T = tokens.shape
        n_prefix = self._n_prefix
        cache = self.model.init_cache(B, self.max_seq)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch_inputs, cache)
        logits.block_until_ready()
        t_prefill = time.perf_counter() - t0

        out = [tokens]
        cur = self._sample(logits)[:, None]
        t1 = time.perf_counter()
        for i in range(max_new_tokens):
            out.append(cur)
            if i == max_new_tokens - 1:
                break
            index = jnp.int32(n_prefix + T + i)
            logits, cache = self._decode(self.params, cur, cache, index)
            cur = self._sample(logits)[:, None]
        jax.block_until_ready(cur)
        t_decode = time.perf_counter() - t1
        return GenerationResult(
            tokens=np.asarray(jnp.concatenate(out, axis=1)),
            prefill_seconds=t_prefill,
            decode_seconds=t_decode,
            steps=max_new_tokens,
        )
