"""Config registry: ``get_arch(name)`` / ``list_archs()``.

The ten assigned architectures plus the paper's own potential-committee
scenario.  Arch ids match the assignment table.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    ArchSpec,
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    TRAIN_4K,
)

from repro.configs import (  # noqa: E402
    h2o_danube3_4b,
    internvl2_2b,
    jamba1p5_large_398b,
    llama3p2_1b,
    minicpm_2b,
    mistral_nemo_12b,
    qwen2_moe_a2p7b,
    qwen3_moe_235b_a22b,
    rwkv6_7b,
    whisper_small,
)

_REGISTRY: Dict[str, ArchSpec] = {
    "rwkv6-7b": rwkv6_7b.SPEC,
    "qwen2-moe-a2.7b": qwen2_moe_a2p7b.SPEC,
    "qwen3-moe-235b-a22b": qwen3_moe_235b_a22b.SPEC,
    "minicpm-2b": minicpm_2b.SPEC,
    "llama3.2-1b": llama3p2_1b.SPEC,
    "h2o-danube-3-4b": h2o_danube3_4b.SPEC,
    "mistral-nemo-12b": mistral_nemo_12b.SPEC,
    "jamba-1.5-large-398b": jamba1p5_large_398b.SPEC,
    "whisper-small": whisper_small.SPEC,
    "internvl2-2b": internvl2_2b.SPEC,
}


def list_archs():
    return sorted(_REGISTRY)


def get_arch(name: str) -> ArchSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {list_archs()}")
    return _REGISTRY[name]


def get_shape(spec: ArchSpec, shape_name: str) -> ShapeConfig:
    for s in spec.shapes:
        if s.name == shape_name:
            return s
    raise KeyError(f"unknown shape {shape_name!r}")
