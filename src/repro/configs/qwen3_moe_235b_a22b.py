"""qwen3-moe-235b-a22b — Qwen3 MoE family [hf:Qwen/Qwen3-30B-A3B scaled config].

94L, d_model=4096, 64H (GQA kv=4), per-expert d_ff=1536, vocab=151936,
128 routed experts top-8, qk-norm (qwen3).
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                   # per-expert
    vocab_size=151936,
    moe_num_experts=128,
    moe_top_k=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

from repro.configs.base import TrainConfig

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    # EP: 128 experts / 16 = 8 per device; expert F FSDP-sharded over `data`
    # (§Perf iter 2: 168 -> 19.6 GiB/dev); int8 Adam moments (iter 3:
    # -> 9.9 GiB/dev, fits v5e HBM).
    rules={"experts": ("model",), "expert_mlp": ("data",),
           "cache_seq": ("model",)},   # kv=4 < 16
    train=TrainConfig(quantized_opt_state=True),
)
