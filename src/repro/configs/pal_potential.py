"""pal-potential — the paper's own scenario: a committee of MLP potentials.

This is the configuration the faithful PAL reproduction runs with
(examples/potential_md.py, benchmarks/speedup_usecases.py): a
query-by-committee ensemble of fully-connected potentials on radial-basis
descriptors (paper §3.1/§3.2), energies + forces via jax.grad.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class PotentialConfig:
    name: str = "pal-potential"      # scenario tag (result paths, logs)
    n_atoms: int = 8                 # atoms per configuration
    committee_size: int = 4          # paper §3.1 uses 4 NNs
    hidden: Tuple[int, ...] = (128, 128)  # MLP hidden-layer widths
    n_rbf: int = 32                  # radial basis features per pair
    r_cut: float = 6.0               # descriptor cutoff radius (Å)
    dtype: str = "float32"           # parameter/descriptor dtype


@dataclass(frozen=True)
class PALRunConfig:
    """Mirrors the paper's AL_SETTING block (SI S3)."""

    result_dir: str = "results/pal_run"  # checkpoints / progress output dir
    pred_process: int = 1            # committee is one vmapped SPMD program
    orcl_process: int = 4            # oracle worker threads (ab initio ranks)
    gene_process: int = 8            # host generator threads (ignored when
                                     # fleet_walkers > 0)
    ml_process: int = 1              # per-member trainer threads (legacy
                                     # path; the fused trainer is one loop)
    retrain_size: int = 20           # batch size of increment retraining set
    dynamic_oracle_list: bool = True  # oracles register/deregister at
                                     # runtime (elastic pool)
    fixed_size_data: bool = True     # pad labeled blocks to fixed shapes
                                     # (stable jit signatures)
    progress_save_interval: float = 60.0  # seconds between progress dumps
    std_threshold: float = 0.05      # prediction_check uncertainty threshold
    patience: int = 5                # generator steps allowed in high-uncertainty
    weight_sync_every: int = 1       # publish weights every N retrain rounds
    exchange_min_interval: float = 0.005  # floor for one exchange iteration
                                     # (on few-core hosts a free-spinning
                                     # exchange loop starves oracle/training
                                     # threads; the paper's 51.5 ms committee
                                     # inference is an implicit throttle)
    rolling_buffer_size: int = 0     # >0 enables rolling training set (Use Case 2)
    oracle_timeout: float = 30.0     # fault tolerance: requeue after timeout
    max_oracle_retries: int = 2      # redispatches before a task FAILS
    checkpoint_every: float = 0.0    # seconds; 0 disables
    checkpoint_every_iters: int = 0  # autosave every N exchange iterations
                                     # (progress-based twin of
                                     # checkpoint_every; 0 disables)
    seed: int = 0                    # base RNG seed (committee init, LSH
                                     # projections, jitter)
    # --- supervised fault tolerance (core/supervisor.py) ------------------
    supervise: bool = True           # False: first loop crash escalates to
                                     # a StopToken (the seed's fail-stop),
                                     # via FailurePolicy.max_crashes=1
    oracle_task_retries: int = 2     # in-place retries per oracle task
                                     # before the worker reports an
                                     # OracleTaskFailure (task != worker)
    oracle_task_backoff_s: float = 0.05  # first retry delay; doubles per
                                     # attempt, jittered, capped at 2 s
    loop_max_crashes: int = 3        # crashes of one loop within the window
                                     # before the supervisor stops
                                     # restarting and escalates
    loop_crash_window_s: float = 30.0  # sliding crash-count window
    loop_restart_backoff_s: float = 0.1  # first restart delay (same growth)
    # --- degradation-aware serving (serving/queue.py) ---------------------
    serve_shed_pending: int = 0      # >0: submit() raises QueueOverloaded
                                     # once this many rows are pending
                                     # (bounded-queue load shedding);
                                     # 0 keeps pure blocking backpressure
    serve_breaker_failures: int = 0  # >0: circuit breaker opens after this
                                     # many CONSECUTIVE dispatch failures
                                     # (CircuitOpen until the reset probe);
                                     # 0 disables the breaker
    serve_breaker_reset_s: float = 5.0  # open->half-open cooldown before
                                     # one probe batch is admitted
    # --- acquisition engine (core/acquisition.make_engine) ---------------
    uq_impl: str = "auto"            # 'auto' | 'xla' | 'pallas' |
                                     # 'pallas_interpret' | 'legacy':
                                     # fused backends need committee=
                                     # CommitteeSpec(...) passed to PAL;
                                     # 'auto' picks fused-xla when one is
                                     # given, per-member legacy otherwise
    uq_block_n: int = 128            # Pallas kernel row-block size
    uq_bucket: int = 8               # min power-of-two n_gen jit bucket
    uq_mesh: str = ""                # '' (single device) | 'host'
                                     # (degenerate 1x1 mesh, CI parity) |
                                     # 'scaleout' (all visible devices on
                                     # 'data') | 'DxM' (e.g. '4x2' explicit
                                     # data x model grid) | 'production'
                                     # (16x16 data x model): mesh-parallel
                                     # fused dispatch — committee over
                                     # 'model' via the COMMITTEE sharding
                                     # rules, request batch over 'data'
    # --- cross-round budgeted acquisition (core/budget.py) ---------------
    oracle_budget: float = 0.0       # >0: target oracle-selected fraction
                                     # per exchange round — installs the
                                     # BudgetRule PI controller (seeded at
                                     # std_threshold) instead of the static
                                     # threshold rule; 0 disables
    budget_horizon: int = 16         # controller window (rounds): integral
                                     # leak + realized-rate EMA
    reweight_buckets: int = 0        # >0: RollingReweightRule region
                                     # buckets (SI Use Case 2 analog);
                                     # 0 disables
    reweight_decay: float = 0.9      # per-round bucket-score decay
    reweight_boost: float = 1.0      # max relative acquisition-score boost
    oracle_budget_exchange: float = 0.0  # per-stream target for exchange
                                     # rounds; 0 falls back to the shared
                                     # oracle_budget
    oracle_budget_serve: float = 0.0     # per-stream target for served
                                     # (STREAM_SERVE) rounds; 0 falls back
                                     # to the shared oracle_budget.  Both
                                     # streams steer ONE effective
                                     # threshold (joint control), each
                                     # against its own target;
                                     # PAL.report() breaks out the
                                     # per-stream realized rates
    serve_uq: bool = False           # serving: build a CommitteeServer on
                                     # the SAME engine (batch-level UQResult
                                     # per request; uncertain requests route
                                     # to the oracle buffer through the
                                     # same budget controller)
    # --- queue-batched serving (serving/queue.py) -------------------------
    serve_max_batch: int = 0         # >0 (with serve_uq): build
                                     # PAL.serve_queue — a ServingQueue
                                     # that fuses many small requests into
                                     # one microbatched engine dispatch;
                                     # best as a power of two matching the
                                     # engine's shape buckets (no new
                                     # traces).  0 disables
    serve_max_wait_ms: float = 2.0   # queue deadline: a pending request is
                                     # dispatched at the latest this many
                                     # ms after it was enqueued, even if
                                     # the microbatch is not full (the
                                     # INITIAL deadline when the latency
                                     # controller is on)
    # --- multi-tenant serving tier (ISSUE 9) ------------------------------
    serve_rate_limit: float = 0.0    # >0: per-client token-bucket rate
                                     # limit (rows/second); a client over
                                     # its bucket gets a typed RateLimited
                                     # rejection instead of queue space.
                                     # 0 disables rate limiting
    serve_rate_burst: float = 0.0    # token-bucket capacity (rows); 0
                                     # defaults to one second of burst
                                     # (max(serve_rate_limit, 1))
    serve_latency_target_ms: float = 0.0  # >0: adaptive deadline — a
                                     # latency PI controller (the oracle
                                     # budget controller re-aimed at p99)
                                     # steers the effective queue deadline
                                     # toward this served-p99 target.
                                     # 0 keeps the static serve_max_wait_ms
    serve_wait_min_ms: float = 0.05  # adaptive-deadline lower authority
                                     # bound (ms)
    serve_wait_max_ms: float = 50.0  # adaptive-deadline upper authority
                                     # bound (ms)
    serve_latency_window: int = 64   # served requests per p99 measurement
                                     # / controller update
    serve_cache_buckets: int = 0     # >0: LSH answer cache — confident
                                     # repeat requests short-circuit before
                                     # the device (hash-space size; entries
                                     # bounded by 4 per bucket).  The cache
                                     # invalidates wholesale on every
                                     # weight refresh.  0 disables
    serve_cache_std_max: float = 0.0  # only answers with scalar_std <=
                                     # this (and not rule-selected) are
                                     # cached; 0 falls back to
                                     # std_threshold
    serve_cache_tol: float = 0.0     # L-inf match radius around the cached
                                     # key row; 0 = bit-identical rows only
                                     # (cache hit == fresh dispatch,
                                     # exactly)
    # --- fused committee training (training/committee_trainer.py) ---------
    # Active when BOTH committee=CommitteeSpec(...) AND loss_fn= are passed
    # to PAL: the per-member ml_process trainer threads collapse into ONE
    # committee-trainer loop advancing all K members in a single vmapped
    # dispatch per step, fed from a device-resident replay ring, with
    # weights handed to the acquisition engine device-to-device.  Without a
    # loss_fn the per-member make_model(..., 'train') factories remain the
    # legacy path.
    train_steps: int = 200           # fused steps per retrain round (yields
                                     # early when a new labeled block lands)
    train_batch: int = 32            # per-member minibatch rows
    train_lr: float = 1e-3           # AdamW learning rate (constant sched)
    train_bootstrap: bool = True     # per-member bootstrap minibatches
                                     # (decorrelated members); False gives
                                     # every member the same data order
    train_replay_capacity: int = 2048  # device replay-ring rows
    train_memory_policy: str = "fp32"  # stacked-TrainState storage preset:
                                     # fp32 | bf16 | int8 (QTensor moments)
                                     # — optim/memory_policy.MemoryPolicy;
                                     # the K=64 memory-diet knob
    train_replay_dtype: str = "float32"  # replay-ring row storage (bfloat16
                                     # halves the ring + append bytes;
                                     # gathers are fp32 either way)
    # --- device-resident exploration fleet (exploration/fleet.py) ---------
    # fleet_walkers > 0 replaces the gene_process host generators with ONE
    # stacked WalkerFleet: N walkers advanced, scored, and selected in a
    # single fused dispatch per exchange iteration (requires a fused
    # engine, i.e. committee=CommitteeSpec(...)).  Trusted initial states
    # come from the first proposal of each make_generator(rank) — or an
    # explicit fleet_init=(N, dim) array passed to PAL.
    fleet_walkers: int = 0           # 0 keeps the host-generator path
    fleet_sampler: str = "euler"     # 'euler' | 'langevin'
    fleet_patience: int = 0          # consecutive-uncertain steps before a
                                     # device restart; 0 falls back to
                                     # `patience`
    fleet_dt: float = 0.002          # sampler time step
    fleet_noise: float = 0.01        # thermal-noise scale (0 = deterministic)
    fleet_clip: float = 20.0         # per-component force clip
    fleet_friction: float = 0.1      # 'langevin' velocity damping
    fleet_max_steps: int = 0         # stop the exchange after N fleet steps
                                     # (0 = run until another stop source)
    # --- platform / multi-process launch (launch/platform.py,
    # launch/distributed.py) ----------------------------------------------
    # Process-level runtime knobs: launch scripts call
    # `platform.configure(...)` / `distributed.initialize_from_config(cfg)`
    # BEFORE building engines, so one config describes the whole launch.
    platform: str = ""               # '' (auto) | 'cpu' | 'gpu' | 'tpu' —
                                     # pinned before backend init
    host_devices: int = 0            # >0: emulated host devices
                                     # (--xla_force_host_platform_device_
                                     # count=N, set before jax import) —
                                     # how CI runs a real 8-device mesh
                                     # on one CPU host
    enable_x64: bool = False         # double-precision jax (oracle-side
                                     # reference computations)
    gpu_autotune: bool = False       # append the XLA GPU autotune flag set
    dist_coordinator: str = ""       # 'host:port' of process 0 enables the
                                     # jax.distributed multi-process launch
                                     # (one jit program spanning hosts)
    dist_processes: int = 0          # total process count in the launch
    dist_process_id: int = -1        # this process's id (0-based); -1 reads
                                     # JAX_PROCESS_ID / PAL_PROCESS_ID env
    dist_cpu_collectives: str = "gloo"  # CPU cross-process collectives
                                     # backend ('gloo' | 'mpi'); ignored
                                     # off-CPU


DEFAULT = PotentialConfig()
DEFAULT_RUN = PALRunConfig()
