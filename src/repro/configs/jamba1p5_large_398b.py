"""jamba-1.5-large-398b — Mamba+attention 1:7 interleave, MoE
[arXiv:2403.19887 / Jamba-1.5].

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab=65536, 16 experts top-2
on every other layer; 1 attention layer per 8 (offset 4).
Hybrid family: `long_500k` RUNS (mamba state O(1), 9 attention layers' KV
sharded over `data` on the cache-sequence axis).

Mamba mixer realized in the SSD-chunked TPU form (DESIGN.md §6).
"""
from repro.configs.base import ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    moe_num_experts=16,
    moe_top_k=2,
    moe_layer_period=2,
    moe_layer_offset=1,
    attn_layer_period=8,
    attn_layer_offset=4,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
    mamba_head_dim=128,
    rope_theta=10_000.0,     # jamba attention layers use no rope in v1; 1.5 uses it
)

SPEC = ArchSpec(
    model=MODEL,
    # EP 16/16 over `model`; expert F FSDP over `data` (§Perf: serving
    # residency 47 -> 8.7 GiB/dev, training master/moments sharded 256-way)
    # dense-FFN / mamba inner dim F=24576 shards over BOTH axes (256-way,
    # §Perf: non-expert master+moments 18 -> 1.1 GiB/dev)
    rules={"experts": ("model",), "expert_mlp": ("data",),
           "mlp": ("model", "data"),
           "cache_seq": ("model",)},                   # kv=8 < 16 (decode_32k)
    serve_rules={"mlp": ("model",)},   # serving: bf16 weights fit at 16-way
                                       # TP; 256-way costs gather collectives
    train=TrainConfig(quantized_opt_state=True),
)
