"""internvl2-2b — InternViT + InternLM2 [arXiv:2404.16821].

Backbone only (assignment): 24L, d_model=2048, 16H (GQA kv=8), d_ff=8192,
vocab=92553 (padded 92672).  The InternViT frontend is a STUB —
`input_specs()` provides (B, 256, 2048) precomputed patch embeddings used as
a sequence prefix; text tokens fill the remaining positions.
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    vision_tokens=256,
    rope_theta=1_000_000.0,
)

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    rules={"cache_seq": ("model",)},   # kv=8 < 16
)
