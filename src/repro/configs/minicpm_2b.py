"""minicpm-2b — WSD schedule, llama-like [arXiv:2404.06395].

40L, d_model=2304, 36H MHA, d_ff=5760, vocab=122753 (padded to 122880).
36 heads don't divide 16 -> heads unsharded, TP via d_ff + vocab.
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig, TrainConfig

MODEL = ModelConfig(
    name="minicpm-2b",
    family="dense",
    num_layers=40,
    d_model=2304,
    num_heads=36,
    num_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    tie_embeddings=True,
    rope_theta=10_000.0,
)

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    # 36 MHA heads don't divide 16 -> heads unshardable; Ulysses-style
    # sequence sharding instead (§Perf: useful flops 0.13 -> 0.91, the
    # dominant memory term 45.4s -> 5.7s)
    rules={"cache_seq": ("model",), "seq": ("model",)},
    train=TrainConfig(schedule="wsd", warmup_steps=100, stable_steps=8000,
                      decay_steps=10_000),
)
