"""whisper-small — encoder-decoder with conv frontend STUB [arXiv:2212.04356].

12L encoder + 12L decoder, d_model=768, 12H MHA, d_ff=3072, vocab=51865
(padded 51968).  `input_specs()` provides precomputed frame embeddings
(B, 1500, 768) — the mel+conv frontend is a stub per the assignment.
seq_len applies to the decoder token stream.
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,             # decoder layers
    encoder_layers=12,
    encoder_seq=1500,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    act="gelu",
    rope_theta=0.0,            # whisper uses learned positions, not rope
)

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    # 12 heads < 16 -> unshardable; sequence sharding as for minicpm
    rules={"cache_seq": ("model",), "seq": ("model",)},
)
