"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a :class:`ModelConfig`; input
shapes as :class:`ShapeConfig`; distribution as :class:`MeshRules` (logical
axis -> mesh axes).  Configs are plain frozen dataclasses so they hash, print,
and diff cleanly, and `replace()` covers reduced smoke variants.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Logical axis names used to annotate every parameter / activation dimension.
# sharding/rules.py maps these onto physical mesh axes.
# ---------------------------------------------------------------------------
BATCH = "batch"
SEQ = "seq"
EMBED = "embed"
HEADS = "heads"
KV_HEADS = "kv_heads"
HEAD_DIM = "head_dim"
MLP = "mlp"
VOCAB = "vocab"
EXPERTS = "experts"
EXPERT_MLP = "expert_mlp"
LAYERS = "layers"
STATE = "state"          # SSM state dim
CONV = "conv"            # conv kernel dim
COMMITTEE = "committee"
CACHE_SEQ = "cache_seq"  # KV-cache sequence axis (decode)
ENC_SEQ = "enc_seq"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters (full, literature-exact configs)."""

    name: str
    family: str  # dense | moe | rwkv6 | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // num_heads

    # --- MoE ---
    moe_num_experts: int = 0
    moe_top_k: int = 0
    moe_num_shared_experts: int = 0
    moe_shared_d_ff: int = 0          # d_ff of the shared-expert block (qwen2-moe)
    moe_layer_period: int = 1         # MoE on layers where i % period == offset
    moe_layer_offset: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 1024        # dispatch group size (bounds dispatch FLOPs)
    moe_router_aux_coef: float = 0.01

    # --- attention ---
    sliding_window: Optional[int] = None
    rope_theta: float = 1_000_000.0
    qk_norm: bool = False

    # --- hybrid (jamba) ---
    attn_layer_period: int = 0        # 1 attention layer per `period` layers (jamba: 8)
    attn_layer_offset: int = 0
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_head_dim: int = 64          # SSD head dim (TPU adaptation, DESIGN.md §6)

    # --- rwkv6 ---
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64          # rank of the data-dependence LoRAs
    rwkv_decay_lora_rank: int = 64

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper frame positions (post conv stub)

    # --- vlm (internvl) ---
    vision_tokens: int = 0            # stub patch-embedding prefix length

    # --- misc ---
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                 # mlp activation
    dtype: str = "bfloat16"           # activation / compute dtype
    param_dtype: str = "float32"
    vocab_pad_multiple: int = 128
    scan_layers: bool = True
    remat: str = "dots"               # none | dots | full
    logit_softcap: float = 0.0

    # ----------------------------------------------------------------- utils
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, self.vocab_pad_multiple)

    @property
    def rwkv_num_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def mamba_num_heads(self) -> int:
        return self.mamba_d_inner // self.mamba_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Layer-type helpers (hybrid / moe interleave) --------------------------
    def is_attention_layer(self, i: int) -> bool:
        if self.family == "rwkv6":
            return False
        if self.attn_layer_period:
            return i % self.attn_layer_period == self.attn_layer_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.moe_num_experts:
            return False
        return i % self.moe_layer_period == self.moe_layer_offset


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode
    # rule overrides applied on top of the arch rules for this shape
    rule_overrides: Mapping[str, Tuple[str, ...]] = field(default_factory=dict)


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig(
    "long_500k", 524288, 1, "decode",
    rule_overrides={CACHE_SEQ: ("data",)},
)

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule / step configuration."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip_norm: float = 1.0
    schedule: str = "cosine"          # cosine | wsd | constant
    warmup_steps: int = 100
    decay_steps: int = 10_000
    stable_steps: int = 0             # WSD plateau
    min_lr_ratio: float = 0.1
    accum_steps: int = 1
    zero1: bool = True                # shard opt state over `data` where divisible
    quantized_opt_state: bool = False # legacy alias for opt_moments="int8"
    opt_moments: str = ""             # "" | fp32 | bf16 | int8 — AdamW
                                      # moment storage (optim/adamw.py
                                      # resolve_moments; "" defers to
                                      # quantized_opt_state)
    grad_compression: str = "none"    # none | bf16 (cast at DP-reduce point)
    z_loss_coef: float = 0.0


@dataclass(frozen=True)
class ArchSpec:
    """Everything the launcher needs for one assigned architecture."""

    model: ModelConfig
    shapes: Tuple[ShapeConfig, ...] = ALL_SHAPES
    # shapes skipped with a reason (e.g. long_500k on pure full attention)
    skip_shapes: Mapping[str, str] = field(default_factory=dict)
    # logical axis -> mesh axes; merged over sharding.rules.DEFAULT_RULES
    rules: Mapping[str, Tuple[str, ...]] = field(default_factory=dict)
    # extra overrides applied ONLY for serving kinds (prefill/decode) —
    # e.g. jamba wants 256-way FFN sharding for optimizer state in training
    # but plain 16-way TP when serving bf16 weights (less gather traffic)
    serve_rules: Mapping[str, Tuple[str, ...]] = field(default_factory=dict)
    train: TrainConfig = TrainConfig()

    def runnable_shapes(self) -> Sequence[ShapeConfig]:
        return [s for s in self.shapes if s.name not in self.skip_shapes]


FULL_ATTN_LONG_SKIP = (
    "long_500k skipped: pure full-attention architecture (O(S) KV cache and "
    "O(S^2) prefill at 524288 would not be served this way); see DESIGN.md §5"
)
