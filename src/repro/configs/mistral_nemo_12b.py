"""mistral-nemo-12b — 128k context [hf:mistralai/Mistral-Nemo-Base-2407].

40L, d_model=5120, 32H (GQA kv=8) with explicit head_dim=128 (32*128=4096
!= d_model — true Nemo config), d_ff=14336, vocab=131072.
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000.0,
)

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    rules={"cache_seq": ("model",)},   # kv=8 < 16
)
