"""h2o-danube-3-4b — llama+mistral mix with sliding-window attention
[arXiv:2401.16818].

24L, d_model=3840, 32H (GQA kv=8), d_ff=10240, vocab=32000, SWA window 4096.
Baseline long_500k is skipped with the full-attention archs; the SWA-bounded
decode cache variant is exercised in §Perf (DESIGN.md §5).
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10_000.0,
)

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    rules={"cache_seq": ("model",)},   # kv=8 < 16
)
