"""llama3.2-1b — small llama3 [hf:meta-llama/Llama-3.2-1B].

16L, d_model=2048, 32H (GQA kv=8), d_ff=8192, vocab=128256.
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="llama3.2-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    tie_embeddings=True,
    rope_theta=500_000.0,
)

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    # kv=8 cannot shard 16-way -> decode cache shards its sequence axis
    rules={"cache_seq": ("model",)},
)
