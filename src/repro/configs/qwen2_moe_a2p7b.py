"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model=2048, 16H (kv=16), per-expert d_ff=1408, vocab=151936,
60 routed experts top-4 + 4 shared experts (shared d_ff = 4*1408 = 5632).
"""
from repro.configs.base import FULL_ATTN_LONG_SKIP, ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,                   # per-expert
    vocab_size=151936,
    moe_num_experts=60,
    moe_top_k=4,
    moe_num_shared_experts=4,
    moe_shared_d_ff=5632,
    moe_group_size=256,          # §Perf iter 2/4: dispatch cost ~ E*C*D, C ~ S

    rope_theta=1_000_000.0,
)

SPEC = ArchSpec(
    model=MODEL,
    skip_shapes={"long_500k": FULL_ATTN_LONG_SKIP},
    # 60 experts don't divide 16 -> per-expert TP on d_ff (1408/16=88);
    # rules resolver falls back automatically, pinned here for clarity.
    rules={"experts": (), "expert_mlp": ("model",)},
)
