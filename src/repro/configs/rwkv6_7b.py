"""rwkv6-7b — Finch, data-dependent decay [arXiv:2404.05892].

32L, d_model=4096, attention-free, d_ff=14336, vocab=65536.
Linear-attention family: `long_500k` RUNS (O(1) decode state).
"""
from repro.configs.base import ArchSpec, ModelConfig

MODEL = ModelConfig(
    name="rwkv6-7b",
    family="rwkv6",
    num_layers=32,
    d_model=4096,
    num_heads=64,            # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    rwkv_lora_rank=64,
    rwkv_decay_lora_rank=128,
    tie_embeddings=False,
    act="relu_sq",           # rwkv channel-mix uses squared ReLU
)

SPEC = ArchSpec(model=MODEL)
