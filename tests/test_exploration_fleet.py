"""Device-resident exploration fleet tests.

Fleet-vs-host parity: an N=1 ``WalkerFleet`` with the deterministic
(noise=0) Euler sampler reproduces the host generator trajectory and the
same selection decisions through the legacy per-generator Exchange path,
on both fused backends; the device ``PatienceRestart`` rule matches the
host ``PatienceTracker`` counter semantics including restart flags.  Plus:
zero-per-iteration-host-bytes accounting, bit-identical checkpoint
resume, the chaos ``nan_walker`` reset, the Exchange fleet fast path, and
the legacy-path satellite fixes (gather_ns counter, drain-on-stop).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import PAL
from repro.core import acquisition as acq
from repro.core import budget as bud
from repro.core import committee as cmte
from repro.core import selection as sel
from repro.core.buffers import OracleInputBuffer
from repro.core.chaos import ChaosInjector, FaultEvent, FaultPlan
from repro.core.controller import Exchange, ExchangeConfig, PredictionPool
from repro.exploration.fleet import (
    FleetConfig, PatienceRestart, WalkerFleet,
)

D = 6
IMPLS = ["xla", "pallas_interpret"]
DT, CLIP = 0.002, 20.0


def _committee(seed=0, k=4, scale=0.03):
    """K slightly-perturbed linear force fields f = x @ W + b: smooth
    committee disagreement that grows with |x|, so trajectories drift
    between certain and uncertain regions."""
    rng = np.random.RandomState(seed)
    members = [
        {"w": jnp.asarray(-0.05 * np.eye(D) + scale * rng.randn(D, D),
                          jnp.float32),
         "b": jnp.asarray(scale * rng.randn(D), jnp.float32)}
        for _ in range(k)]
    return cmte.stack_members(members), (lambda p, x: x @ p["w"] + p["b"])


class DetGene:
    """Host reference walker with the fleet's exact deterministic update:
    first call and restarts propose the trusted state; otherwise
    ``x + dt * clip(f, ±clip)`` on the scattered committee mean."""

    def __init__(self, x0, max_steps=10 ** 9):
        self.x0 = np.asarray(x0, np.float32)
        self.x = self.x0.copy()
        self.steps = 0
        self.max_steps = max_steps
        self.trajectory = []

    def generate_new_data(self, data_to_gene):
        self.steps += 1
        if self.steps > self.max_steps:
            return True, self.x
        if data_to_gene is None and self.steps > 1:
            self.x = self.x0.copy()
        elif data_to_gene is not None:
            f = np.clip(np.asarray(data_to_gene, np.float32), -CLIP, CLIP)
            self.x = (self.x + np.float32(DT) * f).astype(np.float32)
        self.trajectory.append(self.x.copy())
        return False, self.x

    def save_progress(self):
        pass

    def stop_run(self):
        pass


def _det_cfg(patience, **kw):
    kw.setdefault("dt", DT)
    kw.setdefault("clip", CLIP)
    kw.setdefault("noise", 0.0)
    return FleetConfig(patience=patience, **kw)


# ---------------------------------------------------------------------------
# tentpole: fleet-vs-host parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
def test_fleet_matches_host_generator_trajectory(impl):
    """N=1 deterministic fleet ≡ host generator through the legacy
    Exchange: same trajectory, same oracle queue, same restart counts."""
    cparams, apply_fn = _committee()
    x0 = np.full(D, 0.8, np.float32)
    threshold, patience, steps = 0.012, 3, 40

    # host path: one generator through the legacy per-generator Exchange
    eng_h = acq.FusedEngine(apply_fn, cparams, threshold, impl=impl)
    gen = DetGene(x0)
    ex = Exchange([gen], PredictionPool([], None, engine=eng_h),
                  OracleInputBuffer(),
                  ExchangeConfig(std_threshold=threshold, patience=patience,
                                 min_interval=0.0))
    for _ in range(steps):
        assert ex.step() is None
    host_queue = ex.oracle_buffer.snapshot()

    # fleet path: the same walker as a 1-walker fleet (padded to the same
    # engine bucket, so both backends see one compiled shape)
    eng_f = acq.FusedEngine(apply_fn, cparams, threshold, impl=impl)
    fleet = WalkerFleet(eng_f, x0[None, :], _det_cfg(patience))
    fleet_traj, fleet_queue = [], []
    for _ in range(steps):
        out = fleet.step()
        fleet_traj.append(fleet.positions()[0])
        fleet_queue.extend(list(out.selected))

    host_traj = np.stack(gen.trajectory)
    fleet_traj = np.stack(fleet_traj)
    # same dynamics, device vs host fp32 (FMA contraction differs)
    np.testing.assert_allclose(fleet_traj, host_traj, atol=5e-5, rtol=0)
    # identical selection decisions -> identical oracle queues
    assert len(fleet_queue) == len(host_queue)
    for a, b in zip(fleet_queue, host_queue):
        np.testing.assert_allclose(a, np.asarray(b, np.float32),
                                   atol=5e-5, rtol=0)
    # identical restart realizations — and the scenario exercises both
    assert fleet.stats()["restarts"] == int(ex.patience.restarts[0])
    assert fleet.stats()["restarts"] > 0
    assert len(host_queue) > 0


@pytest.mark.parametrize("impl", IMPLS)
def test_fleet_selection_results_match_engine_score(impl):
    """Per-step parity of the selection decision itself: the fused
    step+score dispatch selects exactly what scoring the same proposals
    through ``UQEngine.score`` / ``selection_from_uq`` would."""
    cparams, apply_fn = _committee(seed=3)
    x0 = np.stack([np.full(D, 0.5 + 0.3 * i, np.float32) for i in range(3)])
    eng_f = acq.FusedEngine(apply_fn, cparams, 0.01, impl=impl)
    eng_s = acq.FusedEngine(apply_fn, cparams, 0.01, impl=impl)
    fleet = WalkerFleet(eng_f, x0, _det_cfg(patience=4))
    n = fleet.n_walkers
    for _ in range(12):
        out = fleet.step()
        proposals = list(fleet.positions())
        res = sel.selection_from_uq(proposals, eng_s.score(proposals))
        assert np.array_equal(np.asarray(out.mask)[:n], res.uncertain_mask)
        np.testing.assert_allclose(np.asarray(out.scalar_std)[:n], res.std,
                                   rtol=1e-6)
        assert out.n_selected == len(res.inputs_to_oracle)
        for a, b in zip(out.selected, res.inputs_to_oracle):
            np.testing.assert_array_equal(a, b)


def test_patience_restart_matches_host_tracker():
    """Device PatienceRestart ≡ host PatienceTracker, step for step,
    including the restart flags."""
    rng = np.random.RandomState(0)
    n, patience = 7, 3
    host = sel.PatienceTracker(n, patience)
    rule = PatienceRestart(patience)
    counts = jnp.zeros(n, jnp.int32)
    restarts = jnp.zeros(n, jnp.int32)
    for _ in range(60):
        mask = rng.rand(n) < 0.7
        flag_host = host.step(mask)
        counts, restarts, flag = rule.apply(counts, restarts,
                                            jnp.asarray(mask))
        assert np.array_equal(np.asarray(flag), flag_host)
        assert np.array_equal(np.asarray(counts), host.counts)
        assert np.array_equal(np.asarray(restarts), host.restarts)


# ---------------------------------------------------------------------------
# host-byte accounting and jit-cache isolation
# ---------------------------------------------------------------------------


def test_fleet_zero_host_bytes_for_unselected_walkers():
    """The hot loop uploads nothing and downloads only the selected rows
    plus one int32 count — nothing per unselected walker."""
    cparams, apply_fn = _committee()
    # huge threshold: nothing is ever selected
    eng = acq.FusedEngine(apply_fn, cparams, 1e6, impl="xla")
    fleet = WalkerFleet(eng, np.ones((16, D), np.float32),
                        _det_cfg(patience=1000, noise=0.01))
    fleet.step()                               # warm the (fleet, bucket) jit
    b2d0, b2h0 = eng.bytes_to_device, eng.bytes_to_host
    iters = 20
    for _ in range(iters):
        out = fleet.step()
        assert out.n_selected == 0
    assert eng.bytes_to_device - b2d0 == 0
    assert eng.bytes_to_host - b2h0 == 4 * iters   # the int32 count only


def test_score_after_keeps_plain_score_cache_clean():
    """score_after's jit cache and trace counter are separate from
    score()'s — the fleet must not perturb the bucketed-score contract
    (``trace_counts`` is asserted exactly elsewhere)."""
    cparams, apply_fn = _committee()
    eng = acq.FusedEngine(apply_fn, cparams, 0.01, impl="xla")
    fleet = WalkerFleet(eng, np.ones((4, D), np.float32), _det_cfg(2))
    for _ in range(3):
        fleet.step()
    assert eng.trace_counts == {}
    assert list(eng.step_trace_counts.values()) == [1]
    eng.score([np.ones(D, np.float32)] * 4)
    assert eng.trace_counts == {8: 1}
    assert list(eng.step_trace_counts.values()) == [1]


def test_stop_drain_does_not_advance_rule_state():
    """Satellite 2 corollary: the mid-gather drain scores with
    advance=False, so a partial round must not consume cross-round
    budget-controller state."""
    cparams, apply_fn = _committee()
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.01,
        rules=(bud.BudgetRule(target=0.5, thr_init=0.01, horizon=8),),
        impl="xla")
    gens = [DetGene(np.full(D, 0.5, np.float32)),
            DetGene(np.full(D, 1.0, np.float32), max_steps=1)]
    ex = Exchange(gens, PredictionPool([], None, engine=eng),
                  OracleInputBuffer(),
                  ExchangeConfig(std_threshold=0.01, min_interval=0.0))
    assert ex.step() is None                   # full round: rounds -> 1
    assert int(np.asarray(eng.rule_state[0]["rounds"])) == 1
    tok = ex.step()                            # gen1 stops mid-gather
    assert tok is not None and tok.origin == "generator1"
    assert int(np.asarray(eng.rule_state[0]["rounds"])) == 1


# ---------------------------------------------------------------------------
# checkpoint: bit-identical resume mid-trajectory
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", IMPLS)
def test_fleet_state_roundtrip_bit_identical(impl):
    cparams, apply_fn = _committee()
    eng = acq.FusedEngine(apply_fn, cparams, 0.01, impl=impl)
    fleet = WalkerFleet(
        eng, np.random.RandomState(0).randn(5, D).astype(np.float32),
        _det_cfg(patience=2, noise=0.02, seed=9))
    for _ in range(7):
        fleet.step()
    snap = fleet.state_dict()
    for _ in range(6):
        fleet.step()
    ref = fleet.state_dict()

    resumed = WalkerFleet(eng, np.zeros((5, D), np.float32),
                          _det_cfg(patience=2, noise=0.02, seed=9))
    resumed.load_state_dict(snap)
    for _ in range(6):
        resumed.step()
    got = resumed.state_dict()
    assert set(got) == set(ref)
    for k in ref:
        assert np.array_equal(got[k], ref[k]), k   # BIT-identical


def test_fleet_snapshot_key_mismatch_rejected():
    cparams, apply_fn = _committee()
    eng = acq.FusedEngine(apply_fn, cparams, 0.01, impl="xla")
    fleet = WalkerFleet(eng, np.ones((2, D), np.float32), _det_cfg(2))
    snap = fleet.state_dict()
    snap.pop("key")
    with pytest.raises(ValueError, match="snapshot keys"):
        fleet.load_state_dict(snap)


# ---------------------------------------------------------------------------
# chaos: nan_walker resets through the restart gate
# ---------------------------------------------------------------------------


def test_chaos_nan_walker_resets_not_crashes():
    cparams, apply_fn = _committee()
    eng = acq.FusedEngine(apply_fn, cparams, 1e6, impl="xla")
    plan = FaultPlan(events=(
        FaultEvent("fleet.step", 3, "nan_walker", arg=1.0),))
    chaos = ChaosInjector(plan)
    x0 = np.random.RandomState(1).randn(4, D).astype(np.float32)
    fleet = WalkerFleet(eng, x0, _det_cfg(patience=1000), chaos=chaos)
    fleet.step()
    fleet.step()
    fleet.step()        # event fires here: walker 1 poisoned, then reset
    assert len(chaos.fired) == 1
    assert fleet.stats()["nan_resets"] == 1
    # the poisoned walker restarted from its trusted state this very step
    np.testing.assert_array_equal(fleet.positions()[1], x0[1])
    for _ in range(3):
        fleet.step()
    assert np.isfinite(fleet.positions()).all()
    assert fleet.stats()["nan_resets"] == 1    # reset once, not every step


def test_acceptance_plan_fleet_event_is_opt_in():
    assert len(FaultPlan.acceptance().events) == 6
    plan = FaultPlan.acceptance(fleet=True)
    assert len(plan.events) == 7
    assert plan.events[-1].site == "fleet.step"
    assert plan.events[-1].kind == "nan_walker"


# ---------------------------------------------------------------------------
# Exchange fleet fast path
# ---------------------------------------------------------------------------


def test_exchange_fleet_path_counters_and_stop():
    cparams, apply_fn = _committee()
    eng = acq.FusedEngine(apply_fn, cparams, -1.0, impl="xla")
    buf = OracleInputBuffer()
    fleet = WalkerFleet(eng, np.ones((4, D), np.float32),
                        _det_cfg(patience=1000, max_steps=5))
    ex = Exchange([], PredictionPool([], None, engine=eng), buf,
                  ExchangeConfig(min_interval=0.0), fleet=fleet)
    tokens = [ex.step() for _ in range(5)]
    assert tokens[:4] == [None] * 4
    assert tokens[4] is not None and tokens[4].origin == "fleet"
    c = ex.monitor.report()["counters"]
    assert c["exchange.iterations"] == 5
    assert c["exchange.proposals"] == 20       # 4 walkers x 5 steps
    assert c["exchange.queued_to_oracle"] == len(buf) == 20


# ---------------------------------------------------------------------------
# legacy-path satellites: gather buffer reuse + drain-on-stop
# ---------------------------------------------------------------------------


def _legacy_exchange(gens, threshold=-1.0):
    cparams, apply_fn = _committee()
    eng = acq.FusedEngine(apply_fn, cparams, threshold, impl="xla")
    buf = OracleInputBuffer()
    ex = Exchange(gens, PredictionPool([], None, engine=eng), buf,
                  ExchangeConfig(std_threshold=threshold, patience=1000,
                                 min_interval=0.0))
    return ex, buf


def test_legacy_gather_buffer_reused_and_timed():
    gens = [DetGene(np.full(D, 0.5 * (i + 1), np.float32))
            for i in range(3)]
    ex, _ = _legacy_exchange(gens)
    ex.step()
    gather0, scatter0 = ex._gather, ex.data_to_gene
    ex.step()
    # satellite 1: gather and scatter lists are the same objects across
    # iterations (filled in place), and gather time is accounted
    assert ex._gather is gather0
    assert ex.data_to_gene is scatter0
    assert ex.monitor.report()["counters"]["exchange.gather_ns"] > 0


def test_stop_mid_gather_drains_earlier_proposals():
    """Regression (satellite 2): generator 2 stopping used to drop
    generators 0 and 1's already-gathered proposals un-scored."""
    gens = [DetGene(np.full(D, 0.5, np.float32)),
            DetGene(np.full(D, 1.0, np.float32)),
            DetGene(np.full(D, 1.5, np.float32), max_steps=2)]
    ex, buf = _legacy_exchange(gens)
    assert ex.step() is None
    assert len(buf) == 3                       # threshold -1: all selected
    assert ex.step() is None
    assert len(buf) == 6
    tok = ex.step()                            # gen2 stops on its 3rd call
    assert tok is not None and tok.origin == "generator2"
    # gens 0 and 1 proposed before the stop: both drained to the oracle
    assert len(buf) == 8
    c = ex.monitor.report()["counters"]
    assert c["exchange.drained_on_stop"] == 2


# ---------------------------------------------------------------------------
# PAL runtime wiring
# ---------------------------------------------------------------------------


class _NullModel:
    """Legacy-trainer placeholder (never driven: these tests step the
    exchange synchronously and never start the runtime threads)."""

    def __init__(self, *a):
        pass

    def stop_run(self):
        pass

    def save_progress(self):
        pass


class _FleetOracle:
    def __init__(self, rank, rd):
        pass

    def run_calc(self, inp):
        return inp, (np.sin(np.asarray(inp)) * 0.1).astype(np.float32)

    def stop_run(self):
        pass

    def save_progress(self):
        pass


def _mk_gen(rank, rd):
    rng = np.random.RandomState(rank)
    return DetGene((0.5 + 0.1 * rng.randn(D)).astype(np.float32))


def _fleet_cfg(tmp, **kw):
    base = dict(result_dir=tmp, gene_process=4, orcl_process=1,
                pred_process=1, ml_process=1, retrain_size=4,
                std_threshold=0.01, patience=3, exchange_min_interval=0.0,
                fleet_walkers=4, fleet_noise=0.0, fleet_max_steps=6,
                checkpoint_every=0.0)
    base.update(kw)
    return PALRunConfig(**base)


def _fleet_pal(tmp, cfg_kw=None, **kw):
    cparams, apply_fn = _committee()
    return PAL(_fleet_cfg(tmp, **(cfg_kw or {})),
               make_generator=_mk_gen,
               make_model=lambda r, rd, d, m: _NullModel(),
               make_oracle=_FleetOracle,
               committee=acq.CommitteeSpec(apply_fn, cparams), **kw)


def test_pal_builds_and_checkpoints_fleet(tmp_path):
    tmp = str(tmp_path)
    pal = _fleet_pal(tmp)
    assert pal.fleet is not None and pal.generators == []
    assert pal.exchange.fleet is pal.fleet
    for _ in range(4):                         # drive the fleet synchronously
        assert pal.exchange.step() is None
    pal.checkpoint()
    rep = pal.report()
    assert rep["fleet"]["steps"] == 4
    assert rep["counters"]["exchange.proposals"] == 16
    mid = pal.fleet.state_dict()

    resumed = _fleet_pal(tmp, resume=True)
    got = resumed.fleet.state_dict()
    for k in mid:
        assert np.array_equal(got[k], mid[k]), k


def test_pal_fleet_requires_fused_engine(tmp_path):
    cfg = _fleet_cfg(str(tmp_path), uq_impl="legacy")
    with pytest.raises(ValueError, match="fused"):
        PAL(cfg, make_generator=_mk_gen,
            make_model=lambda r, rd, d, m: _NullModel(),
            make_oracle=_FleetOracle)
