"""Sharded, queue-batched committee serving tests.

* ``ServingQueue``: microbatching semantics — size trigger, deadline
  trigger, per-request scatter, ORDERING under concurrent submitters,
  oversized requests, error propagation, close-time drain, empty requests.
* ``CommitteeServer.predict`` empty-batch short-circuit (no dispatch, no
  counters, no controller round).
* Sharded ``FusedEngine`` on the degenerate host mesh: bit-identical
  ``UQResult``/``SelectionResult``s vs the unsharded path, INCLUDING the
  carried stateful ``BudgetRule`` state, across shape buckets and weight
  refreshes.
* Per-stream budgets: ``BudgetRule.target_serve`` metering
  ``STREAM_SERVE`` rounds against their own target, the config knobs
  (``oracle_budget_exchange`` / ``oracle_budget_serve``), and
  ``PAL.report()``'s per-stream rate breakout.
"""
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import PAL, UserGene, UserModel, UserOracle
from repro.core import acquisition as acq
from repro.core import budget as bud
from repro.core import committee as cmte
from repro.core import selection as sel
from repro.core.buffers import OracleInputBuffer
from repro.launch.mesh import make_host_mesh
from repro.serving import CommitteeServer, QueueConfig, ServingQueue

K, IN_DIM, OUT_DIM = 5, 6, 3


def _committee(seed=0):
    rng = np.random.RandomState(seed)
    members = [{"w": jnp.asarray(rng.randn(IN_DIM, OUT_DIM)
                                 .astype(np.float32) * 0.5)}
               for _ in range(K)]
    return members, cmte.stack_members(members), (lambda p, x: x @ p["w"])


def _rows(n, seed=1, scale=1.0):
    rng = np.random.RandomState(seed)
    return [(rng.randn(IN_DIM) * scale).astype(np.float32)
            for _ in range(n)]


def _server(threshold=0.4, rules=None, seed=0, **kw):
    _, cparams, apply_fn = _committee(seed)
    eng = acq.FusedEngine(apply_fn, cparams, threshold, rules=rules,
                          impl="xla")
    return CommitteeServer(eng, None, **kw), eng


# ---------------------------------------------------------------------------
# CommitteeServer: empty-batch short-circuit (satellite fix)
# ---------------------------------------------------------------------------


def test_committee_server_empty_predict_short_circuits():
    class _Boom:
        def score(self, *a, **k):
            raise AssertionError("engine must not be touched")

    obuf = OracleInputBuffer()
    server = CommitteeServer(_Boom(), obuf)
    mean, uq = server.predict([])
    assert mean.shape == (0, 0)         # 2-D like non-empty results
    assert uq.mean.shape == (0, 0) and uq.mask.shape == (0,)
    assert uq.scalar_std.shape == (0,) and uq.component_std.shape == (0,)
    assert server.requests == 0 and server.routed == 0
    assert len(obuf) == 0


def test_committee_server_empty_mean_keeps_output_width():
    """After any non-empty batch, empty results carry (0, out_dim) so
    aggregating callers can vstack across batches."""
    server, _ = _server()
    server.predict(_rows(3, seed=40))
    mean, uq = server.predict([])
    assert mean.shape == (0, OUT_DIM)
    stacked = np.vstack([server.predict(b)[0]
                         for b in (_rows(2, seed=41), [], _rows(1, seed=42))])
    assert stacked.shape == (3, OUT_DIM)


def test_committee_server_empty_predict_no_controller_round():
    server, eng = _server(
        rules=(bud.BudgetRule(target=0.25, thr_init=0.4),))
    server.predict([])
    assert int(np.asarray(eng.rule_state[0]["rounds"])) == 0


# ---------------------------------------------------------------------------
# ServingQueue: microbatching semantics
# ---------------------------------------------------------------------------


def test_queue_fuses_requests_and_matches_percall_results():
    server, eng = _server()
    rows = _rows(16, seed=2)
    direct = eng.score(rows, advance=False)
    with ServingQueue(server, QueueConfig(max_batch=16,
                                          max_wait_ms=200.0)) as q:
        futs = [q.submit([r]) for r in rows]       # 16 size-1 requests
        outs = [f.result(timeout=10) for f in futs]
    # one fused dispatch carried all 16 requests (size trigger)
    assert q.dispatches == 1 and q.batched_requests == 16
    assert server.requests == 16
    for i, (mean, uq) in enumerate(outs):
        np.testing.assert_array_equal(mean[0], direct.mean[i])
        np.testing.assert_array_equal(uq.scalar_std[0], direct.scalar_std[i])
        np.testing.assert_array_equal(uq.mask[0], direct.mask[i])


def test_queue_deadline_flush():
    server, _ = _server()
    with ServingQueue(server, QueueConfig(max_batch=1024,
                                          max_wait_ms=10.0)) as q:
        t0 = time.perf_counter()
        mean, uq = q.predict(_rows(3, seed=3))
        waited = time.perf_counter() - t0
    assert mean.shape == (3, OUT_DIM) and uq.mask.shape == (3,)
    # dispatched by the deadline, nowhere near filling max_batch
    assert waited < 5.0
    assert q.dispatches == 1


def test_queue_preserves_per_request_ordering_under_concurrency():
    server, eng = _server()
    n_threads, per_thread = 8, 12
    errs = []

    def client(tid):
        rng = np.random.RandomState(100 + tid)
        try:
            with_sizes = [1 + (tid + j) % 3 for j in range(per_thread)]
            for j, sz in enumerate(with_sizes):
                rows = [(rng.randn(IN_DIM)).astype(np.float32)
                        for _ in range(sz)]
                mean, uq = q.predict(rows)
                want = eng.score(rows, advance=False)
                # exactly this caller's rows, in submission order
                np.testing.assert_array_equal(mean, want.mean)
                np.testing.assert_array_equal(uq.scalar_std, want.scalar_std)
                np.testing.assert_array_equal(uq.mask, want.mask)
                assert len(uq.mask) == sz
        except BaseException as e:  # noqa: BLE001
            errs.append((tid, e))

    with ServingQueue(server, QueueConfig(max_batch=16,
                                          max_wait_ms=2.0)) as q:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errs, errs
    # microbatching actually happened (fewer dispatches than requests)
    assert q.dispatches < q.batched_requests
    assert q.batched_requests == n_threads * per_thread


def test_queue_request_boundaries_never_split():
    """A request's rows always land in ONE dispatch, even when it exceeds
    max_batch (it goes out alone)."""
    server, _ = _server()
    with ServingQueue(server, QueueConfig(max_batch=4,
                                          max_wait_ms=50.0)) as q:
        rows = _rows(11, seed=4)                  # 11 > max_batch
        mean, uq = q.predict(rows)
    assert mean.shape == (11, OUT_DIM) and len(uq.mask) == 11
    assert q.dispatches == 1


def test_queue_empty_request_no_dispatch():
    server, eng = _server()
    with ServingQueue(server, QueueConfig(max_batch=8,
                                          max_wait_ms=10.0)) as q:
        fut = q.submit([])
        mean, uq = fut.result(timeout=5)
    assert mean.shape == (0, 0) and uq.mask.shape == (0,)
    assert q.dispatches == 0 and server.requests == 0


def test_queue_empty_request_keeps_fifo_width_with_nonempty_traffic():
    """An empty submitted AFTER non-empty requests must resolve with the
    microbatch's (0, out_dim) width — vstack across a request stream that
    interleaves empties must work."""
    server, _ = _server()
    with ServingQueue(server, QueueConfig(max_batch=8,
                                          max_wait_ms=10.0)) as q:
        futs = [q.submit([r]) for r in _rows(3, seed=20)]
        futs.append(q.submit([]))
        outs = [f.result(timeout=5) for f in futs]
    assert outs[-1][0].shape == (0, OUT_DIM)
    stacked = np.vstack([m for m, _ in outs])
    assert stacked.shape == (3, OUT_DIM)


def test_committee_server_empty_predict_out_dim_seed():
    """A server constructed with out_dim= answers empties at that width
    even before any non-empty traffic (streams that may START empty)."""
    server, _ = _server(out_dim=OUT_DIM)
    mean, uq = server.predict([])
    assert mean.shape == (0, OUT_DIM)
    stacked = np.vstack([mean, server.predict(_rows(2, seed=43))[0]])
    assert stacked.shape == (2, OUT_DIM)


def test_queue_backpressure_bounds_backlog():
    """With max_pending set, submit blocks instead of growing the backlog
    without bound; everything still completes and the backlog invariant
    holds at every dispatch."""
    server, _ = _server()
    seen_rows = []
    real_predict = server.predict

    def spying_predict(rows):
        seen_rows.append(len(rows))
        time.sleep(0.002)                     # make overload reachable
        return real_predict(rows)

    server.predict = spying_predict
    q = ServingQueue(server, QueueConfig(max_batch=4, max_wait_ms=1.0,
                                         max_pending=8))
    try:
        futs = []
        for r in _rows(64, seed=44):
            futs.append(q.submit([r]))        # blocks when 8 rows pending
            with q._lock:
                assert q._pending_rows <= 8
        for f in futs:
            f.result(timeout=30)
    finally:
        q.close()
    assert sum(seen_rows) == 64 and max(seen_rows) <= 4


def test_queue_propagates_dispatch_errors_to_futures():
    class _Failing:
        def predict(self, rows):
            raise RuntimeError("committee on fire")

    q = ServingQueue(_Failing(), QueueConfig(max_batch=4, max_wait_ms=5.0))
    try:
        futs = [q.submit([r]) for r in _rows(4, seed=5)]
        for f in futs:
            with pytest.raises(RuntimeError, match="committee on fire"):
                f.result(timeout=10)
    finally:
        q.close()


def test_queue_close_drains_pending_and_rejects_new():
    server, _ = _server()
    q = ServingQueue(server, QueueConfig(max_batch=1024,
                                         max_wait_ms=60_000.0))
    futs = [q.submit([r]) for r in _rows(5, seed=6)]
    q.close()                                     # deadline far away: drain
    for f in futs:
        mean, uq = f.result(timeout=1)
        assert uq.mask.shape == (1,)
    with pytest.raises(RuntimeError):
        q.submit(_rows(1, seed=7))
    with pytest.raises(RuntimeError):             # empties too
        q.submit([])


# ---------------------------------------------------------------------------
# sharded engine: host-mesh parity (incl. stateful rule state)
# ---------------------------------------------------------------------------


def _parity_rules():
    return (bud.RollingReweightRule(n_buckets=8),
            bud.BudgetRule(target=0.25, thr_init=0.4, horizon=8))


def test_sharded_host_mesh_identical_selection_results():
    """On make_host_mesh() the sharded FusedEngine must produce
    SelectionResults identical to the unsharded path, across shape
    buckets, including stateful BudgetRule/RollingReweightRule state."""
    _, cparams, apply_fn = _committee(seed=8)
    plain = acq.FusedEngine(apply_fn, cparams, 0.4, rules=_parity_rules(),
                            impl="xla")
    shard = acq.FusedEngine(apply_fn, cparams, 0.4, rules=_parity_rules(),
                            impl="xla", mesh=make_host_mesh())
    for r, n in enumerate((13, 8, 33, 13, 5)):    # several buckets
        rows = _rows(n, seed=50 + r, scale=1.5)
        a = plain.score(rows, stream=r % 2)
        b = shard.score(rows, stream=r % 2)
        np.testing.assert_array_equal(a.mean, b.mean)
        np.testing.assert_array_equal(a.scalar_std, b.scalar_std)
        np.testing.assert_array_equal(a.component_std, b.component_std)
        np.testing.assert_array_equal(a.mask, b.mask)
        ra = sel.selection_from_uq(rows, a)
        rb = sel.selection_from_uq(rows, b)
        np.testing.assert_array_equal(ra.uncertain_mask, rb.uncertain_mask)
        for x, y in zip(ra.inputs_to_oracle, rb.inputs_to_oracle):
            np.testing.assert_array_equal(x, y)
    # carried controller/re-weighting state advanced identically
    for x, y in zip(jax.tree.leaves(plain.state_dict()),
                    jax.tree.leaves(shard.state_dict())):
        np.testing.assert_array_equal(x, y)
    # both compiled once per bucket
    assert plain.trace_counts == shard.trace_counts
    assert all(c == 1 for c in shard.trace_counts.values())


def test_sharded_engine_places_params_and_batch_on_mesh():
    _, cparams, apply_fn = _committee(seed=9)
    mesh = make_host_mesh()
    eng = acq.FusedEngine(apply_fn, cparams, 0.4, impl="xla", mesh=mesh)
    from jax.sharding import NamedSharding

    for leaf in jax.tree.leaves(eng.cparams):
        assert isinstance(leaf.sharding, NamedSharding)
        assert leaf.sharding.mesh.shape == dict(mesh.shape)
        # leading committee axis carries the 'model' mapping (K=5 divides
        # the 1-ary host axis; on a bigger mesh the divisibility fallback
        # may replicate instead)
        assert leaf.sharding.spec[0] in ("model", None)
    uq = eng.score(_rows(4, seed=10))
    assert uq.mask.shape == (4,)


def test_sharded_engine_refresh_keeps_layout():
    from repro.core.weight_sync import WeightStore

    members, cparams, apply_fn = _committee(seed=11)
    eng = acq.FusedEngine(apply_fn, cparams, 0.4, impl="xla",
                          mesh=make_host_mesh())
    store = WeightStore(K)
    w_new = np.random.RandomState(12).randn(K, IN_DIM * OUT_DIM) \
        .astype(np.float32)
    for i in range(K):
        store.publish_packed(i, w_new[i])
    assert eng.refresh_from(store) == 1
    from jax.sharding import NamedSharding

    leaf = jax.tree.leaves(eng.cparams)[0]
    assert isinstance(leaf.sharding, NamedSharding)
    np.testing.assert_allclose(
        np.asarray(leaf).reshape(K, -1), w_new, rtol=1e-6)


def test_make_engine_resolves_uq_mesh_knob():
    _, cparams, apply_fn = _committee(seed=13)
    cfg = PALRunConfig(std_threshold=0.4, uq_impl="xla", uq_mesh="host")
    eng = acq.make_engine(cfg,
                          committee=acq.CommitteeSpec(apply_fn, cparams))
    assert isinstance(eng, acq.FusedEngine)
    assert eng.mesh is not None and dict(eng.mesh.shape) == \
        {"data": 1, "model": 1}
    with pytest.raises(ValueError, match="uq_mesh"):
        acq.resolve_mesh(PALRunConfig(uq_mesh="nope"))


# ---------------------------------------------------------------------------
# per-stream budgets
# ---------------------------------------------------------------------------


def test_budget_rule_per_stream_targets():
    """With target_serve != target, serve-only traffic settles at the
    serving budget while exchange-only traffic settles at the exchange
    budget — same rule, same threshold state, stream-tagged rounds."""
    _, cparams, apply_fn = _committee(seed=14)

    def run(stream, target, target_serve):
        eng = acq.FusedEngine(
            apply_fn, cparams, 0.5,
            rules=(bud.BudgetRule(target=target, thr_init=0.5, horizon=8,
                                  target_serve=target_serve),),
            impl="xla")
        rates = []
        for r in range(80):
            rows = _rows(32, seed=200 + r, scale=1.0)
            rates.append(float(eng.score(rows, stream=stream).mask.mean()))
        return float(np.mean(rates[40:]))

    ex_rate = run(acq.STREAM_EXCHANGE, 0.2, 0.45)
    sv_rate = run(acq.STREAM_SERVE, 0.2, 0.45)
    assert abs(ex_rate - 0.2) < 0.06, ex_rate
    assert abs(sv_rate - 0.45) < 0.08, sv_rate


def test_budget_rule_shared_target_ignores_stream():
    """target_serve unset -> streams are indistinguishable (the PR-3
    single-target path), so mixed traffic still converges to the target."""
    _, cparams, apply_fn = _committee(seed=15)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.5,
        rules=(bud.BudgetRule(target=0.3, thr_init=0.5, horizon=8),),
        impl="xla")
    rates = []
    for r in range(80):
        rows = _rows(32, seed=300 + r)
        rates.append(float(eng.score(rows, stream=r % 2).mask.mean()))
    assert abs(float(np.mean(rates[40:])) - 0.3) < 0.06
    assert len(eng.trace_counts) == 1       # stream tag never retraces
    assert all(c == 1 for c in eng.trace_counts.values())


def test_rules_from_config_per_stream_budgets():
    r = bud.rules_from_config(PALRunConfig(oracle_budget=0.2))
    assert r[0].target == 0.2 and r[0].target_serve == 0.2
    r = bud.rules_from_config(PALRunConfig(oracle_budget=0.2,
                                           oracle_budget_serve=0.05))
    assert r[0].target == 0.2 and r[0].target_serve == 0.05
    r = bud.rules_from_config(PALRunConfig(oracle_budget_exchange=0.3,
                                           oracle_budget_serve=0.1))
    assert r[0].target == 0.3 and r[0].target_serve == 0.1
    # one stream configured: the other inherits (joint control)
    r = bud.rules_from_config(PALRunConfig(oracle_budget_serve=0.1))
    assert r[0].target == 0.1 and r[0].target_serve == 0.1


# ---------------------------------------------------------------------------
# runtime wiring: PAL.serve_queue + per-stream report breakout
# ---------------------------------------------------------------------------


class _Gene(UserGene):
    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)

    def generate_new_data(self, data_to_gene):
        return False, self.rng.randn(IN_DIM).astype(np.float32)


class _Model(UserModel):
    def __init__(self, rank, rd, dev, mode):
        super().__init__(rank, rd, dev, mode)
        self.w = np.random.RandomState(rank).randn(IN_DIM, OUT_DIM) * 0.5

    def predict(self, xs):
        return [np.asarray(x) @ self.w for x in xs]

    def update(self, warr):
        self.w = warr.reshape(IN_DIM, OUT_DIM)

    def get_weight(self):
        return self.w.reshape(-1).astype(np.float32)

    def get_weight_size(self):
        return IN_DIM * OUT_DIM

    def add_trainingset(self, dps):
        pass

    def retrain(self, req):
        return False


class _Oracle(UserOracle):
    def run_calc(self, inp):
        return inp, np.zeros(OUT_DIM, np.float32)


def _pal(**cfg_kw):
    tmp = tempfile.mkdtemp()
    _, cparams, apply_fn = _committee(seed=16)
    cfg = PALRunConfig(result_dir=tmp, gene_process=2, orcl_process=0,
                       pred_process=1, ml_process=1, std_threshold=0.4,
                       **cfg_kw)
    return PAL(cfg, make_generator=_Gene, make_model=_Model,
               make_oracle=_Oracle,
               committee=acq.CommitteeSpec(apply_fn, cparams))


def test_pal_builds_serve_queue_and_reports_per_stream_rates():
    pal = _pal(oracle_budget=0.3, serve_uq=True, serve_max_batch=8,
               serve_max_wait_ms=5.0)
    try:
        assert pal.serve_queue is not None
        assert pal.serve_queue.server is pal.server
        pal.exchange.step()                       # exchange traffic
        rng = np.random.RandomState(17)
        futs = [pal.serve_queue.submit(
                    [(rng.randn(IN_DIM) * 2).astype(np.float32)])
                for _ in range(8)]
        for f in futs:
            f.result(timeout=10)
        rep = pal.report()
        c = rep["counters"]
        assert c.get("serve.requests", 0) == 8
        assert rep["serve_queue_dispatches"] == pal.serve_queue.dispatches
        assert rep["serve_queue_batched_requests"] == 8
        # per-stream breakout, consistent with the joint rate
        assert rep["oracle_rate_serve"] == pytest.approx(
            c.get("serve.routed_to_oracle", 0) / 8)
        ex_p = c.get("exchange.proposals", 0)
        assert ex_p > 0
        assert rep["oracle_rate_exchange"] == pytest.approx(
            c.get("exchange.queued_to_oracle", 0) / ex_p)
        joint = (c.get("exchange.queued_to_oracle", 0)
                 + c.get("serve.routed_to_oracle", 0)) / (ex_p + 8)
        assert rep["oracle_rate"] == pytest.approx(joint)
    finally:
        pal.shutdown()


def test_pal_without_queue_has_no_serve_queue():
    pal = _pal(serve_uq=True)
    try:
        assert pal.server is not None and pal.serve_queue is None
        assert pal.report()["oracle_rate_serve"] is None
    finally:
        pal.shutdown()


# ---------------------------------------------------------------------------
# degradation-aware serving: load shedding + circuit breaker (ISSUE 6)
# ---------------------------------------------------------------------------


class _StubServer:
    """Deterministic CommitteeServer stand-in: succeeds or fails on demand,
    returning a shaped UQResult per microbatch."""

    def __init__(self):
        self.ok = True
        self.calls = 0

    def predict(self, rows):
        self.calls += 1
        if not self.ok:
            raise RuntimeError("injected dispatch failure")
        n = len(rows)
        mean = np.zeros((n, OUT_DIM), np.float32)
        z = np.zeros(n, np.float32)
        return mean, acq.UQResult(mean, z, z.copy(), np.zeros(n, bool),
                                  np.full(n, K, np.int32))


def test_queue_load_shedding_raises_typed_overload():
    from repro.serving.queue import QueueOverloaded, ServingRejected

    srv = _StubServer()
    # huge batch + huge deadline: nothing dispatches while we fill the
    # backlog, so the shed bound is hit deterministically
    q = ServingQueue(srv, QueueConfig(max_batch=1000, max_wait_ms=10_000.0,
                                      shed_pending=4))
    futs = [q.submit(_rows(1, seed=i)) for i in range(4)]
    with pytest.raises(QueueOverloaded):
        q.submit(_rows(1, seed=99))
    assert issubclass(QueueOverloaded, ServingRejected)
    assert q.shed_requests == 1
    assert q.health()["shed_requests"] == 1
    q.close(timeout=10)                       # drain flushes the admitted 4
    for f in futs:
        mean, uq = f.result(timeout=10)
        assert mean.shape == (1, OUT_DIM)
        assert int(uq.finite_members[0]) == K


def test_queue_circuit_breaker_opens_probes_and_closes():
    from repro.serving.queue import CircuitOpen

    srv = _StubServer()
    srv.ok = False
    q = ServingQueue(srv, QueueConfig(max_batch=1, breaker_failures=2,
                                      breaker_reset_s=0.15))
    try:
        # two consecutive dispatch failures (delivered on the futures, not
        # raised at submit) open the circuit
        for i in range(2):
            with pytest.raises(RuntimeError, match="injected"):
                q.submit(_rows(1, seed=i)).result(timeout=10)
        assert q.health()["breaker_state"] == "open"
        assert q.breaker_opens == 1
        with pytest.raises(CircuitOpen):
            q.submit(_rows(1, seed=2))
        # cooldown elapses -> half-open probe admitted; it fails -> reopen
        time.sleep(0.2)
        with pytest.raises(RuntimeError, match="injected"):
            q.submit(_rows(1, seed=3)).result(timeout=10)
        assert q.health()["breaker_state"] == "open"
        assert q.breaker_opens == 2
        with pytest.raises(CircuitOpen):
            q.submit(_rows(1, seed=4))
        # service recovers: the next probe closes the circuit for good
        srv.ok = True
        time.sleep(0.2)
        mean, _ = q.submit(_rows(1, seed=5)).result(timeout=10)
        assert mean.shape == (1, OUT_DIM)
        h = q.health()
        assert h["breaker_state"] == "closed"
        assert h["consecutive_failures"] == 0
        assert h["dispatch_failures"] == 3
    finally:
        q.close(timeout=10)


def test_pal_wires_breaker_knobs_and_reports_serve_health():
    pal = _pal(serve_uq=True, serve_max_batch=8, serve_breaker_failures=3,
               serve_breaker_reset_s=1.0, serve_shed_pending=64)
    try:
        qcfg = pal.serve_queue.cfg
        assert qcfg.breaker_failures == 3
        assert qcfg.breaker_reset_s == 1.0
        assert qcfg.shed_pending == 64
        rep = pal.report()
        assert rep["serve_queue_health"]["breaker_state"] == "closed"
        assert rep["last_fault"] is None
        assert rep["thread_restarts"] == 0
    finally:
        pal.shutdown()
