"""launch/platform: the process-level runtime-config module.

The env-editing paths (XLA_FLAGS surgery) are tested in-process — they
are pure string/env manipulation.  The paths that need an UNinitialized
jax backend (flag rewrite actually changing the device count, module
import purity) run in subprocesses, which doubles as the tier-1 entry
that exercises a REAL 8-device emulated mesh end to end.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro.launch import platform as plat

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FLAG = "--xla_force_host_platform_device_count"


def _run(code: str, **env):
    """Run a python snippet in a fresh interpreter with src/ importable."""
    full_env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    full_env["PYTHONPATH"] = os.path.join(REPO, "src")
    full_env.update(env)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=full_env,
                          cwd=REPO, timeout=300)


def test_requested_host_devices_parses_flag(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", f"--xla_foo=1 {FLAG}=12")
    assert plat.requested_host_devices() == 12
    monkeypatch.setenv("XLA_FLAGS", "--xla_foo=1")
    assert plat.requested_host_devices() is None
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    assert plat.requested_host_devices() is None


def test_ensure_host_devices_same_count_is_noop(monkeypatch):
    # re-applying the already-requested count never needs the backend —
    # safe from module top-levels even after jax is live
    monkeypatch.setenv("XLA_FLAGS", f"{FLAG}=6 --xla_bar=2")
    assert plat.ensure_host_devices(6) == 6
    assert os.environ["XLA_FLAGS"] == f"{FLAG}=6 --xla_bar=2"


def test_ensure_host_devices_rejects_bad_count():
    with pytest.raises(ValueError):
        plat.ensure_host_devices(0)
    with pytest.raises(ValueError):
        plat.ensure_host_devices(-3)


def test_ensure_host_devices_raises_once_backend_locked(monkeypatch):
    jax.devices()                      # force backend init
    assert plat.backend_initialized()
    monkeypatch.setenv("XLA_FLAGS", f"{FLAG}=6")
    with pytest.raises(RuntimeError, match="already initialized"):
        plat.ensure_host_devices(3)


def test_set_platform_validates(monkeypatch):
    with pytest.raises(ValueError):
        plat.set_platform("quantum")
    jax.devices()
    with pytest.raises(RuntimeError, match="already initialized"):
        plat.set_platform("cpu")


def test_apply_gpu_autotune_idempotent(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS", "--xla_bar=2")
    plat.apply_gpu_autotune()
    after = os.environ["XLA_FLAGS"]
    assert "--xla_bar=2" in after
    for f in plat.GPU_AUTOTUNE_FLAGS.split():
        assert after.count(f.split("=")[0]) == 1
    plat.apply_gpu_autotune()          # second call: no duplicates
    assert os.environ["XLA_FLAGS"] == after


def test_configure_from_env_defaults():
    cfg = plat.configure_from_env({})
    assert cfg == plat.PlatformConfig()


def test_configure_applies_host_devices(monkeypatch):
    # count already requested -> configure is a no-op even when locked
    monkeypatch.setenv("XLA_FLAGS", f"{FLAG}=6")
    cfg = plat.configure_from_env({"REPRO_HOST_DEVICES": "6"})
    assert cfg.host_devices == 6
    assert plat.requested_host_devices() == 6


def test_describe_reports_runtime_facts():
    d = plat.describe()
    for key in ("platform", "device_kind", "device_count",
                "local_device_count", "process_index", "process_count",
                "emulated_host_devices"):
        assert key in d
    assert d["device_count"] == jax.device_count()
    assert d["process_count"] >= 1


def test_module_import_is_jax_free():
    # importing platform.py must NEVER initialize (or even import) jax —
    # that is the whole point of the module
    r = _run("""
        import sys
        import repro.launch.platform as plat
        assert "jax" not in sys.modules, "platform.py imported jax"
        print("PURE")
    """)
    assert r.returncode == 0, r.stderr
    assert "PURE" in r.stdout


def test_eight_device_mesh_end_to_end_subprocess():
    """Tier-1 entry for the emulated-device knob: a fresh process requests
    8 host devices (rewriting an existing flag), gets a REAL 8-device
    mesh, and fused scoring on it is bit-identical to unsharded."""
    r = _run(f"""
        import os
        os.environ["XLA_FLAGS"] = "{FLAG}=4 --xla_cpu_enable_fast_math=false"
        from repro.launch.platform import (ensure_host_devices,
                                           requested_host_devices)
        assert ensure_host_devices(8) == 8        # rewrite 4 -> 8
        assert requested_host_devices() == 8
        assert "--xla_cpu_enable_fast_math=false" in os.environ["XLA_FLAGS"]

        import jax
        import jax.numpy as jnp
        import numpy as np
        assert jax.device_count() == 8, jax.devices()
        ensure_host_devices(8)                    # locked same-count: ok
        try:
            ensure_host_devices(2)
            raise AssertionError("locked different count must raise")
        except RuntimeError:
            pass

        from repro.core.acquisition import FusedEngine
        from repro.core.committee import stack_members
        from repro.launch.mesh import make_scaleout_mesh

        D, H = 4, 8
        def init(seed):
            r = np.random.RandomState(seed)
            return {{"w1": jnp.asarray(r.randn(D, H).astype(np.float32)),
                     "w2": jnp.asarray(r.randn(H, D).astype(np.float32))}}
        cp = stack_members([init(i) for i in range(8)])
        apply_fn = lambda p, x: jnp.tanh(x @ p["w1"]) @ p["w2"]
        e0 = FusedEngine(apply_fn, cp, 0.5, impl="xla", mesh=None)
        e8 = FusedEngine(apply_fn, cp, 0.5, impl="xla",
                         mesh=make_scaleout_mesh(8, 1))
        x = list(np.random.RandomState(0).randn(16, D).astype(np.float32))
        r0, r8 = e0.score(x), e8.score(x)
        for f in ("mean", "scalar_std", "component_std", "mask"):
            assert np.array_equal(np.asarray(getattr(r0, f)),
                                  np.asarray(getattr(r8, f))), f
        print("MESH8_OK")
    """)
    assert r.returncode == 0, r.stderr
    assert "MESH8_OK" in r.stdout
