"""Deterministic stand-in for ``hypothesis`` when the library is absent.

The tier-1 suite must collect and run without optional dependencies, so
``test_core.py`` / ``test_optim_data_ckpt.py`` fall back to this module:
``given`` replays each property test over a fixed number of seeded random
examples drawn from minimal strategy objects.  It implements exactly the
strategy surface those tests use (integers / floats / lists / tuples /
fixed_dictionaries) — no shrinking, no database, just coverage.
"""
from __future__ import annotations

import random

_MAX_EXAMPLES = 25          # cap even when tests ask for more (speed)


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def integers(min_value=-(10 ** 9), max_value=10 ** 9):
        return _Strategy(lambda rng: rng.randint(int(min_value), int(max_value)))

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(float(min_value),
                                                 float(max_value)))

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)

    @staticmethod
    def tuples(*strategies):
        return _Strategy(
            lambda rng: tuple(s.example(rng) for s in strategies))

    @staticmethod
    def fixed_dictionaries(mapping):
        return _Strategy(
            lambda rng: {k: v.example(rng) for k, v in mapping.items()})


st = _Strategies()


def settings(max_examples=None, deadline=None, **_kw):
    """Records max_examples on the function; order-independent with given."""
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return deco


def given(*strategies, **kw_strategies):
    """Run the test over seeded examples.  The wrapper takes no arguments so
    pytest does not mistake the injected parameters for fixtures."""
    def deco(fn):
        def wrapper():
            limit = (getattr(wrapper, "_fallback_max_examples", None)
                     or getattr(fn, "_fallback_max_examples", None)
                     or _MAX_EXAMPLES)
            rng = random.Random(0)
            for _ in range(min(int(limit), _MAX_EXAMPLES)):
                args = [s.example(rng) for s in strategies]
                kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                fn(*args, **kwargs)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
