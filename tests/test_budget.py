"""Cross-round budgeted acquisition tests: BudgetRule convergence to the
target oracle rate under synthetic std drift, fused-vs-legacy parity for
the stateful rules (budget controller + rolling re-weighting), carried
state surviving PAL.checkpoint/restore, true-n rate accounting under bucket
padding, read-only scoring (advance=False), the config-driven pipeline
factory, and the CommitteeServer serving path (batch-level UQResult +
oracle routing through the same controller)."""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import PAL, UserGene, UserModel, UserOracle
from repro.core import acquisition as acq
from repro.core import budget as bud
from repro.core import committee as cmte
from repro.core.buffers import OracleInputBuffer
from repro.serving.engine import CommitteeServer


K, IN_DIM, OUT_DIM = 5, 6, 3


def _committee(seed=0):
    rng = np.random.RandomState(seed)
    members = [{"w": jnp.asarray(rng.randn(IN_DIM, OUT_DIM)
                                 .astype(np.float32) * 0.5)}
               for _ in range(K)]
    return members, cmte.stack_members(members), (lambda p, x: x @ p["w"])


def _predict_all(members):
    def predict_all(xs):
        x = np.stack([np.asarray(v, np.float32) for v in xs])
        return np.stack([x @ np.asarray(m["w"]) for m in members])
    return predict_all


def _drift_batches(n_rounds, n, *, seed=1, scale0=0.5, scale1=2.0):
    """Input batches whose committee disagreement drifts: the linear
    committee's std scales with |x|, so ramping the input scale ramps the
    std distribution a static threshold would mis-rate."""
    rng = np.random.RandomState(seed)
    out = []
    for r in range(n_rounds):
        s = scale0 + (scale1 - scale0) * r / max(n_rounds - 1, 1)
        out.append([(rng.randn(IN_DIM) * s).astype(np.float32)
                    for _ in range(n)])
    return out


def _engines(members, cparams, apply_fn, threshold, rules):
    return {
        "fused_xla": acq.FusedEngine(apply_fn, cparams, threshold,
                                     rules=rules, impl="xla"),
        "fused_pallas": acq.FusedEngine(apply_fn, cparams, threshold,
                                        rules=rules, impl="pallas_interpret"),
        "legacy": acq.LegacyEngine(_predict_all(members), threshold,
                                   rules=rules),
    }


# ---------------------------------------------------------------------------
# controller convergence
# ---------------------------------------------------------------------------


def test_budget_rule_converges_to_target_rate_under_drift():
    """With the input-std distribution drifting 4x over the run, the
    realized selected-per-round rate must settle at the configured target
    (a static threshold would drift from near-0 to near-1)."""
    members, cparams, apply_fn = _committee()
    target = 0.25
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.5,
        rules=(bud.BudgetRule(target=target, thr_init=0.5, horizon=8),),
        impl="xla")
    batches = _drift_batches(80, 32)
    rates = [float(eng.score(b).mask.mean()) for b in batches]
    settled = np.mean(rates[40:])
    assert abs(settled - target) < 0.05, (settled, rates[40:])
    # the carried EMA agrees with the realized rate
    ema = float(np.asarray(eng.rule_state[0]["ema_rate"]))
    assert abs(ema - target) < 0.1
    assert int(np.asarray(eng.rule_state[0]["rounds"])) == len(batches)


def test_static_threshold_drifts_where_budget_holds():
    """Sanity for the premise: same drifting stream, static ThresholdRule
    — realized rate swings far outside the band the controller holds."""
    members, cparams, apply_fn = _committee()
    batches = _drift_batches(80, 32)
    probe = acq.LegacyEngine(_predict_all(members), 0.0).score(batches[0])
    t = float(np.quantile(probe.scalar_std, 0.9))   # rate ~0.1 at scale0
    eng = acq.FusedEngine(apply_fn, cparams, t, impl="xla")
    rates = [float(eng.score(b).mask.mean()) for b in batches]
    assert np.mean(rates[60:]) - np.mean(rates[:5]) > 0.5


def test_budget_threshold_bounded():
    """A long all-certain stretch cannot push the threshold below thr_min
    (controller authority is clamped)."""
    members, cparams, apply_fn = _committee()
    rule = bud.BudgetRule(target=0.5, thr_init=0.5, horizon=4)
    eng = acq.FusedEngine(apply_fn, cparams, 0.5, rules=(rule,), impl="xla")
    rng = np.random.RandomState(3)
    for _ in range(200):    # tiny inputs -> std ~ 0 -> nothing selectable
        eng.score([(rng.randn(IN_DIM) * 1e-4).astype(np.float32)
                   for _ in range(8)])
    thr = float(np.asarray(eng.rule_state[0]["threshold"]))
    lo, hi = rule._bounds()
    assert lo <= thr <= hi
    assert thr == pytest.approx(lo)


# ---------------------------------------------------------------------------
# fused-vs-legacy parity for the stateful rules
# ---------------------------------------------------------------------------


def test_budget_rule_parity_across_backends():
    members, cparams, apply_fn = _committee(seed=2)
    rules = (bud.BudgetRule(target=0.3, thr_init=0.4, horizon=8),)
    engines = _engines(members, cparams, apply_fn, 0.4, rules)
    for r, batch in enumerate(_drift_batches(25, 12, seed=5)):
        masks = {n: e.score(batch).mask for n, e in engines.items()}
        ref = masks["legacy"]
        for name, m in masks.items():
            np.testing.assert_array_equal(m, ref, err_msg=f"{name} @ {r}")
    thr = {n: float(np.asarray(e.rule_state[0]["threshold"]))
           for n, e in engines.items()}
    for name, t in thr.items():
        assert t == pytest.approx(thr["legacy"], rel=1e-4), (name, thr)
    assert any(float(np.asarray(e.rule_state[0]["rounds"])) == 25
               for e in engines.values())


def test_reweight_rule_parity_across_backends():
    members, cparams, apply_fn = _committee(seed=4)
    def rules():
        return (bud.RollingReweightRule(n_buckets=16, decay=0.8, boost=1.0),
                acq.ThresholdRule(0.4))
    engines = _engines(members, cparams, apply_fn, 0.4, rules())
    for r, batch in enumerate(_drift_batches(15, 10, seed=6)):
        masks = {n: e.score(batch).mask for n, e in engines.items()}
        for name, m in masks.items():
            np.testing.assert_array_equal(m, masks["legacy"],
                                          err_msg=f"{name} @ {r}")
    scores = {n: np.asarray(e.rule_state[0]["scores"])
              for n, e in engines.items()}
    for name, s in scores.items():
        np.testing.assert_allclose(s, scores["legacy"], rtol=1e-4,
                                   atol=1e-6, err_msg=name)
    assert scores["legacy"].max() > 0


def test_budget_pipeline_single_trace_per_bucket():
    """Stateful rules ride the same shape-bucketed jit cache: varying n
    compiles once per bucket, state threads through without retraces."""
    members, cparams, apply_fn = _committee(seed=7)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.4,
        rules=(bud.RollingReweightRule(n_buckets=8),
               bud.BudgetRule(target=0.3, thr_init=0.4)),
        impl="xla")
    rng = np.random.RandomState(8)
    for n in (5, 8, 3, 7, 6):
        eng.score([rng.randn(IN_DIM).astype(np.float32) for _ in range(n)])
    assert eng.trace_counts == {8: 1}
    assert int(np.asarray(eng.rule_state[1]["rounds"])) == 5


# ---------------------------------------------------------------------------
# semantics
# ---------------------------------------------------------------------------


def test_budget_rate_uses_true_n_not_bucket_padding():
    """An all-uncertain round of n=8 in a 32-wide bucket is rate 1.0, not
    8/32: over-budget, so the threshold must RISE."""
    members, cparams, apply_fn = _committee(seed=9)
    eng = acq.FusedEngine(
        apply_fn, cparams, 1e-6,
        rules=(bud.BudgetRule(target=0.5, thr_init=1e-3, horizon=4),),
        impl="xla", min_bucket=32)
    rng = np.random.RandomState(10)
    uq = eng.score([(rng.randn(IN_DIM) * 5).astype(np.float32)
                    for _ in range(8)])
    assert uq.mask.all()                       # everything over thr_init
    thr = float(np.asarray(eng.rule_state[0]["threshold"]))
    assert thr > 1e-3                          # rate 1.0 > target: raise
    ema = float(np.asarray(eng.rule_state[0]["ema_rate"]))
    # EMA initialized at target, one step toward rate 1.0 with alpha=1/4
    assert ema == pytest.approx(0.5 + (1.0 - 0.5) / 4)


def test_reweight_boosts_recently_uncertain_region():
    """Use Case 2 semantics: after a round of high std in region A, a
    borderline sample in A outranks an identical-raw-std sample in a cold
    region for downstream rules."""
    rule = bud.RollingReweightRule(n_buckets=32, decay=0.9, boost=1.0,
                                   bucket_width=0.5, seed=0)
    state = rule.init_state()
    a, b = np.float32(0.3), np.float32(7.7)    # distinct buckets (1-D x)
    ids = np.asarray(rule._bucket_ids(np.array([[a], [b]], np.float32)))
    assert ids[0] != ids[1]

    def stats(xs, stds):
        n = len(xs)
        return acq.UQStats(
            x=np.asarray(xs, np.float32).reshape(n, 1), mean=None,
            scalar_std=np.asarray(stds, np.float32),
            component_std=None, valid=np.ones(n, bool), n_valid=n)

    # round 1: region A very uncertain, region B quiet
    _, _, state = rule.apply_stateful(stats([a, b], [1.0, 0.05]),
                                      np.ones(2, bool), state)
    # round 2: equal raw std in both regions — A must come out boosted
    st2, _, state = rule.apply_stateful(stats([a, b], [0.4, 0.4]),
                                        np.ones(2, bool), state)
    boosted = np.asarray(st2.scalar_std)
    assert boosted[0] > boosted[1]
    assert boosted[0] == pytest.approx(0.8, rel=1e-5)   # full boost: 2x


def test_advance_false_is_read_only():
    """Manager re-scoring / read-only serving must not consume controller
    rounds: advance=False evaluates against current state untouched."""
    members, cparams, apply_fn = _committee(seed=11)
    rules = (bud.BudgetRule(target=0.3, thr_init=0.4, horizon=8),)
    for eng in _engines(members, cparams, apply_fn, 0.4, rules).values():
        batch = _drift_batches(1, 10, seed=12)[0]
        eng.score(batch)
        before = jax.tree.map(np.asarray, eng.rule_state)
        eng.score(batch, advance=False)
        after = jax.tree.map(np.asarray, eng.rule_state)
        for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            np.testing.assert_array_equal(x, y)


def test_concurrent_advancing_scorers_never_lose_rounds():
    """Exchange + serving (advance=True) share one engine: the read-state
    -> dispatch -> store-state cycle is atomic, so N concurrent advancing
    calls advance the controller by exactly N rounds (a lost update would
    under-integrate the PI controller under serving load)."""
    import threading

    members, cparams, apply_fn = _committee(seed=30)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.4,
        rules=(bud.BudgetRule(target=0.3, thr_init=0.4, horizon=8),),
        impl="xla")
    per_thread, n_threads = 25, 4
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker(seed):
        try:
            barrier.wait()
            rng = np.random.RandomState(seed)
            for _ in range(per_thread):
                eng.score([rng.randn(IN_DIM).astype(np.float32)
                           for _ in range(8)])
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,))
               for s in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert int(np.asarray(eng.rule_state[0]["rounds"])) \
        == per_thread * n_threads


def test_uqresult_reports_raw_std_not_boosted():
    """Re-weighting biases selection only: the UQResult statistics the
    generators/Manager consume stay the raw committee std."""
    members, cparams, apply_fn = _committee(seed=13)
    batch = _drift_batches(1, 9, seed=14)[0]
    raw = acq.FusedEngine(apply_fn, cparams, 0.4, impl="xla").score(batch)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.4,
        rules=(bud.RollingReweightRule(n_buckets=8, boost=5.0),
               acq.ThresholdRule(0.4)),
        impl="xla")
    uq = eng.score(batch)
    np.testing.assert_allclose(uq.scalar_std, raw.scalar_std, rtol=1e-6)
    np.testing.assert_allclose(uq.component_std, raw.component_std,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# state checkpoint / restore
# ---------------------------------------------------------------------------


def test_engine_state_dict_roundtrip():
    members, cparams, apply_fn = _committee(seed=15)
    rules = (bud.RollingReweightRule(n_buckets=8),
             bud.BudgetRule(target=0.2, thr_init=0.4))
    eng = acq.FusedEngine(apply_fn, cparams, 0.4, rules=rules, impl="xla")
    for batch in _drift_batches(5, 8, seed=16):
        eng.score(batch)
    snap = eng.state_dict()
    assert all(isinstance(x, np.ndarray) for x in jax.tree.leaves(snap))
    eng2 = acq.FusedEngine(apply_fn, cparams, 0.4, rules=rules, impl="xla")
    eng2.load_state_dict(snap)
    for x, y in zip(jax.tree.leaves(eng.rule_state),
                    jax.tree.leaves(eng2.rule_state)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))
    # restored engine continues identically
    nxt = _drift_batches(1, 8, seed=17)[0]
    np.testing.assert_array_equal(eng.score(nxt).mask, eng2.score(nxt).mask)


class _Gene(UserGene):
    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)

    def generate_new_data(self, data_to_gene):
        return False, self.rng.randn(IN_DIM).astype(np.float32)


class _Model(UserModel):
    def __init__(self, rank, rd, dev, mode):
        super().__init__(rank, rd, dev, mode)
        self.w = np.random.RandomState(rank).randn(IN_DIM, OUT_DIM) * 0.5

    def predict(self, xs):
        return [np.asarray(x) @ self.w for x in xs]

    def update(self, warr):
        self.w = warr.reshape(IN_DIM, OUT_DIM)

    def get_weight(self):
        return self.w.reshape(-1).astype(np.float32)

    def get_weight_size(self):
        return IN_DIM * OUT_DIM

    def add_trainingset(self, dps):
        pass

    def retrain(self, req):
        return False


class _Oracle(UserOracle):
    def run_calc(self, inp):
        return inp, np.zeros(OUT_DIM, np.float32)


def test_budget_state_survives_pal_checkpoint_restore():
    tmp = tempfile.mkdtemp()
    members, cparams, apply_fn = _committee(seed=21)
    cfg = PALRunConfig(result_dir=tmp, gene_process=2, orcl_process=0,
                       pred_process=1, ml_process=1, std_threshold=0.4,
                       oracle_budget=0.3, budget_horizon=8,
                       reweight_buckets=16)
    pal = PAL(cfg, make_generator=_Gene, make_model=_Model,
              make_oracle=_Oracle,
              committee=acq.CommitteeSpec(apply_fn, cparams))
    # config knobs built the budgeted pipeline on the fused engine
    assert isinstance(pal.engine, acq.FusedEngine)
    kinds = tuple(type(r).__name__ for r in pal.engine.rules)
    assert kinds == ("RollingReweightRule", "BudgetRule")
    # drive some exchange rounds so the carried state moves
    for _ in range(10):
        pal.exchange.step()
    moved = pal.engine.state_dict()
    assert int(moved[1]["rounds"]) == 10
    pal.checkpoint()

    pal2 = PAL(cfg, make_generator=_Gene, make_model=_Model,
               make_oracle=_Oracle,
               committee=acq.CommitteeSpec(apply_fn, cparams), resume=True)
    restored = pal2.engine.state_dict()
    for x, y in zip(jax.tree.leaves(moved), jax.tree.leaves(restored)):
        np.testing.assert_allclose(x, y)
    assert int(restored[1]["rounds"]) == 10


def test_load_state_dict_skips_mismatched_pipeline():
    """Resuming under a CHANGED budget/re-weighting config must not crash
    at trace time: a structurally mismatched snapshot is skipped (warning)
    and the fresh state keeps working."""
    members, cparams, apply_fn = _committee(seed=31)
    donor = acq.FusedEngine(
        apply_fn, cparams, 0.4,
        rules=(bud.RollingReweightRule(n_buckets=8),
               bud.BudgetRule(target=0.2, thr_init=0.4)),
        impl="xla")
    donor.score(_drift_batches(1, 8, seed=32)[0])
    snap = donor.state_dict()                  # (reweight, budget) 2-tuple

    eng = acq.FusedEngine(                     # budget-only pipeline now
        apply_fn, cparams, 0.4,
        rules=(bud.BudgetRule(target=0.2, thr_init=0.4),), impl="xla")
    fresh = eng.state_dict()
    eng.load_state_dict(snap)                  # mismatch: skipped
    for x, y in zip(jax.tree.leaves(eng.state_dict()),
                    jax.tree.leaves(fresh)):
        np.testing.assert_array_equal(x, y)
    uq = eng.score(_drift_batches(1, 8, seed=33)[0])    # still scores
    assert uq.mask.shape == (8,)
    # matching snapshot still restores
    eng.load_state_dict(snap[1:])
    assert float(eng.state_dict()[0]["rounds"]) == 1


def test_manager_fresh_score_does_not_consume_budget():
    """The runtime's fresh_score closure (dynamic_oracle_list) re-scores
    through the same engine WITHOUT advancing the controller."""
    tmp = tempfile.mkdtemp()
    members, cparams, apply_fn = _committee(seed=22)
    cfg = PALRunConfig(result_dir=tmp, gene_process=2, orcl_process=0,
                       pred_process=1, ml_process=1, std_threshold=0.4,
                       oracle_budget=0.3)
    pal = PAL(cfg, make_generator=_Gene, make_model=_Model,
              make_oracle=_Oracle,
              committee=acq.CommitteeSpec(apply_fn, cparams))
    pal.exchange.step()
    before = pal.engine.state_dict()
    rng = np.random.RandomState(0)
    pal.manager.fresh_score([rng.randn(IN_DIM) for _ in range(4)])
    after = pal.engine.state_dict()
    for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# config-driven pipeline factory
# ---------------------------------------------------------------------------


def test_rules_from_config_combinations():
    assert bud.rules_from_config(PALRunConfig()) is None
    r = bud.rules_from_config(PALRunConfig(oracle_budget=0.2,
                                           budget_horizon=32,
                                           std_threshold=0.7))
    assert len(r) == 1 and isinstance(r[0], bud.BudgetRule)
    assert r[0].target == 0.2 and r[0].horizon == 32
    assert r[0].thr_init == 0.7
    r = bud.rules_from_config(PALRunConfig(reweight_buckets=8,
                                           std_threshold=0.7))
    assert [type(x) for x in r] == [bud.RollingReweightRule,
                                    acq.ThresholdRule]
    assert r[1].threshold == 0.7
    r = bud.rules_from_config(PALRunConfig(reweight_buckets=8,
                                           oracle_budget=0.2))
    assert [type(x) for x in r] == [bud.RollingReweightRule, bud.BudgetRule]


def test_explicit_rules_override_config_budget():
    members, cparams, apply_fn = _committee(seed=23)
    cfg = PALRunConfig(oracle_budget=0.2)
    eng = acq.make_engine(cfg,
                          committee=acq.CommitteeSpec(apply_fn, cparams),
                          rules=(acq.ThresholdRule(0.1),))
    assert [type(r) for r in eng.rules] == [acq.ThresholdRule]


# ---------------------------------------------------------------------------
# serving: batch-level UQ through the same engine + controller
# ---------------------------------------------------------------------------


def test_committee_server_returns_uq_and_routes_to_oracle():
    members, cparams, apply_fn = _committee(seed=24)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.4,
        rules=(bud.BudgetRule(target=0.25, thr_init=0.4, horizon=8),),
        impl="xla")
    obuf = OracleInputBuffer()
    server = CommitteeServer(eng, obuf)
    rng = np.random.RandomState(25)
    batch = [(rng.randn(IN_DIM) * 2).astype(np.float32) for _ in range(12)]
    mean, uq = server.predict(batch)
    assert isinstance(uq, acq.UQResult)
    assert mean.shape == (12, OUT_DIM)
    np.testing.assert_allclose(mean, uq.mean)
    assert uq.mask.sum() > 0
    assert len(obuf) == int(uq.mask.sum())     # selected rows were routed
    routed = obuf.snapshot()
    want = [batch[int(i)] for i in np.where(uq.mask)[0]]
    for a, b in zip(routed, want):
        np.testing.assert_array_equal(a, b)
    assert server.requests == 12 and server.routed == len(routed)
    # served traffic advanced the shared controller (one round consumed)
    assert int(np.asarray(eng.rule_state[0]["rounds"])) == 1


def test_committee_server_read_only_mode():
    members, cparams, apply_fn = _committee(seed=26)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.4,
        rules=(bud.BudgetRule(target=0.25, thr_init=0.4),), impl="xla")
    server = CommitteeServer(eng, None, advance=False)
    rng = np.random.RandomState(27)
    for _ in range(3):
        server.predict([(rng.randn(IN_DIM) * 2).astype(np.float32)
                        for _ in range(6)])
    assert int(np.asarray(eng.rule_state[0]["rounds"])) == 0


def test_pal_serve_uq_builds_server_on_shared_engine():
    tmp = tempfile.mkdtemp()
    members, cparams, apply_fn = _committee(seed=28)
    cfg = PALRunConfig(result_dir=tmp, gene_process=2, orcl_process=0,
                       pred_process=1, ml_process=1, std_threshold=0.4,
                       oracle_budget=0.3, serve_uq=True)
    pal = PAL(cfg, make_generator=_Gene, make_model=_Model,
              make_oracle=_Oracle,
              committee=acq.CommitteeSpec(apply_fn, cparams))
    assert pal.server is not None
    assert pal.server.engine is pal.engine
    assert pal.server.oracle_buffer is pal.oracle_buffer
    rng = np.random.RandomState(29)
    _, uq = pal.server.predict([(rng.randn(IN_DIM) * 2).astype(np.float32)
                                for _ in range(5)])
    assert uq.mask.shape == (5,)
    assert len(pal.oracle_buffer) == int(uq.mask.sum())
    # served traffic shares the controller, so it counts toward the
    # reported realized rate (total metered demand, not exchange-only)
    assert pal.report()["oracle_rate"] == \
        pytest.approx(int(uq.mask.sum()) / 5)
