"""Sharding-rule resolution (divisibility fallback, axis reuse) and the
paper's-own-domain potential model (descriptor invariances, force
consistency)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import base as ax
from repro.configs.pal_potential import PotentialConfig
from repro.models import potential as pot
from repro.sharding.rules import MeshRules, merged_rules


class FakeMesh:
    """MeshRules only touches .shape for pspec resolution."""

    def __init__(self, shape):
        self.shape = dict(shape)


def _rules(mesh_shape, overrides=None):
    return MeshRules(FakeMesh(mesh_shape), overrides)


# ---------------------------------------------------------------------------
# rule resolution
# ---------------------------------------------------------------------------


def test_basic_tp_resolution():
    r = _rules({"data": 16, "model": 16})
    spec = r.pspec((ax.EMBED, ax.MLP), dims=(1024, 4096), name="wi")
    assert spec == P(None, "model")
    assert not r.fallbacks


def test_divisibility_fallback_drops_axis():
    r = _rules({"data": 16, "model": 16})
    # minicpm: 36 heads don't divide 16
    spec = r.pspec((ax.EMBED, ax.HEADS, ax.HEAD_DIM), dims=(2304, 36, 64))
    assert spec == P(None, None, None)
    assert len(r.fallbacks) == 1
    assert "36 % 16" in r.fallbacks[0].reason


def test_mesh_axis_reuse_fallback():
    r = _rules({"data": 16, "model": 16},
               {ax.SEQ: ("model",)})
    # seq takes 'model' first; heads then falls back
    spec = r.pspec((ax.BATCH, ax.SEQ, ax.HEADS, ax.HEAD_DIM),
                   dims=(256, 4096, 32, 128))
    assert spec == P("data", "model", None, None)
    assert any("mesh axis reuse" in f.reason for f in r.fallbacks)


def test_missing_mesh_axis_is_dropped():
    r = _rules({"data": 16, "model": 16})   # no 'pod' on single-pod mesh
    spec = r.pspec((ax.BATCH, None), dims=(256, 128))
    assert spec == P("data", None)
    r2 = _rules({"pod": 2, "data": 16, "model": 16})
    spec2 = r2.pspec((ax.BATCH, None), dims=(256, 128))
    assert spec2 == P(("pod", "data"), None)


def test_batch_one_falls_back_unsharded():
    r = _rules({"data": 16, "model": 16})
    spec = r.pspec((ax.BATCH, ax.CACHE_SEQ), dims=(1, 524288))
    assert spec == P(None, None)          # default cache_seq unsharded
    r2 = _rules({"data": 16, "model": 16}, {ax.CACHE_SEQ: ("data",)})
    spec2 = r2.pspec((ax.BATCH, ax.CACHE_SEQ), dims=(1, 524288))
    assert spec2 == P(None, "data")       # long_500k override


def test_merged_rules_override_order():
    rules = merged_rules({ax.EXPERTS: ()}, {ax.EXPERTS: ("model",)})
    assert rules[ax.EXPERTS] == ("model",)


# ---------------------------------------------------------------------------
# potential model (the paper's own domain)
# ---------------------------------------------------------------------------

CFG = PotentialConfig(n_atoms=6, committee_size=3, hidden=(32,), n_rbf=16)


def _coords(seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(6, 3) * 1.4)


def test_descriptor_translation_invariant():
    c = _coords()
    d1 = pot.descriptors(c, CFG)
    d2 = pot.descriptors(c + 5.0, CFG)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-5)


def test_descriptor_rotation_invariant():
    c = _coords()
    theta = 0.7
    R = jnp.asarray([[np.cos(theta), -np.sin(theta), 0],
                     [np.sin(theta), np.cos(theta), 0],
                     [0, 0, 1.0]])
    d1 = pot.descriptors(c, CFG)
    d2 = pot.descriptors(c @ R.T, CFG)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)


def test_descriptor_permutation_equivariant():
    c = _coords()
    perm = np.array([2, 0, 1, 5, 4, 3])
    d1 = pot.descriptors(c, CFG)
    d2 = pot.descriptors(c[perm], CFG)
    np.testing.assert_allclose(np.asarray(d1[perm]), np.asarray(d2),
                               atol=1e-5)


def test_energy_invariant_forces_equivariant():
    params = pot.init(CFG, jax.random.PRNGKey(0))
    c = _coords()
    e1, f1 = pot.energy_forces(params, c, CFG)
    e2, f2 = pot.energy_forces(params, c + 3.0, CFG)
    assert float(e1) == pytest.approx(float(e2), abs=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=1e-4)
    # translation invariance => forces sum to ~0
    np.testing.assert_allclose(np.asarray(f1.sum(0)), 0.0, atol=1e-4)


def test_lj_forces_match_finite_difference():
    c = _coords(1)
    e, f = pot.lj_energy_forces(c)
    eps = 1e-4
    for i, j in [(0, 0), (2, 1), (5, 2)]:
        cp = c.at[i, j].add(eps)
        cm = c.at[i, j].add(-eps)
        fd = -(pot.lennard_jones(cp) - pot.lennard_jones(cm)) / (2 * eps)
        assert float(f[i, j]) == pytest.approx(float(fd), rel=2e-2, abs=1e-3)


def test_committee_disagreement_nonzero_for_different_members():
    cp = pot.init_committee(CFG, jax.random.PRNGKey(0))
    e, f = pot.committee_energy_forces(cp, _coords(), CFG)
    assert e.shape == (3,)
    assert float(jnp.std(e)) > 0


def test_potential_loss_decreases_under_training():
    from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

    params = pot.init(CFG, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    # well-separated geometries: perturbed lattice (overlapping atoms make
    # the LJ labels blow up and the fit meaningless)
    lattice = np.stack(np.meshgrid([0, 1.3], [0, 1.3], [0, 1.3]),
                       -1).reshape(-1, 3)[:6]
    coords = jnp.asarray(lattice[None] + rng.randn(16, 6, 3) * 0.08)
    e, f = jax.vmap(pot.lj_energy_forces)(coords)
    batch = {"coords": coords, "energy": e, "forces": f}
    state = adamw_init(params)
    cfg_o = AdamWConfig(weight_decay=0.0)

    @jax.jit
    def step(params, state):
        (l, m), g = jax.value_and_grad(
            pot.potential_loss, has_aux=True)(params, batch, CFG)
        p2, s2 = adamw_update(g, state, params, jnp.float32(3e-3), cfg_o)
        return p2, s2, l

    losses = []
    for _ in range(60):
        params, state, l = step(params, state)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.5


def test_partial_subset_fallback_keeps_usable_axes():
    """('model','data') with 'data' taken degrades to ('model',), not to
    replicated (the jamba dense-FFN 256-way sharding case)."""
    r = _rules({"data": 16, "model": 16}, {ax.MLP: ("model", "data")})
    spec = r.pspec((ax.BATCH, None, ax.MLP), dims=(32, 4096, 24576))
    assert spec == P("data", None, "model")
    # weights (no batch): both axes usable
    spec_w = r2 = _rules({"data": 16, "model": 16},
                         {ax.MLP: ("model", "data")}).pspec(
        (ax.EMBED, ax.MLP), dims=(8192, 24576))
    assert spec_w == P(None, ("model", "data"))
