"""Memory-diet committee scaling tests (optim/memory_policy.py):

* property suite for ``optim/adamw.py`` int8 block quantization —
  roundtrip error bounded by the per-block absmax scale, shape/axis/dtype
  preservation, zero/constant/non-divisible-block/0-d edges, double-
  quantize idempotence, in-block monotonicity (the sqrt(nu) ordering the
  Adam denominator relies on);
* parity — ``CommitteeTrainer`` under int8/bf16 moment policies tracks the
  fp32 baseline at IDENTICAL data order over a full retrain schedule, and
  ``poison_member`` quarantine stays exact under every policy;
* checkpoint — a quantized stacked TrainState survives state_dict /
  ``PAL.checkpoint`` restore BIT-identically (QTensor q/scale leaves
  included, never dequantized on save), and restoring a snapshot whose
  policy mismatches the configured one raises a clear error;
* ``launch/dryrun.committee_state_bytes`` — the committee-stacking-aware
  optimizer-memory estimate is pinned against measured buffer bytes;
* tentpole acceptance — bf16 replay ring halves storage and append bytes,
  K=32 int8 committee trains and scores through the fused one-dispatch
  engine path, policies compose on the host mesh bit-identically.
"""
import pickle
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # tier-1 has no hypothesis
    from _hypothesis_fallback import given, settings, st

from repro.configs.pal_potential import PALRunConfig
from repro.core import CommitteeSpec, PAL, UserGene, UserOracle
from repro.core import committee as cmte
from repro.data.replay import ReplayTrainingBuffer
from repro.optim.adamw import QTensor, dequantize, quantize
from repro.optim.memory_policy import (
    MemoryPolicy, member_state_nbytes, resolve_policy, stacked_state_nbytes,
)
from repro.training.committee_trainer import CommitteeTrainer

K, IN_DIM, HIDDEN, OUT_DIM = 4, 6, 16, 3
POLICIES = ("fp32", "bf16", "int8")


def _apply(p, x):
    return jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def _loss(p, batch):
    pred = _apply(p, batch["x"])
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _members(seed=0, k=K):
    rng = np.random.RandomState(seed)
    return [{
        "w1": jnp.asarray(rng.randn(IN_DIM, HIDDEN).astype(np.float32) * .3),
        "b1": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * .1),
        "w2": jnp.asarray(rng.randn(HIDDEN, OUT_DIM).astype(np.float32) * .3),
        "b2": jnp.asarray(rng.randn(OUT_DIM).astype(np.float32) * .1),
    } for _ in range(k)]


def _data(n=40, seed=1):
    rng = np.random.RandomState(seed)
    return (rng.randn(n, IN_DIM).astype(np.float32),
            rng.randn(n, OUT_DIM).astype(np.float32))


def _trainer(policy, cparams=None, **kw):
    if cparams is None:
        cparams = cmte.stack_members(_members())
    kw.setdefault("steps", 10)
    kw.setdefault("batch", 8)
    kw.setdefault("lr", 1e-2)
    kw.setdefault("replay_capacity", 64)
    kw.setdefault("seed", 0)
    return CommitteeTrainer(_loss, cparams, memory_policy=policy, **kw)


def _leaves_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(la, lb))


def _per_element_scale(t: QTensor) -> np.ndarray:
    """Broadcast the blocked scale back to the source shape."""
    s = np.asarray(t.scale, np.float32)
    if s.ndim == 0:
        return s
    sm = np.moveaxis(s, t.axis, -1)
    full = np.repeat(sm, t.block, axis=-1)
    return np.moveaxis(full, -1, t.axis)


# ---------------------------------------------------------------------------
# int8 block quantization — property suite
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.tuples(st.integers(1, 5), st.integers(1, 40)),
       st.floats(min_value=-3.0, max_value=3.0),
       st.integers(0, 10 ** 6))
def test_quantize_roundtrip_error_bounded_by_block_scale(shape, offset, seed):
    """|x - deq(q(x))| <= scale/2 per element: round-to-nearest against the
    per-block absmax scale is the whole error budget — no outlier in one
    block may inflate the error bound of another block."""
    rng = np.random.RandomState(seed % (2 ** 31))
    x = (rng.randn(*shape) * rng.uniform(1e-3, 10.0)
         + offset).astype(np.float32)
    t = quantize(jnp.asarray(x))
    y = np.asarray(dequantize(t))
    bound = 0.5 * _per_element_scale(t) + 1e-7
    assert np.all(np.abs(x - y) <= bound)


@settings(max_examples=25, deadline=None)
@given(st.tuples(st.integers(1, 7), st.integers(1, 130)),
       st.integers(0, 10 ** 6))
def test_quantize_preserves_shape_axis_and_dtypes(shape, seed):
    rng = np.random.RandomState(seed % (2 ** 31))
    x = rng.randn(*shape).astype(np.float32)
    t = quantize(jnp.asarray(x))
    assert t.q.shape == x.shape
    assert t.q.dtype == jnp.int8
    assert t.scale.dtype == jnp.float32
    n = x.shape[t.axis]
    assert n % t.block == 0                      # block divides the axis
    want = list(x.shape)
    want[t.axis] = n // t.block
    assert t.scale.shape == tuple(want)
    assert np.asarray(dequantize(t)).shape == x.shape


def test_quantize_zero_and_constant_tensors_are_exact():
    z = quantize(jnp.zeros((3, 256)))
    assert np.all(np.asarray(z.q) == 0)
    assert np.all(np.asarray(dequantize(z)) == 0.0)
    # a constant block hits absmax exactly: q = ±127, roundtrip exact
    for c in (2.5, -0.125):
        t = quantize(jnp.full((4, 128), c, jnp.float32))
        np.testing.assert_allclose(np.asarray(dequantize(t)), c, rtol=1e-6)


def test_quantize_non_divisible_and_scalar_edges():
    # 7 is prime: block collapses to 7 (one block per row-dim)
    t7 = quantize(jnp.arange(7, dtype=jnp.float32))
    assert t7.block == 7 and t7.scale.shape == (1,)
    # 130 = 2*5*13: largest divisor <= 128 is 65 -> scale dim 2, in place
    x130 = np.random.RandomState(0).randn(3, 130).astype(np.float32)
    t130 = quantize(jnp.asarray(x130), axis=1)
    assert t130.block == 65 and t130.axis == 1
    assert t130.scale.shape == (3, 2)
    bound = 0.5 * _per_element_scale(t130) + 1e-7
    assert np.all(np.abs(x130 - np.asarray(dequantize(t130))) <= bound)
    # 0-d scalar round-trips through the [None] path
    s = quantize(jnp.float32(-1.75))
    assert s.q.shape == () and s.scale.shape == ()
    np.testing.assert_allclose(np.asarray(dequantize(s)), -1.75, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 128), st.integers(0, 10 ** 6))
def test_double_quantize_is_idempotent(n, seed):
    """quantize(dequantize(t)) reproduces t: q bitwise, scale allclose —
    re-checkpointing quantized moments must not drift."""
    rng = np.random.RandomState(seed % (2 ** 31))
    x = jnp.asarray(rng.randn(2, n).astype(np.float32) * 4.0)
    t1 = quantize(x)
    t2 = quantize(dequantize(t1), axis=t1.axis)
    assert np.array_equal(np.asarray(t1.q), np.asarray(t2.q))
    np.testing.assert_allclose(np.asarray(t1.scale), np.asarray(t2.scale),
                               rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 128), st.integers(0, 10 ** 6))
def test_quantize_monotone_within_block(n, seed):
    """Order-preserving inside a block (shared scale + round-to-nearest):
    nu is stored as sqrt(nu), and a monotonicity violation there would let
    a SMALLER second moment produce a SMALLER Adam denominator."""
    rng = np.random.RandomState(seed % (2 ** 31))
    x = np.sort(np.abs(rng.randn(n)).astype(np.float32))
    t = quantize(jnp.asarray(x))
    y = np.asarray(dequantize(t))
    if t.scale.shape == (1,):                    # single shared block only
        assert np.all(np.diff(y) >= 0)


def test_sqrt_nu_storage_bounds_denominator_error():
    """The reason for sqrt-space storage: quantizing sqrt(nu) keeps the
    roundtrip error of the Adam DENOMINATOR linear in the block scale even
    for tiny nu entries sharing a block with a large absmax."""
    nu = np.concatenate([np.full(127, 1e-6), [4.0]]).astype(np.float32)
    snu = np.sqrt(nu)
    deq = np.asarray(dequantize(quantize(jnp.asarray(snu))))
    # denominator error <= half an int8 step of the sqrt-space scale
    assert np.max(np.abs(deq - snu)) <= 0.5 * (snu.max() / 127.0) + 1e-7


# ---------------------------------------------------------------------------
# MemoryPolicy resolution + footprint accounting
# ---------------------------------------------------------------------------


def test_policy_presets_and_validation():
    assert MemoryPolicy.named("int8").moments == "int8"
    assert resolve_policy(None) is None
    assert resolve_policy("bf16").moments == "bf16"
    p = MemoryPolicy(name="x", moments="int8", replay_dtype="bfloat16")
    assert resolve_policy(p) is p
    with pytest.raises(ValueError, match="unknown"):
        MemoryPolicy.named("fp16")
    with pytest.raises(ValueError, match="unknown"):
        MemoryPolicy(moments="int4")
    with pytest.raises(ValueError, match="replay_dtype"):
        MemoryPolicy(replay_dtype="float16")
    with pytest.raises(TypeError):
        resolve_policy(42)


def _opt_nbytes(member_params, moments):
    """Optimizer-subtree bytes per member under a moment format."""
    from repro.configs.base import TrainConfig
    from repro.training.train_step import make_train_state
    sds = jax.eval_shape(
        lambda p: make_train_state(p, TrainConfig(opt_moments=moments)),
        member_params)
    return sum(int(np.prod(l.shape)) * np.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(sds.opt))


def test_stacked_footprint_shrinks_with_policy():
    """int8 moments must land well under the 40%-of-fp32 optimizer-state
    gate at the accounting level (the benchmark measures the same on
    device); total TrainState bytes shrink monotonically too."""
    m = _members(k=1)[0]
    by = {p: stacked_state_nbytes(m, 64, MemoryPolicy.named(p))
          for p in POLICIES}
    assert by["fp32"] == 64 * member_state_nbytes(m, MemoryPolicy.named("fp32"))
    assert by["int8"] < by["bf16"] < by["fp32"]
    opt = {p: 64 * _opt_nbytes(m, p) for p in POLICIES}
    assert opt["int8"] <= 0.40 * opt["fp32"]     # the ISSUE's bytes gate
    assert opt["bf16"] <= 0.55 * opt["fp32"]


@pytest.mark.parametrize("policy", POLICIES)
def test_estimate_matches_measured_buffer_bytes(policy):
    """satellite: the dryrun committee estimate == sum of the actual device
    buffer nbytes of the stacked TrainState, for every policy."""
    tr = _trainer(policy)                        # backend init BEFORE dryrun
    measured = sum(int(np.asarray(l).nbytes)
                   for l in jax.tree.leaves(tr.cstate))
    from repro.launch.dryrun import committee_state_bytes
    est = committee_state_bytes(_members(k=1)[0], K, policy=tr.policy)
    assert est == measured


def test_dryrun_estimate_accounts_for_stacking_and_quantization():
    from repro.configs.base import TrainConfig
    from repro.launch.dryrun import committee_state_bytes
    m = _members(k=1)[0]
    one = committee_state_bytes(m, 1)
    assert committee_state_bytes(m, 16) == 16 * one          # K-aware
    q = committee_state_bytes(m, 16,
                              train_cfg=TrainConfig(quantized_opt_state=True))
    assert q == committee_state_bytes(m, 16, policy="int8")  # legacy knob
    assert q < committee_state_bytes(m, 16)                  # format-aware


# ---------------------------------------------------------------------------
# parity under identical data order
# ---------------------------------------------------------------------------


def test_policy_parity_full_schedule_same_data_order():
    """bootstrap=False => every policy sees the IDENTICAL minibatch
    sequence; narrow moment storage must track the fp32 loss trajectory
    over a full retrain schedule, not just one step."""
    rng = np.random.RandomState(1)
    xs = rng.randn(48, IN_DIM).astype(np.float32)
    ys = np.tile(np.sin(2 * xs[:, :1]), (1, OUT_DIM)).astype(np.float32)
    batch = {"x": jnp.asarray(xs), "y": jnp.asarray(ys)}

    def full_loss(tr):                           # per-member, whole dataset
        return np.array([float(_loss(cmte.member(tr.cparams, i), batch)[0])
                         for i in range(K)])

    final = {}
    for policy in POLICIES:
        tr = _trainer(policy, bootstrap=False, seed=3)
        tr.add_blocks(list(zip(xs, ys)))
        before = full_loss(tr)
        tr.train(steps=30)
        final[policy] = full_loss(tr)
        assert np.all(final[policy] < before)    # every member learned
    for policy in ("bf16", "int8"):
        np.testing.assert_allclose(final[policy], final["fp32"],
                                   rtol=0.15, atol=5e-3)


@pytest.mark.parametrize("policy", POLICIES)
def test_poison_quarantine_exact_under_every_policy(policy):
    """poison_member + the fused step's non-finite rollback must stay exact
    whatever the moment storage: the poisoned member's params AND stored
    moments (QTensor leaves included) are bitwise frozen while the healthy
    members keep advancing."""
    xs, ys = _data()
    tr = _trainer(policy, bootstrap=True, seed=7)
    tr.add_blocks(list(zip(xs, ys)))
    tr.train(steps=3)
    tr.poison_member(1)
    frozen_mu = jax.tree.map(
        lambda l: np.asarray(l[1]).copy(), tr.cstate.opt.mu)
    frozen_step = int(np.asarray(tr.cstate.step[1]))
    healthy_w1 = np.asarray(tr.cparams["w1"][0]).copy()

    tr.train(steps=4)
    assert tr.last_member_ok is not None
    assert not tr.last_member_ok[1]
    assert tr.last_member_ok[[0, 2, 3]].all()
    # poisoned member rolled back every step: moments + step bitwise frozen
    assert _leaves_equal(
        frozen_mu, jax.tree.map(lambda l: np.asarray(l[1]), tr.cstate.opt.mu))
    assert int(np.asarray(tr.cstate.step[1])) == frozen_step
    assert np.all(np.isnan(np.asarray(tr.cparams["w1"][1])))
    # healthy members advanced and stayed finite
    assert not np.array_equal(np.asarray(tr.cparams["w1"][0]), healthy_w1)
    for i in (0, 2, 3):
        assert np.all(np.isfinite(np.asarray(tr.cparams["w1"][i])))


def test_host_mesh_int8_bit_identical_to_unsharded():
    """The degenerate 1x1 host mesh must not perturb quantized training:
    committee_shardings over QTensor leaves is layout-only."""
    from repro.launch.mesh import make_host_mesh
    xs, ys = _data()
    tr_plain = _trainer("int8", bootstrap=True, seed=11)
    tr_mesh = _trainer("int8", bootstrap=True, seed=11,
                       mesh=make_host_mesh())
    for tr in (tr_plain, tr_mesh):
        tr.add_blocks(list(zip(xs, ys)))
        tr.train(steps=6)
    assert _leaves_equal(tr_plain.cstate, tr_mesh.cstate)


# ---------------------------------------------------------------------------
# replay-ring storage dtype
# ---------------------------------------------------------------------------


def test_replay_bf16_halves_ring_and_append_bytes():
    xs, ys = _data(32)
    buf32 = ReplayTrainingBuffer(64)
    buf16 = ReplayTrainingBuffer(64, dtype="bfloat16")
    buf32.append(xs, ys)
    buf16.append(xs, ys)
    x32, _, n32 = buf32.arrays()
    x16, _, n16 = buf16.arrays()
    assert n32 == n16 == 32
    assert x16.dtype == jnp.bfloat16 and x32.dtype == jnp.float32
    assert x16.nbytes * 2 == x32.nbytes
    assert buf16.bytes_to_device * 2 == buf32.bytes_to_device
    # gather values agree up to bf16 rounding
    np.testing.assert_allclose(np.asarray(x16[:n16], np.float32),
                               np.asarray(x32[:n32]), rtol=1e-2, atol=1e-2)


def test_replay_snapshot_preserves_storage_dtype():
    xs, ys = _data(16)
    buf = ReplayTrainingBuffer(32, dtype="bfloat16")
    buf.append(xs, ys)
    sd = buf.state_dict()
    assert sd["dtype"] == "bfloat16"
    assert np.asarray(sd["x"]).dtype == jnp.bfloat16  # no widen-on-save
    fresh = ReplayTrainingBuffer(32)                  # fp32-configured
    fresh.load_state_dict(sd)
    assert fresh.dtype == "bfloat16"                  # snapshot wins
    assert fresh.arrays()[0].dtype == jnp.bfloat16
    # legacy fp32 snapshot (no dtype key) restores as fp32
    buf32 = ReplayTrainingBuffer(32)
    buf32.append(xs, ys)
    legacy = buf32.state_dict()
    legacy.pop("dtype")
    into = ReplayTrainingBuffer(32, dtype="bfloat16")
    into.load_state_dict(legacy)
    assert into.dtype == "float32"


# ---------------------------------------------------------------------------
# checkpoint: native quantized leaves + policy-mismatch refusal
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_trainer_snapshot_roundtrip_bit_identical(policy):
    """state_dict -> pickle wire -> load restores the stacked TrainState
    BIT-identically under every policy (QTensor q/scale leaves native),
    and continued training is bit-identical to the original."""
    xs, ys = _data()
    tr = _trainer(policy, seed=4)
    tr.add_blocks(list(zip(xs, ys)))
    tr.train(steps=5)
    wire = pickle.dumps(tr.state_dict())

    tr2 = _trainer(policy, seed=4)
    tr2.load_state_dict(pickle.loads(wire))
    assert _leaves_equal(tr.cstate, tr2.cstate)
    if policy == "int8":
        mu_leaves = jax.tree.leaves(
            tr2.cstate.opt.mu, is_leaf=lambda x: isinstance(x, QTensor))
        assert all(isinstance(l, QTensor) for l in mu_leaves)
        assert all(l.q.dtype == jnp.int8 for l in mu_leaves)
    tr.train(steps=3)
    tr2.train(steps=3)
    assert _leaves_equal(tr.cstate, tr2.cstate)


def test_snapshot_policy_mismatch_raises_not_dequantizes():
    """An int8 snapshot into an fp32-policy trainer (and vice versa) is a
    hard error naming the mismatch — never a silent re-format."""
    xs, ys = _data()
    tr_i8 = _trainer("int8")
    tr_i8.add_blocks(list(zip(xs, ys)))
    tr_i8.train(steps=2)
    snap = tr_i8.state_dict()
    with pytest.raises(ValueError, match="memory policy"):
        _trainer("fp32").load_state_dict(snap)
    with pytest.raises(ValueError, match="int8"):
        _trainer("bf16").load_state_dict(snap)
    # legacy snapshot without metadata: format is INFERRED from the leaves
    snap2 = {k: v for k, v in snap.items() if k != "memory_policy"}
    with pytest.raises(ValueError, match="memory policy"):
        _trainer("fp32").load_state_dict(snap2)
    # and the matching policy still restores it
    tr_ok = _trainer("int8")
    tr_ok.load_state_dict(snap2)
    assert _leaves_equal(tr_i8.cstate, tr_ok.cstate)


def test_params_dtype_mismatch_raises():
    bf = MemoryPolicy(name="w", moments="fp32", params_dtype="bfloat16")
    tr_bf = _trainer(bf)
    with pytest.raises(ValueError, match="params_dtype"):
        _trainer("fp32").load_state_dict(tr_bf.state_dict())


# ---------------------------------------------------------------------------
# PAL runtime integration
# ---------------------------------------------------------------------------


class _Gene(UserGene):
    def __init__(self, rank, rd):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)

    def generate_new_data(self, data_to_gene):
        return False, self.rng.randn(IN_DIM).astype(np.float32)


class _Oracle(UserOracle):
    def run_calc(self, inp):
        y = np.tile(np.sin(2 * inp[:1]), OUT_DIM).astype(np.float32)
        return inp, y


def _pal(tmp, **kw):
    cfg = PALRunConfig(
        result_dir=tmp, gene_process=2, orcl_process=1, pred_process=1,
        ml_process=2, retrain_size=6, std_threshold=0.05, patience=3,
        train_steps=20, train_batch=8, train_lr=1e-2,
        train_replay_capacity=128, **kw)
    return PAL(cfg, make_generator=_Gene, make_oracle=_Oracle,
               committee=CommitteeSpec(_apply, cmte.stack_members(_members())),
               loss_fn=_loss)


def test_pal_checkpoint_roundtrip_quantized_policy():
    """PAL.checkpoint under train_memory_policy='int8': the quantized
    stacked TrainState survives save/restore bit-identically and the
    restored weights publish to the engine device-to-device."""
    tmp = tempfile.mkdtemp()
    pal = _pal(tmp, train_memory_policy="int8",
               train_replay_dtype="bfloat16")
    assert pal.committee_trainer.policy.moments == "int8"
    assert pal.committee_trainer.replay.dtype == "bfloat16"
    xs, ys = _data(20)
    pal.committee_trainer.add_blocks(list(zip(xs, ys)))
    pal.committee_trainer.train(steps=7)
    pal.checkpoint()

    pal2 = _pal(tmp, train_memory_policy="int8",
                train_replay_dtype="bfloat16")
    pal2._restore()
    t1, t2 = pal.committee_trainer, pal2.committee_trainer
    assert t2.steps_done == t1.steps_done == 7
    assert _leaves_equal(t1.cstate, t2.cstate)
    assert t2.replay.dtype == "bfloat16"
    assert pal2.engine.refresh_host_bytes == 0   # zero-copy handoff intact
    t1.train(steps=2)
    t2.train(steps=2)
    assert _leaves_equal(t1.cstate, t2.cstate)


def test_pal_restore_policy_mismatch_raises():
    tmp = tempfile.mkdtemp()
    pal = _pal(tmp, train_memory_policy="int8")
    xs, ys = _data(20)
    pal.committee_trainer.add_blocks(list(zip(xs, ys)))
    pal.committee_trainer.train(steps=3)
    pal.checkpoint()
    pal2 = _pal(tmp)                             # fp32-configured run
    with pytest.raises(ValueError, match="memory policy"):
        pal2._restore()


# ---------------------------------------------------------------------------
# tentpole acceptance: big-K committee through the fused paths
# ---------------------------------------------------------------------------


def test_k32_int8_trains_and_scores_through_fused_engine():
    """K=32 with int8 moments + bf16 replay: trains through the ONE fused
    dispatch and scores through FusedEngine via the zero-copy device
    handoff — the memory-diet K-scaling path end to end."""
    from repro.core.acquisition import FusedEngine
    k = 32
    cparams = cmte.stack_members(_members(seed=2, k=k))
    pol = MemoryPolicy(name="diet", moments="int8", replay_dtype="bfloat16")
    tr = CommitteeTrainer(_loss, cparams, steps=4, batch=8, lr=1e-2,
                          replay_capacity=64, seed=0, memory_policy=pol)
    xs, ys = _data()
    tr.add_blocks(list(zip(xs, ys)))
    out = tr.train()
    assert out["loss"].shape == (k,)
    assert np.all(np.isfinite(out["loss"]))

    eng = FusedEngine(_apply, cparams, 0.05, impl="xla")
    eng.refresh_from_device(tr.snapshot_cparams())
    res = eng.score(xs[:8])
    assert res.scalar_std.shape == (8,)
    assert np.all(np.isfinite(res.scalar_std))
    assert eng.refresh_host_bytes == 0
