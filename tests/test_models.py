"""Model-zoo behaviour: forward/loss sanity per family, prefill/decode
consistency against the full forward, XLA-vs-Pallas impl equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_config
from repro.models import common as cm
from repro.models.model_zoo import build_model, make_loss_fn

FAMILIES = ["dense", "moe", "rwkv6", "hybrid", "encdec", "vlm"]
B, T = 2, 16


def _batch(cfg, rng, tokens=None, T=T):
    tok = tokens if tokens is not None else jax.random.randint(
        rng, (B, T), 0, cfg.vocab_size)
    b = {"tokens": tok, "labels": tok}
    if cfg.family == "encdec":
        b["enc_embeds"] = jax.random.normal(rng, (B, cfg.encoder_seq,
                                                  cfg.d_model))
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(rng, (B, cfg.vision_tokens,
                                                    cfg.d_model))
    return b


@pytest.mark.parametrize("family", FAMILIES)
def test_forward_shapes_and_finite(family, rng):
    cfg = tiny_config(family)
    m = build_model(cfg, max_seq=T)
    params = m.init(rng)
    batch = _batch(cfg, rng)
    loss, metrics = make_loss_fn(m)(params, batch)
    assert jnp.isfinite(loss)
    logits = m.forward(params, batch) if family not in ("moe", "hybrid") \
        else m.forward(params, batch, return_aux=True)[0]
    # vlm: `tokens` are text-only; logits cover text positions
    assert logits.shape == (B, T, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("family", FAMILIES)
def test_prefill_and_decode_match_forward(family, rng):
    cfg = tiny_config(family)
    m = build_model(cfg, max_seq=T + 4)
    params = m.init(rng)
    tok = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    n_prefix = cfg.vision_tokens if family == "vlm" else 0
    cache = m.init_cache(B, T + n_prefix + 4)
    batch = _batch(cfg, rng, tokens=tok)

    kw = {}
    if family == "encdec":
        kw["enc_embeds"] = batch["enc_embeds"]
    if family == "vlm":
        kw["patch_embeds"] = batch["patch_embeds"]
    last, cache = m.prefill(params, tok, cache, **kw)
    full = m.forward(params, batch) if family not in ("moe", "hybrid") \
        else m.forward(params, batch, return_aux=True)[0]
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=5e-4)

    nxt = jnp.argmax(last, -1)[:, None].astype(jnp.int32)
    logits2, cache = m.decode_step(params, nxt, cache,
                                   jnp.int32(T + n_prefix))
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([tok, nxt], 1)
    batch2["labels"] = batch2["tokens"]
    full2 = m.forward(params, batch2) if family not in ("moe", "hybrid") \
        else m.forward(params, batch2, return_aux=True)[0]
    np.testing.assert_allclose(np.asarray(logits2), np.asarray(full2[:, -1]),
                               atol=5e-4)


@pytest.mark.parametrize("family", ["dense", "rwkv6", "hybrid"])
def test_xla_vs_pallas_interpret_forward(family, rng):
    cfg = tiny_config(family)
    m_x = build_model(cfg, impl="xla")
    m_p = build_model(cfg, impl="pallas_interpret")
    params = m_x.init(rng)
    tok = jax.random.randint(rng, (B, 64), 0, cfg.vocab_size)
    lx = m_x.forward(params, {"tokens": tok})
    lp = m_p.forward(params, {"tokens": tok})
    if family in ("moe", "hybrid"):
        lx, lp = lx, lp
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp), atol=2e-3)


def test_scan_vs_unrolled_layers_equal(rng):
    cfg = tiny_config("dense", num_layers=3)
    m1 = build_model(cfg)
    m2 = build_model(cfg.replace(scan_layers=False))
    params = m1.init(rng)
    tok = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    # scan vs unrolled only differ by XLA fusion reassociation
    np.testing.assert_allclose(
        np.asarray(m1.forward(params, {"tokens": tok})),
        np.asarray(m2.forward(params, {"tokens": tok})), atol=1e-3)


def test_remat_modes_do_not_change_values(rng):
    cfg = tiny_config("dense")
    tok = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    outs = []
    for remat in ("none", "dots", "full"):
        m = build_model(cfg.replace(remat=remat))
        params = m.init(rng)
        loss, _ = make_loss_fn(m)(params, {"tokens": tok, "labels": tok})
        outs.append(float(loss))
    assert outs[0] == pytest.approx(outs[1], abs=1e-5)
    assert outs[0] == pytest.approx(outs[2], abs=1e-5)


def test_gqa_grouping_uses_shared_kv(rng):
    """With identical kv heads replicated, GQA == MHA on the same kv."""
    cfg = tiny_config("dense", num_heads=4, num_kv_heads=4)
    m = build_model(cfg)
    params = m.init(rng)
    tok = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    out = m.forward(params, {"tokens": tok})
    assert bool(jnp.isfinite(out).all())


def test_sliding_window_changes_logits(rng):
    cfg = tiny_config("dense")
    m_full = build_model(cfg)
    m_swa = build_model(cfg.replace(sliding_window=4))
    params = m_full.init(rng)
    tok = jax.random.randint(rng, (B, 32), 0, cfg.vocab_size)
    a = m_full.forward(params, {"tokens": tok})
    b = m_swa.forward(params, {"tokens": tok})
    # early positions identical (window covers all), late ones differ
    np.testing.assert_allclose(np.asarray(a[:, :4]), np.asarray(b[:, :4]),
                               atol=1e-5)
    assert not np.allclose(np.asarray(a[:, -1]), np.asarray(b[:, -1]),
                           atol=1e-4)


def test_moe_aux_loss_positive_and_bounded(rng):
    cfg = tiny_config("moe")
    m = build_model(cfg)
    params = m.init(rng)
    tok = jax.random.randint(rng, (B, T), 0, cfg.vocab_size)
    _, aux = m.forward(params, {"tokens": tok}, return_aux=True)
    # Switch aux >= 1 ideally ~1 at uniform routing, scaled by coef
    assert float(aux) > 0.0
    assert float(aux) < 10.0


def test_lm_loss_ignores_negative_labels(rng):
    from repro.models.transformer import lm_loss
    logits = jax.random.normal(rng, (2, 8, 32))
    labels = jnp.full((2, 8), -1, jnp.int32)
    labels = labels.at[0, 0].set(3)
    loss, metrics = lm_loss(logits, labels)
    assert metrics["tokens"] == 1.0
    assert jnp.isfinite(loss)


def test_vocab_padding_rounds_up():
    cfg = tiny_config("dense", vocab_size=122753)
    assert cfg.padded_vocab == 122880
    cfg2 = tiny_config("dense", vocab_size=51865)
    assert cfg2.padded_vocab == 51968
