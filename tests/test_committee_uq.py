"""Fused committee-UQ tests: kernel parity (xla vs pallas_interpret vs
NumPy ddof=1, incl. the component-std output), K=1 edge case, the
shape-bucketed jit cache (compiles at most once per bucket), UQResult
routing equivalence, vectorized diversity_filter semantics, and
preallocated weight-pack buffers.  Engine backend/rule parity lives in
tests/test_acquisition.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.core import selection as sel
from repro.core.buffers import OracleInputBuffer
from repro.core.controller import Exchange, ExchangeConfig, PredictionPool
from repro.core.weight_sync import WeightStore
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# kernel parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("K,n,d", [
    (8, 64, 4),       # acceptance shape
    (4, 33, 8),       # n not a multiple of the row block -> padding path
    (3, 10, 5),       # odd everything
    (2, 1, 1),        # minimal
    (16, 128, 16),    # larger
])
def test_committee_uq_xla_vs_pallas_interpret(K, n, d):
    rng = np.random.RandomState(0)
    preds = jnp.asarray(rng.randn(K, n, d).astype(np.float32))
    t = 0.8
    mx, sx, cx, kx, fx = ops.committee_uq(preds, t, impl="xla")
    mp, sp, cp, kp, fp = ops.committee_uq(preds, t, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(mp), np.asarray(mx),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sx),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cp), np.asarray(cx),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(kp), np.asarray(kx))
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(fx))
    assert (np.asarray(fx) == K).all()        # all-finite inputs
    assert mx.shape == (n, d) and sx.shape == (n,)
    assert cx.shape == (n,) and kx.shape == (n,) and fx.shape == (n,)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_committee_uq_matches_numpy_ddof1(impl):
    rng = np.random.RandomState(1)
    K, n, d = 6, 24, 3
    preds = rng.randn(K, n, d).astype(np.float32)
    t = 0.7
    mean, sstd, cstd, mask, _ = ops.committee_uq(jnp.asarray(preds), t,
                                                 impl=impl)
    std64 = preds.astype(np.float64).std(axis=0, ddof=1)
    want_sstd = std64.max(axis=-1)
    want_cstd = std64.mean(axis=-1)
    np.testing.assert_allclose(np.asarray(mean), preds.mean(axis=0),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(sstd), want_sstd,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cstd), want_cstd,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(mask), want_sstd > t)


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_committee_uq_k1_zero_std(impl):
    """A single-member committee has zero disagreement by definition."""
    preds = jnp.asarray(np.random.RandomState(2).randn(1, 16, 4)
                        .astype(np.float32))
    mean, sstd, cstd, mask, finite = ops.committee_uq(preds, 1e-9, impl=impl)
    assert (np.asarray(finite) == 1).all()
    np.testing.assert_allclose(np.asarray(mean), np.asarray(preds[0]),
                               rtol=1e-6)
    assert (np.asarray(sstd) == 0).all()
    assert (np.asarray(cstd) == 0).all()
    assert not np.asarray(mask).any()


def test_committee_uq_mask_equals_anycomponent_semantics():
    """mask == (per-component std > t).any(components) — the paper's check."""
    rng = np.random.RandomState(3)
    preds = rng.randn(5, 20, 6).astype(np.float32)
    t = 0.9
    _, _, _, mask, _ = ops.committee_uq(jnp.asarray(preds), t, impl="xla")
    want = (preds.std(axis=0, ddof=1) > t).any(axis=-1)
    np.testing.assert_array_equal(np.asarray(mask), want)


# ---------------------------------------------------------------------------
# member quarantine: degraded-K statistics inside the same pass
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_committee_uq_quarantines_nonfinite_members(impl):
    """A member with ANY non-finite component in a row is excluded from
    that row's statistics; the remaining members produce exact degraded-K
    mean/std and the finite count reports the degradation."""
    rng = np.random.RandomState(7)
    K, n, d = 5, 40, 3
    preds = rng.randn(K, n, d).astype(np.float32)
    bad = preds.copy()
    bad[2, :10] = np.nan            # member 2 diverged on rows 0..9
    bad[4, 10, 1] = np.inf          # member 4: one bad component on row 10
    t = 0.5
    m, s, c, k, f = (np.asarray(o) for o in ops.committee_uq(
        jnp.asarray(bad), t, impl=impl))
    want_f = np.full(n, K, np.int32)
    want_f[:11] = K - 1
    np.testing.assert_array_equal(f, want_f)
    assert np.isfinite(m).all() and np.isfinite(s).all()
    keep = preds[[0, 1, 3, 4]]      # the finite members on rows 0..9
    std64 = keep[:, :10].astype(np.float64).std(axis=0, ddof=1)
    np.testing.assert_allclose(m[:10], keep[:, :10].mean(axis=0),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(s[:10], std64.max(axis=-1),
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(c[:10], std64.mean(axis=-1),
                               rtol=1e-4, atol=1e-6)
    # untouched rows: bit-identical to the all-finite committee
    ref_out = [np.asarray(o) for o in ops.committee_uq(
        jnp.asarray(preds), t, impl=impl)]
    np.testing.assert_array_equal(m[11:], ref_out[0][11:])
    np.testing.assert_array_equal(s[11:], ref_out[1][11:])


@pytest.mark.parametrize("impl", ["xla", "pallas_interpret"])
def test_committee_uq_zero_and_one_finite_member_rows(impl):
    """cnt < 2 rows have std 0 (disagreement unmeasurable); cnt == 0 rows
    are force-unselected however low the threshold."""
    rng = np.random.RandomState(8)
    preds = rng.randn(4, 12, 2).astype(np.float32)
    preds[:, 3] = np.nan            # row 3: no finite member at all
    preds[1:, 5] = np.nan           # row 5: exactly one finite member
    m, s, c, k, f = (np.asarray(o) for o in ops.committee_uq(
        jnp.asarray(preds), 0.0, impl=impl))
    assert f[3] == 0 and f[5] == 1
    assert s[3] == 0 and s[5] == 0 and np.isfinite(m).all()
    assert not k[3]                 # zero finite members -> never selected
    np.testing.assert_allclose(m[5], preds[0, 5], rtol=1e-6)


def test_committee_uq_allfinite_bit_identical_to_unmasked_welford():
    """The masked Welford recurrence degenerates to the historical unmasked
    one when every member is finite — same compiled math, not merely
    allclose."""
    rng = np.random.RandomState(9)
    preds = jnp.asarray(rng.randn(6, 32, 4).astype(np.float32))
    m, s, c, k, f = ops.committee_uq(preds, 0.4, impl="pallas_interpret")
    p64 = np.asarray(preds)
    assert (np.asarray(f) == 6).all()
    np.testing.assert_allclose(np.asarray(m), p64.mean(axis=0),
                               rtol=1e-6, atol=1e-7)


def test_fused_engine_reports_finite_members_single_dispatch():
    """Quarantined-member scoring stays ONE fused dispatch per bucket: a
    poisoned member changes trace_counts not at all, and UQResult carries
    the finite count."""
    members, cparams, apply_fn = _mlp()
    eng = acq.FusedEngine(apply_fn, cparams, 0.3, impl="xla")
    rng = np.random.RandomState(11)
    gen = lambda n: [rng.randn(6).astype(np.float32) for _ in range(n)]
    uq = eng.score(gen(8))
    assert uq.finite_members is not None
    assert (uq.finite_members == 4).all()
    assert eng.last_finite_min == 4 and eng.quarantine_rounds == 0
    # poison member 1's weights -> every row scores with K-1 finite members
    import jax as _jax
    poisoned = _jax.tree.map(
        lambda l: l.at[1].set(jnp.nan), eng.cparams)
    eng.cparams = poisoned
    uq2 = eng.score(gen(8))
    assert (uq2.finite_members == 3).all()
    assert np.isfinite(uq2.mean).all() and np.isfinite(uq2.scalar_std).all()
    assert eng.last_finite_min == 3 and eng.quarantine_rounds == 1
    assert eng.trace_counts == {8: 1}          # no retrace, no extra dispatch


# ---------------------------------------------------------------------------
# fused engine: bucketed jit cache + end-to-end equivalence
# ---------------------------------------------------------------------------

def _mlp():
    rng = np.random.RandomState(0)
    members = [{"w": jnp.asarray(rng.randn(6, 3).astype(np.float32) * 0.5)}
               for _ in range(4)]
    return members, cmte.stack_members(members), (lambda p, x: x @ p["w"])


def test_bucketed_jit_cache_compiles_once_per_bucket():
    _, cparams, apply_fn = _mlp()
    eng = acq.FusedEngine(apply_fn, cparams, 0.3, impl="xla")
    rng = np.random.RandomState(0)
    gen = lambda n: [rng.randn(6).astype(np.float32) for _ in range(n)]
    for n in (5, 8, 3, 7, 8, 1):          # all land in the n=8 bucket
        uq = eng.score(gen(n))
        assert uq.mean.shape == (n, 3) and uq.scalar_std.shape == (n,)
        assert uq.component_std.shape == (n,)
    assert eng.trace_counts == {8: 1}
    eng.score(gen(20))                     # new bucket: 32
    eng.score(gen(32))
    eng.score(gen(9))                      # new bucket: 16
    assert eng.trace_counts == {8: 1, 32: 1, 16: 1}
    assert all(c == 1 for c in eng.trace_counts.values())


def test_shape_bucket_power_of_two():
    assert cmte.shape_bucket(1) == 8
    assert cmte.shape_bucket(8) == 8
    assert cmte.shape_bucket(9) == 16
    assert cmte.shape_bucket(100) == 128
    assert cmte.shape_bucket(3, minimum=2) == 4


def test_fused_engine_matches_reference_uq():
    members, cparams, apply_fn = _mlp()
    eng = acq.FusedEngine(apply_fn, cparams, 0.3, impl="xla")
    rng = np.random.RandomState(4)
    inputs = [rng.randn(6).astype(np.float32) for _ in range(7)]
    uq = eng.score(inputs)
    x = np.stack(inputs)
    preds = np.stack([np.asarray(x @ np.asarray(m["w"])) for m in members])
    std = preds.std(axis=0, ddof=1)
    np.testing.assert_allclose(uq.mean, preds.mean(axis=0), rtol=1e-4,
                               atol=1e-5)
    np.testing.assert_allclose(uq.scalar_std, std.max(axis=-1),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(uq.component_std, std.mean(axis=-1),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(uq.mask, std.max(axis=-1) > 0.3)


def test_selection_from_uq_equals_prediction_check():
    """selection_from_uq(engine UQResult) == prediction_check(preds)."""
    rng = np.random.RandomState(5)
    inputs = [rng.randn(4) for _ in range(12)]
    preds = rng.randn(5, 12, 3)
    t = 0.8
    legacy = sel.prediction_check(inputs, preds, t)
    mean, sstd, cstd, mask, _ = ops.committee_uq(
        jnp.asarray(preds, dtype=jnp.float32), t, impl="xla")
    uq = acq.UQResult(np.asarray(mean), np.asarray(sstd), np.asarray(cstd),
                      np.asarray(mask))
    fast = sel.selection_from_uq(inputs, uq)
    np.testing.assert_array_equal(fast.uncertain_mask, legacy.uncertain_mask)
    np.testing.assert_allclose(fast.std, legacy.std, rtol=1e-4, atol=1e-5)
    assert len(fast.inputs_to_oracle) == len(legacy.inputs_to_oracle)
    for a, b in zip(fast.inputs_to_oracle, legacy.inputs_to_oracle):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(fast.data_to_generators, legacy.data_to_generators):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_exchange_fused_path_matches_legacy():
    """Full Exchange loop: fused single-dispatch == sequential members."""
    members, cparams, apply_fn = _mlp()
    eng = acq.FusedEngine(apply_fn, cparams, 0.3, impl="xla")

    class Gene:
        def __init__(self, rank):
            self.rng = np.random.RandomState(rank)
            self.received = []

        def generate_new_data(self, data_to_gene):
            self.received.append(data_to_gene)
            return False, self.rng.randn(6).astype(np.float32)

        def save_progress(self):
            pass

    class Member:
        def __init__(self, p):
            self.w = np.asarray(p["w"])

        def predict(self, xs):
            return [np.asarray(x, np.float32) @ self.w for x in xs]

    cfg = ExchangeConfig(std_threshold=0.3, patience=2)
    ga, gb = [Gene(i) for i in range(5)], [Gene(i) for i in range(5)]
    oa, ob = OracleInputBuffer(), OracleInputBuffer()
    # legacy pool: Exchange installs the per-member default engine
    ex_legacy = Exchange(ga, PredictionPool([Member(m) for m in members],
                                            None), oa, cfg)
    ex_fused = Exchange(gb, PredictionPool([], None, engine=eng), ob, cfg)
    assert isinstance(ex_legacy.prediction.engine, acq.LegacyEngine)
    for _ in range(8):
        ex_legacy.step()
        ex_fused.step()
    assert len(oa) == len(ob)
    for a, b in zip(ga, gb):
        for da, db in zip(a.received, b.received):
            assert (da is None) == (db is None)
            if da is not None:
                np.testing.assert_allclose(da, db, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# satellite: vectorized diversity_filter semantics
# ---------------------------------------------------------------------------


def _diversity_filter_reference(inputs, selected, min_dist):
    kept = []
    for i in selected:
        x = np.asarray(inputs[int(i)]).reshape(-1)
        if all(np.linalg.norm(x - np.asarray(inputs[j]).reshape(-1))
               >= min_dist for j in kept):
            kept.append(int(i))
    return np.asarray(kept, dtype=int)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_diversity_filter_matches_naive_loop(seed):
    rng = np.random.RandomState(seed)
    inputs = [rng.randn(3) * 0.5 for _ in range(40)]
    selected = rng.permutation(40)[:25]
    for min_dist in (0.05, 0.5, 2.0):
        got = sel.diversity_filter(inputs, selected, min_dist)
        want = _diversity_filter_reference(inputs, selected, min_dist)
        np.testing.assert_array_equal(got, want)


def test_diversity_filter_empty_selection():
    assert sel.diversity_filter([np.zeros(2)], np.array([], dtype=int),
                                0.1).size == 0


# ---------------------------------------------------------------------------
# satellite: preallocated weight-pack buffers
# ---------------------------------------------------------------------------


def test_get_weight_into_preallocated_buffer():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": jnp.ones((4,), jnp.float32)}
    want = cmte.get_weight(tree)
    buf = np.zeros(cmte.get_weight_size(tree), np.float32)
    out = cmte.get_weight(tree, out=buf)
    assert out is buf
    np.testing.assert_array_equal(out, want)
    with pytest.raises(ValueError):
        cmte.get_weight(tree, out=np.zeros(3, np.float32))


def test_weight_store_publish_reuses_buffers():
    tree = {"w": jnp.ones((3, 3), jnp.float32)}
    store = WeightStore(1)
    store.publish(0, tree)
    first, v1 = store.pull_packed(0)
    buf_a = store._weights[0]
    store.publish(0, jax.tree.map(lambda x: x * 2, tree))
    second, v2 = store.pull_packed(0)
    buf_b = store._weights[0]
    assert v2 > v1
    assert buf_b is not buf_a                  # ping-pong pair
    np.testing.assert_array_equal(second, first * 2)
    store.publish(0, jax.tree.map(lambda x: x * 3, tree))
    assert store._weights[0] is buf_a          # buffer cycled, no fresh alloc
    third, _ = store.pull_packed(0)
    np.testing.assert_array_equal(third, np.full(9, 3.0, np.float32))
    # pulls hand out copies, never the live pack buffer
    assert third is not store._weights[0]
    third[:] = -1.0
    again, _ = store.pull_packed(0)
    np.testing.assert_array_equal(again, np.full(9, 3.0, np.float32))


def test_weight_store_publish_packed_copies_caller_array():
    store = WeightStore(1)
    arr = np.arange(4, dtype=np.float32)
    store.publish_packed(0, arr)
    arr[:] = -1                                # caller reuses its buffer
    got, _ = store.pull_packed(0)
    np.testing.assert_array_equal(got, np.arange(4, dtype=np.float32))


def test_fused_engine_refresh_replicates_members():
    """K=4 prediction committee fed by 2 trainers: member i replicates
    trainer i % 2, committee shape (and jit cache) preserved."""
    _, cparams, apply_fn = _mlp()                     # K = 4, w: (6, 3)
    eng = acq.FusedEngine(apply_fn, cparams, 0.3, impl="xla")
    store = WeightStore(2)
    w0 = np.full((6, 3), 2.0, np.float32)
    w1 = np.full((6, 3), 5.0, np.float32)
    store.publish(0, {"w": jnp.asarray(w0)})
    assert eng.refresh_from(store) == 0               # member 1 not published
    store.publish(1, {"w": jnp.asarray(w1)})
    assert eng.refresh_from(store) == 1
    assert eng.size == 4                              # K preserved
    got = np.asarray(jax.tree.leaves(eng.cparams)[0])
    np.testing.assert_array_equal(got[0], w0)
    np.testing.assert_array_equal(got[1], w1)
    np.testing.assert_array_equal(got[2], w0)         # 2 % 2 == 0
    np.testing.assert_array_equal(got[3], w1)
    assert eng.refresh_from(store) == 0               # nothing newer


def test_pool_with_override_forces_legacy_engine():
    """predict_all_override puts the user in control of raw predictions, so
    the factory must route it through the legacy backend — and the pool
    itself refuses a fused engine that would bypass the override."""
    from repro.configs.pal_potential import PALRunConfig

    _, cparams, apply_fn = _mlp()
    pool = PredictionPool([], None,
                          predict_all_override=lambda xs: np.zeros(
                              (4, len(xs), 3)))
    with pytest.raises(ValueError):
        pool.engine = acq.FusedEngine(apply_fn, cparams, 0.3, impl="xla")
    eng = acq.make_engine(
        PALRunConfig(std_threshold=0.3),
        committee=acq.CommitteeSpec(apply_fn, cparams),
        predict_all=pool.predict_all, force_legacy=True)
    assert isinstance(eng, acq.LegacyEngine)
    pool.engine = eng
    uq = pool.predict_uq([np.zeros(6, np.float32)])
    assert uq.mean.shape == (1, 3)
    assert not uq.mask.any()                   # zero preds -> zero std
    assert pool.predict_all([np.zeros(6, np.float32)]).shape == (4, 1, 3)


def test_weight_store_roundtrip_through_update():
    tree = {"a": jnp.asarray(np.random.RandomState(0)
                             .randn(2, 5).astype(np.float32))}
    store = WeightStore(1)
    store.publish(0, tree)
    out, _ = store.pull(0, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
