"""Serving engine behaviour + a true 512-device dry-run smoke test run in a
subprocess (XLA_FLAGS must be set before jax init, so it cannot run
in-process with the rest of the suite)."""
import json
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from conftest import tiny_config
from repro.models.model_zoo import build_model
from repro.serving import ServeEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_serve_engine_greedy_deterministic():
    cfg = tiny_config("dense")
    m = build_model(cfg, max_seq=48)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, max_seq=48, batch=2)
    batch = {"tokens": np.ones((2, 16), np.int32) * 5}
    r1 = eng.generate(batch, max_new_tokens=8)
    r2 = eng.generate(batch, max_new_tokens=8)
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    assert r1.tokens.shape == (2, 24)


def test_serve_engine_temperature_sampling_varies():
    cfg = tiny_config("dense")
    m = build_model(cfg, max_seq=48)
    params = m.init(jax.random.PRNGKey(0))
    e1 = ServeEngine(m, params, max_seq=48, batch=2, temperature=1.5, seed=1)
    e2 = ServeEngine(m, params, max_seq=48, batch=2, temperature=1.5, seed=2)
    batch = {"tokens": np.ones((2, 16), np.int32)}
    t1 = e1.generate(batch, max_new_tokens=12).tokens
    t2 = e2.generate(batch, max_new_tokens=12).tokens
    assert not np.array_equal(t1, t2)


def test_serve_engine_matches_decode_consistency():
    """Greedy engine tokens equal manual prefill+decode loop."""
    cfg = tiny_config("rwkv6")
    m = build_model(cfg, max_seq=40)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, max_seq=40, batch=1)
    batch = {"tokens": np.arange(8, dtype=np.int32)[None] % cfg.vocab_size}
    res = eng.generate(batch, max_new_tokens=4)

    import jax.numpy as jnp
    cache = m.init_cache(1, 40)
    last, cache = m.prefill(params, jnp.asarray(batch["tokens"]), cache)
    toks = [int(jnp.argmax(last, -1)[0])]
    for i in range(3):
        nxt = jnp.asarray([[toks[-1]]], jnp.int32)
        logits, cache = m.decode_step(params, nxt, cache, jnp.int32(8 + i))
        toks.append(int(jnp.argmax(logits, -1)[0]))
    np.testing.assert_array_equal(res.tokens[0, 8:], np.asarray(toks))


# ---------------------------------------------------------------------------
# 512-device dry-run smoke (subprocess)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_dryrun_subprocess_single_cell():
    """Full production-mesh lower+compile for one cheap cell proves the
    512-virtual-device path end to end."""
    out = tempfile.mkdtemp()
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "llama3.2-1b", "--shape", "decode_32k", "--out", out],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert proc.returncode == 0, proc.stderr[-2000:]
    rep = json.load(open(os.path.join(
        out, "llama3.2-1b_decode_32k_singlepod.json")))
    assert rep.get("compiled") is True
    assert rep["mesh"] == {"data": 16, "model": 16}
    assert rep["resident_gib_per_device"] > 0


def test_make_production_mesh_shapes():
    """Mesh factory axes/shape contract (uses a 1-device stub check only —
    real 512-dev construction is exercised in the subprocess test)."""
    from repro.launch import mesh as mesh_mod
    import inspect
    src = inspect.getsource(mesh_mod.make_production_mesh)
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '"pod", "data", "model"' in src
