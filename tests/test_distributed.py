"""launch/distributed: multi-process launch path.

Config plumbing is tested in-process; the real thing — two OS processes
joining one jax runtime over the gloo CPU collectives backend and
computing a cross-process collective — runs as a subprocess pair (the
same smoke the CI ``mesh`` job requires to pass).
"""
from __future__ import annotations

import os
import socket
import subprocess
import sys
from types import SimpleNamespace

import pytest

from repro.launch import distributed as dist

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_noop_without_coordinator():
    assert dist.initialize_from_config(
        SimpleNamespace(dist_coordinator="")) is False
    assert not dist.is_initialized()


def test_requires_process_count():
    cfg = SimpleNamespace(dist_coordinator="127.0.0.1:9", dist_processes=0)
    with pytest.raises(ValueError, match="dist_processes"):
        dist.initialize_from_config(cfg)


def test_requires_process_id(monkeypatch):
    monkeypatch.delenv("PAL_PROCESS_ID", raising=False)
    monkeypatch.delenv("JAX_PROCESS_ID", raising=False)
    cfg = SimpleNamespace(dist_coordinator="127.0.0.1:9", dist_processes=2,
                          dist_process_id=-1)
    with pytest.raises(ValueError, match="PAL_PROCESS_ID"):
        dist.initialize_from_config(cfg)


def test_env_process_id(monkeypatch):
    monkeypatch.delenv("PAL_PROCESS_ID", raising=False)
    monkeypatch.delenv("JAX_PROCESS_ID", raising=False)
    assert dist._env_process_id() == -1
    monkeypatch.setenv("JAX_PROCESS_ID", "4")
    assert dist._env_process_id() == 4
    monkeypatch.setenv("PAL_PROCESS_ID", "2")     # PAL_ wins
    assert dist._env_process_id() == 2


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def test_two_process_smoke():
    """Two ranks, one coordinator, one cross-process collective: each
    process must see 2 global devices and both must print the same global
    sum (rows_per_process=4 x 2 ranks x 1 device -> sum(arange(8)) = 28)."""
    port = _free_port()
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.launch.distributed",
             "--coordinator", f"127.0.0.1:{port}",
             "--processes", "2", "--process-id", str(i), "--demo"],
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env, cwd=REPO)
        for i in range(2)
    ]
    try:
        outs = [p.communicate(timeout=240) for p in procs]
    except subprocess.TimeoutExpired:
        for p in procs:
            p.kill()
        pytest.fail("distributed smoke timed out")
    for p, (out, err) in zip(procs, outs):
        assert p.returncode == 0, f"rank failed:\n{out}\n{err}"
        assert "DIST_OK 2 2 28.0" in out, f"unexpected output:\n{out}\n{err}"
