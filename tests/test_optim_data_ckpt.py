"""Substrate tests: AdamW (+int8 moments), schedules, synthetic data
determinism/sharding, prefetcher, checkpoints (atomicity, retention,
resume)."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback keeps tier-1 green
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import AsyncCheckpointer, load_checkpoint, save_checkpoint
from repro.checkpoint.pytree_ckpt import latest_step, list_steps
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.prefetch import Prefetcher
from repro.data.replay import ALReplayBuffer
from repro.data.synthetic import SyntheticTokenStream, synthetic_batch
from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               clip_by_global_norm, dequantize, global_norm,
                               quantize)
from repro.optim.schedule import make_schedule

# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def _quadratic_converges(quantized: bool) -> float:
    target = jnp.asarray(np.random.RandomState(0).randn(8, 8), jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    state = adamw_init(params, quantized=quantized)
    cfg = AdamWConfig(weight_decay=0.0, quantized=quantized)

    @jax.jit
    def step(params, state):
        grads = jax.grad(lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        return adamw_update(grads, state, params, jnp.float32(0.05), cfg)

    for _ in range(300):
        params, state = step(params, state)
    return float(jnp.mean((params["w"] - target) ** 2))


def test_adamw_converges_quadratic():
    assert _quadratic_converges(False) < 1e-3


def test_adamw_int8_moments_converge():
    assert _quadratic_converges(True) < 5e-2


@given(st.integers(0, 10000))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_bounded_error(seed):
    rng = np.random.RandomState(seed)
    shape = tuple(rng.randint(1, 9, size=rng.randint(1, 4)))
    x = jnp.asarray(rng.randn(*shape).astype(np.float32) * 10 ** rng.randint(
        -3, 3))
    t = quantize(x)
    assert t.q.shape == x.shape
    y = dequantize(t)
    scale = float(jnp.max(jnp.abs(x))) + 1e-12
    assert float(jnp.max(jnp.abs(x - y))) <= scale / 127.0 + 1e-9


def test_clip_by_global_norm():
    grads = {"a": jnp.ones(4) * 10}
    clipped, gn = clip_by_global_norm(grads, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below threshold: untouched
    small = {"a": jnp.ones(4) * 0.01}
    out, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.01, rtol=1e-6)


def test_wsd_schedule_shape():
    fn = make_schedule("wsd", 1.0, warmup_steps=10, decay_steps=100,
                       stable_steps=50, min_lr_ratio=0.1)
    assert float(fn(jnp.int32(0))) == 0.0
    assert float(fn(jnp.int32(10))) == pytest.approx(1.0)
    assert float(fn(jnp.int32(40))) == pytest.approx(1.0)      # stable
    assert float(fn(jnp.int32(100))) == pytest.approx(0.1)     # decayed
    mid = float(fn(jnp.int32(80)))
    assert 0.1 < mid < 1.0                                     # linear decay


def test_cosine_schedule_endpoints():
    fn = make_schedule("cosine", 2.0, warmup_steps=5, decay_steps=50,
                       min_lr_ratio=0.05)
    assert float(fn(jnp.int32(5))) == pytest.approx(2.0, rel=1e-3)
    assert float(fn(jnp.int32(50))) == pytest.approx(0.1, rel=1e-3)


# ---------------------------------------------------------------------------
# synthetic data
# ---------------------------------------------------------------------------

CFG = ModelConfig(name="t", family="dense", num_layers=1, d_model=32,
                  num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=1000)
SHAPE = ShapeConfig("s", 16, 8, "train")


def test_synthetic_batch_deterministic():
    a = synthetic_batch(CFG, SHAPE, step=7, seed=3)
    b = synthetic_batch(CFG, SHAPE, step=7, seed=3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(CFG, SHAPE, step=8, seed=3)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_synthetic_labels_are_shifted_tokens():
    b = synthetic_batch(CFG, SHAPE, step=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_dp_shards_partition_global_batch():
    full = synthetic_batch(CFG, SHAPE, step=0, dp_rank=0, dp_size=1)
    parts = [synthetic_batch(CFG, SHAPE, step=0, dp_rank=r, dp_size=4)
             for r in range(4)]
    stacked = np.concatenate([p["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(full["tokens"], stacked)


def test_stream_resume_bit_exact():
    s1 = SyntheticTokenStream(CFG, SHAPE, seed=1)
    batches = [next(s1) for _ in range(5)]
    state = s1.state_dict()
    s2 = SyntheticTokenStream(CFG, SHAPE)
    s2.load_state_dict(state)
    np.testing.assert_array_equal(next(s1)["tokens"], next(s2)["tokens"])


def test_tokens_within_vocab():
    b = synthetic_batch(CFG, SHAPE, step=0)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < CFG.vocab_size


def test_prefetcher_preserves_order_and_surfaces_errors():
    it = Prefetcher(iter(range(10)), depth=2)
    assert list(it) == list(range(10))

    def bad():
        yield 1
        raise RuntimeError("boom")

    it2 = Prefetcher(bad(), depth=2)
    assert next(it2) == 1
    with pytest.raises(RuntimeError):
        next(it2)


def test_replay_buffer_sampling_and_eviction():
    buf = ALReplayBuffer(capacity=4, seq_len=8)
    buf.add([np.arange(10) + i for i in range(6)])
    assert len(buf) == 4 and buf.evicted == 2
    batch = buf.sample(3, np.random.RandomState(0))
    assert batch["tokens"].shape == (3, 8)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])


# ---------------------------------------------------------------------------
# checkpoints
# ---------------------------------------------------------------------------


def test_checkpoint_save_load_roundtrip():
    tmp = tempfile.mkdtemp()
    tree = {"w": jnp.arange(6).reshape(2, 3), "s": jnp.float32(2.5)}
    save_checkpoint(tmp, 5, tree, extra={"note": "x"})
    snap = load_checkpoint(tmp)
    assert snap["step"] == 5
    np.testing.assert_array_equal(snap["tree"]["w"], np.arange(6).reshape(2, 3))
    assert snap["extra"]["note"] == "x"


def test_checkpoint_retention_keeps_newest():
    tmp = tempfile.mkdtemp()
    ck = AsyncCheckpointer(tmp, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.ones(2) * s})
    ck.wait()
    assert list_steps(tmp) == [3, 4]
    assert latest_step(tmp) == 4


def test_checkpoint_no_partial_files_visible():
    tmp = tempfile.mkdtemp()
    save_checkpoint(tmp, 1, {"x": jnp.ones(3)})
    files = os.listdir(tmp)
    assert all(not f.startswith(".tmp_") for f in files)


def test_async_checkpointer_resume():
    tmp = tempfile.mkdtemp()
    ck = AsyncCheckpointer(tmp)
    ck.save(7, {"x": jnp.ones(2) * 7})
    snap = ck.restore_latest()
    assert snap["step"] == 7
    np.testing.assert_array_equal(snap["tree"]["x"], [7.0, 7.0])


def test_async_checkpointer_surfaces_worker_errors(monkeypatch):
    tmp = tempfile.mkdtemp()
    ck = AsyncCheckpointer(tmp)
    import repro.checkpoint.pytree_ckpt as mod

    def bomb(*a, **k):
        raise IOError("disk full")

    monkeypatch.setattr(mod, "save_checkpoint", bomb)
    ck.save(1, {"x": jnp.ones(1)})
    with pytest.raises(IOError):
        ck.wait()
