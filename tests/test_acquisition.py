"""Unified acquisition engine tests: backend parity (fused Pallas /
fused XLA / per-member legacy produce identical SelectionResults — incl.
flag_value, patience restarts, and the component-std path), device-side
rules vs their host equivalents (top_fraction, diversity_filter), the
config-driven factory, and the Manager consuming UQResult for
dynamic_oracle_list."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import acquisition as acq
from repro.core import committee as cmte
from repro.core import selection as sel
from repro.core.buffers import OracleInputBuffer, TrainingDataBuffer
from repro.core.controller import Manager, ManagerConfig


K, IN_DIM, OUT_DIM = 5, 6, 3


def _committee(seed=0):
    rng = np.random.RandomState(seed)
    members = [{"w": jnp.asarray(rng.randn(IN_DIM, OUT_DIM)
                                 .astype(np.float32) * 0.5)}
               for _ in range(K)]
    return members, cmte.stack_members(members), (lambda p, x: x @ p["w"])


def _predict_all(members):
    def predict_all(xs):
        x = np.stack([np.asarray(v, np.float32) for v in xs])
        return np.stack([x @ np.asarray(m["w"]) for m in members])
    return predict_all


def _inputs(n, seed=1):
    rng = np.random.RandomState(seed)
    return [rng.randn(IN_DIM).astype(np.float32) for _ in range(n)]


def _safe_threshold(scores):
    """A threshold in the widest gap of the score distribution, so fp32
    device statistics and fp64 host statistics cannot disagree on the
    selection near the boundary."""
    s = np.sort(np.asarray(scores, dtype=np.float64))
    gaps = np.diff(s)
    i = int(np.argmax(gaps))
    return float((s[i] + s[i + 1]) / 2.0)


def _engines(members, cparams, apply_fn, threshold, rules=None):
    return {
        "fused_xla": acq.FusedEngine(apply_fn, cparams, threshold,
                                     rules=rules, impl="xla"),
        "fused_pallas": acq.FusedEngine(apply_fn, cparams, threshold,
                                        rules=rules, impl="pallas_interpret"),
        "legacy": acq.LegacyEngine(_predict_all(members), threshold,
                                   rules=rules),
    }


# ---------------------------------------------------------------------------
# backend parity
# ---------------------------------------------------------------------------


def test_backends_produce_identical_selection_results():
    members, cparams, apply_fn = _committee()
    inputs = _inputs(13)
    probe = acq.LegacyEngine(_predict_all(members), 0.0).score(inputs)
    t = _safe_threshold(probe.scalar_std)

    results = {}
    for name, eng in _engines(members, cparams, apply_fn, t).items():
        uq = eng.score(inputs)
        results[name] = (uq, sel.selection_from_uq(inputs, uq))
    ref_uq, ref_res = results["legacy"]
    assert ref_res.uncertain_mask.any() and not ref_res.uncertain_mask.all()
    for name, (uq, res) in results.items():
        np.testing.assert_array_equal(res.uncertain_mask,
                                      ref_res.uncertain_mask, err_msg=name)
        np.testing.assert_allclose(uq.scalar_std, ref_uq.scalar_std,
                                   rtol=1e-4, atol=1e-5, err_msg=name)
        np.testing.assert_allclose(uq.component_std, ref_uq.component_std,
                                   rtol=1e-4, atol=1e-5, err_msg=name)
        assert len(res.inputs_to_oracle) == len(ref_res.inputs_to_oracle)
        for a, b in zip(res.inputs_to_oracle, ref_res.inputs_to_oracle):
            np.testing.assert_array_equal(a, b, err_msg=name)
        for a, b in zip(res.data_to_generators, ref_res.data_to_generators):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5,
                                       err_msg=name)


def test_backends_agree_on_flag_value():
    members, cparams, apply_fn = _committee(seed=3)
    inputs = _inputs(9, seed=4)
    probe = acq.LegacyEngine(_predict_all(members), 0.0).score(inputs)
    t = _safe_threshold(probe.scalar_std)
    flagged = {}
    for name, eng in _engines(members, cparams, apply_fn, t).items():
        res = sel.selection_from_uq(inputs, eng.score(inputs),
                                    flag_value=0.0)
        flagged[name] = res
    ref = flagged["legacy"]
    assert ref.uncertain_mask.any()
    for name, res in flagged.items():
        for i, (a, b) in enumerate(zip(res.data_to_generators,
                                       ref.data_to_generators)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5,
                                       err_msg=f"{name}[{i}]")
        # flagged rows are exactly the selected rows, zeroed
        for i in np.where(ref.uncertain_mask)[0]:
            np.testing.assert_array_equal(res.data_to_generators[i], 0.0)


def test_backends_agree_on_patience_restarts():
    """Same committee, same deterministic generator stream -> identical
    restart schedule under every backend."""
    members, cparams, apply_fn = _committee(seed=5)
    inputs_stream = [_inputs(6, seed=100 + s) for s in range(10)]
    all_scores = np.concatenate([
        acq.LegacyEngine(_predict_all(members), 0.0).score(b).scalar_std
        for b in inputs_stream])
    t = float(np.median(all_scores))        # roughly half uncertain per step

    schedules = {}
    for name, eng in _engines(members, cparams, apply_fn, t).items():
        tracker = sel.PatienceTracker(6, patience=1)
        restarts = []
        for batch in inputs_stream:
            res = sel.selection_from_uq(batch, eng.score(batch))
            restarts.append(tracker.step(res.uncertain_mask).copy())
        schedules[name] = (np.stack(restarts), tracker.restarts.copy())
    ref_sched, ref_counts = schedules["legacy"]
    assert ref_counts.sum() > 0             # the schedule actually restarts
    for name, (sched, counts) in schedules.items():
        np.testing.assert_array_equal(sched, ref_sched, err_msg=name)
        np.testing.assert_array_equal(counts, ref_counts, err_msg=name)


# ---------------------------------------------------------------------------
# device rules vs host equivalents
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fraction", [0.0, 0.1, 0.25, 0.3, 0.5, 0.7, 0.9,
                                      1.0])
def test_top_fraction_rule_matches_host(fraction):
    members, cparams, apply_fn = _committee(seed=6)
    inputs = _inputs(16, seed=7)
    rules = (acq.TopFractionRule(fraction),)
    host_uq = acq.LegacyEngine(_predict_all(members), 0.0).score(inputs)
    want = np.zeros(len(inputs), bool)
    want[sel.top_fraction(host_uq.scalar_std, fraction)] = True
    for name, eng in _engines(members, cparams, apply_fn, 0.0,
                              rules=rules).items():
        got = eng.score(inputs).mask
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_top_fraction_rule_invariant_to_bucket_padding():
    """k is computed from the TRUE n (traced scalar), not the padded
    bucket, and padding rows are never selected."""
    members, cparams, apply_fn = _committee(seed=8)
    eng = acq.FusedEngine(apply_fn, cparams, 0.0,
                          rules=(acq.TopFractionRule(0.5),), impl="xla",
                          min_bucket=32)          # heavy padding for n=6
    inputs = _inputs(6, seed=9)
    mask = eng.score(inputs).mask
    assert mask.shape == (6,)
    assert mask.sum() == 3                         # round(0.5 * 6)


def test_diversity_rule_matches_host_filter():
    members, cparams, apply_fn = _committee(seed=10)
    rng = np.random.RandomState(11)
    # clustered inputs so the min_dist filter actually bites
    centers = rng.randn(4, IN_DIM) * 2.0
    inputs = [np.asarray(centers[i % 4] + rng.randn(IN_DIM) * 1e-3,
                         np.float32) for i in range(12)]
    min_dist = 0.5
    host_uq = acq.LegacyEngine(_predict_all(members), 0.0).score(inputs)
    # host equivalent: visit candidates in descending-uncertainty order
    order = np.argsort(-host_uq.scalar_std, kind="stable")
    kept = sel.diversity_filter(inputs, order, min_dist)
    want = np.zeros(len(inputs), bool)
    want[kept] = True
    rules = (acq.DiversityRule(min_dist),)
    for name, eng in _engines(members, cparams, apply_fn, 0.0,
                              rules=rules).items():
        got = eng.score(inputs).mask
        np.testing.assert_array_equal(got, want, err_msg=name)
    assert 0 < want.sum() < len(inputs)            # the filter did something


def test_diversity_rule_accurate_for_large_norm_inputs():
    """Distances come from direct differences, not the fp32 Gram identity —
    large-offset inputs (e.g. MD coordinates far from the origin) must not
    flip keep/drop decisions near min_dist."""
    members, cparams, apply_fn = _committee(seed=20)
    rng = np.random.RandomState(21)
    offset = np.full(IN_DIM, 1000.0, np.float32)
    # pairs at true distance ~0.7 (> min_dist) and ~0.05 (< min_dist)
    base = [offset + rng.randn(IN_DIM).astype(np.float32) * 5.0
            for _ in range(5)]
    inputs = []
    for b in base:
        inputs.append(b)
        inputs.append((b + 0.7 / np.sqrt(IN_DIM)).astype(np.float32))
        inputs.append((b + 0.05 / np.sqrt(IN_DIM)).astype(np.float32))
    min_dist = 0.5
    host_uq = acq.LegacyEngine(_predict_all(members), 0.0).score(inputs)
    order = np.argsort(-host_uq.scalar_std, kind="stable")
    want = np.zeros(len(inputs), bool)
    want[sel.diversity_filter(inputs, order, min_dist)] = True
    for name, eng in _engines(members, cparams, apply_fn, 0.0,
                              rules=(acq.DiversityRule(min_dist),)).items():
        np.testing.assert_array_equal(eng.score(inputs).mask, want,
                                      err_msg=name)
    assert 0 < want.sum() < len(inputs)


@pytest.mark.parametrize("n,fraction", [
    (5, 0.1),         # fp32 0.1*5 = 0.50000000745; host round(0.5) = 0
    (5, 0.3),         # 1.5 rounds half-to-even -> 2 on both sides
    (15, 0.1),        # 1.5 again, via an inexact fraction
    (5, 0.5),         # exact half from an exact fraction: 2.5 -> 2
    (45, 0.7),        # fp32 lands ON 31.5, float64 just below -> 31
    (75, 0.14),       # fp32 just below a half, float64 just above -> 11
    (90, 0.35),       # 31.5-boundary, float64 below -> 31
    (100, 0.545),     # 54.5-boundary, float64 above -> 55
])
def test_top_fraction_rule_k_matches_host_round(n, fraction):
    """k == int(round(n * fraction)) exactly for ANY (n, fraction) — the
    device rule precomputes the host's float64 rounding at trace time, so
    fp32 representation error can never flip a .5 boundary."""
    members, cparams, apply_fn = _committee(seed=24)
    inputs = _inputs(n, seed=25)
    want_k = len(sel.top_fraction(np.arange(n, dtype=float), fraction))
    assert want_k == int(round(n * fraction))
    for name, eng in _engines(members, cparams, apply_fn, 0.0,
                              rules=(acq.TopFractionRule(fraction),)).items():
        assert int(eng.score(inputs).mask.sum()) == want_k, (name, fraction)


def test_top_fraction_rule_exact_count_under_ties():
    """Duplicate proposals (identical scores) must not push the selection
    over the round(fraction * n) cap — the rule is an exact top-k."""
    members, cparams, apply_fn = _committee(seed=22)
    one = np.random.RandomState(23).randn(IN_DIM).astype(np.float32)
    inputs = [one.copy() for _ in range(8)]       # all scores exactly equal
    for name, eng in _engines(members, cparams, apply_fn, 0.0,
                              rules=(acq.TopFractionRule(0.5),)).items():
        mask = eng.score(inputs).mask
        assert mask.sum() == 4, (name, mask)
        # deterministic tie-break toward the lower index
        np.testing.assert_array_equal(
            mask, np.arange(8) < 4, err_msg=name)


def test_threshold_rule_preserves_float64_on_host():
    """The legacy backend thresholds in float64 (seed prediction_check
    semantics) — the rule must not force a jnp fp32 downcast that merges
    near-threshold values."""
    sstd = np.array([0.25 + 1e-10, 0.25 - 1e-10], dtype=np.float64)
    stats = acq.UQStats(x=None, mean=None, scalar_std=sstd,
                        component_std=None, valid=np.ones(2, bool),
                        n_valid=2)
    mask = np.asarray(acq.ThresholdRule(0.25).apply(stats,
                                                    np.ones(2, bool)))
    assert list(mask) == [True, False]


def test_fused_engine_concurrent_first_score_traces_once():
    """Exchange and Manager threads share one engine: a fresh shape bucket
    hit from both sides concurrently must still compile exactly once."""
    import threading

    members, cparams, apply_fn = _committee(seed=30)
    eng = acq.FusedEngine(apply_fn, cparams, 0.1, impl="xla")
    barrier = threading.Barrier(2)
    errors = []

    def worker(seed):
        try:
            barrier.wait()
            for _ in range(5):
                eng.score(_inputs(7, seed=seed))
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert eng.trace_counts == {8: 1}


def test_legacy_engine_skips_input_stack_without_diversity_rule():
    members, _, _ = _committee(seed=31)
    seen = {}

    class Probe(acq.SelectionRule):
        def apply(self, stats, mask):
            seen["x"] = stats.x
            return mask

    acq.LegacyEngine(_predict_all(members), 0.1,
                     rules=(acq.ThresholdRule(0.1), Probe())
                     ).score(_inputs(4))
    assert seen["x"] is None                   # nothing declared needs_inputs
    acq.LegacyEngine(_predict_all(members), 0.1,
                     rules=(acq.DiversityRule(0.1), Probe())
                     ).score(_inputs(4))
    assert seen["x"] is not None and seen["x"].shape == (4, IN_DIM)


def test_rule_pipeline_composes_and_stays_single_trace():
    """threshold -> top-fraction -> diversity, all inside one compiled
    dispatch, one trace per bucket even as n varies."""
    members, cparams, apply_fn = _committee(seed=12)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.0,
        rules=(acq.ThresholdRule(0.0), acq.TopFractionRule(0.75),
               acq.DiversityRule(0.05)),
        impl="xla")
    for n in (5, 8, 3, 7):
        uq = eng.score(_inputs(n, seed=n))
        assert uq.mask.shape == (n,)
    assert eng.trace_counts == {8: 1}


# ---------------------------------------------------------------------------
# config-driven factory
# ---------------------------------------------------------------------------


def test_make_engine_auto_picks_fused_with_committee():
    members, cparams, apply_fn = _committee()
    cfg = PALRunConfig(std_threshold=0.3)
    eng = acq.make_engine(cfg,
                          committee=acq.CommitteeSpec(apply_fn, cparams))
    assert isinstance(eng, acq.FusedEngine)
    assert eng.impl == "xla" and not eng.uses_models


def test_make_engine_auto_falls_back_to_legacy():
    members, _, _ = _committee()
    cfg = PALRunConfig(std_threshold=0.3)
    eng = acq.make_engine(cfg, predict_all=_predict_all(members))
    assert isinstance(eng, acq.LegacyEngine) and eng.uses_models


def test_make_engine_honors_knobs():
    members, cparams, apply_fn = _committee()
    cfg = PALRunConfig(std_threshold=0.3, uq_impl="pallas_interpret",
                       uq_block_n=64, uq_bucket=16)
    eng = acq.make_engine(cfg,
                          committee=acq.CommitteeSpec(apply_fn, cparams))
    assert isinstance(eng, acq.FusedEngine)
    assert eng.impl == "pallas_interpret"
    assert eng.block_n == 64 and eng.min_bucket == 16
    uq = eng.score(_inputs(3))
    assert uq.mask.shape == (3,)
    assert eng.trace_counts == {16: 1}             # floored at uq_bucket


def test_make_engine_fused_impl_requires_committee():
    cfg = PALRunConfig(uq_impl="pallas")
    with pytest.raises(ValueError):
        acq.make_engine(cfg, predict_all=lambda xs: np.zeros((2, 1, 1)))


def test_make_engine_force_legacy_overrides_committee():
    members, cparams, apply_fn = _committee()
    cfg = PALRunConfig(uq_impl="xla")
    eng = acq.make_engine(cfg,
                          committee=acq.CommitteeSpec(apply_fn, cparams),
                          predict_all=_predict_all(members),
                          force_legacy=True)
    assert isinstance(eng, acq.LegacyEngine)


# ---------------------------------------------------------------------------
# oracle re-prioritization on UQResult (dynamic_oracle_list)
# ---------------------------------------------------------------------------


def test_adjust_input_for_oracle_uq_matches_stacked_port():
    rng = np.random.RandomState(13)
    buf = [rng.randn(IN_DIM) for _ in range(9)]
    preds = rng.randn(K, 9, OUT_DIM)
    std = preds.std(axis=0, ddof=1)
    t = _safe_threshold(std.max(axis=-1))
    want = sel.adjust_input_for_oracle(buf, preds, t)
    uq = acq.UQResult(preds.mean(axis=0), std.max(axis=-1),
                      std.mean(axis=-1), std.max(axis=-1) > t)
    got = sel.adjust_input_for_oracle_uq(buf, uq, t)
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(a, b)


def test_manager_drops_stale_entries_at_threshold():
    """Satellite fix: ManagerConfig.std_threshold is actually used — stale
    entries whose fresh committee std fell below it are DROPPED, not just
    reordered (the old hard-coded 0.0 never dropped anything)."""
    obuf = OracleInputBuffer()
    tbuf = TrainingDataBuffer(retrain_size=1)
    items = [np.full(2, float(i)) for i in range(4)]
    obuf.put(items)
    # fresh committee: items 0 and 2 confidently predicted now, 1 and 3 not
    scalar_std = np.array([0.01, 0.9, 0.02, 0.5])
    comp_std = scalar_std / 2

    def fresh_score(xs):
        return acq.UQResult(np.zeros((len(xs), 1)), scalar_std, comp_std,
                            scalar_std > 0.1)

    mgr = Manager(obuf, tbuf, [], ManagerConfig(std_threshold=0.1),
                  fresh_score=fresh_score)
    mgr.step(retrain_completions=1)
    left = obuf.snapshot()
    assert [int(x[0]) for x in left] == [1, 3]     # sorted by std desc
    assert mgr.monitor.count("manager.buffer_adjusts") == 1


def test_exchange_with_custom_rule_stays_single_dispatch():
    """Acceptance: a user rule (top-fraction) runs through the fused path —
    exchange.step() never materializes a (K, n_gen, out_dim) host tensor
    (the engine's device->host traffic is exactly the four small UQ
    arrays), and the manager's dynamic_oracle_list consumes the same
    engine without ever calling the pool's stacked-prediction path."""
    from repro.core.controller import (Exchange, ExchangeConfig,
                                       PredictionPool)

    members, cparams, apply_fn = _committee(seed=14)
    eng = acq.FusedEngine(
        apply_fn, cparams, 0.0,
        rules=(acq.ThresholdRule(0.0), acq.TopFractionRule(0.5)),
        impl="xla", min_bucket=8)

    class Gene:
        def __init__(self, rank):
            self.rng = np.random.RandomState(rank)

        def generate_new_data(self, data_to_gene):
            return False, self.rng.randn(IN_DIM).astype(np.float32)

        def save_progress(self):
            pass

    n_gen = 6
    pool = PredictionPool([], None, engine=eng)
    obuf = OracleInputBuffer()
    ex = Exchange([Gene(i) for i in range(n_gen)], pool, obuf,
                  ExchangeConfig(std_threshold=0.0, patience=10))
    steps = 4
    for _ in range(steps):
        ex.step()
    # top-fraction cap: exactly round(0.5 * 6) = 3 queued per step
    assert len(obuf) == 3 * steps
    # device->host bytes per step == the padded (mean, sstd, cstd, mask,
    # finite_members) arrays only: nb*(d*4 + 4 + 4 + 1 + 4) — nothing
    # K-sized ever crosses
    nb = 8
    expected = steps * nb * (OUT_DIM * 4 + 4 + 4 + 1 + 4)
    assert eng.bytes_to_host == expected
    # dynamic_oracle_list on the SAME engine: stacked predict_all must
    # never be touched (the pool has no members — it would raise)
    tbuf = TrainingDataBuffer(retrain_size=1)
    mgr = Manager(obuf, tbuf, [], ManagerConfig(std_threshold=0.0),
                  fresh_score=lambda xs: eng.score(xs))
    mgr.step(retrain_completions=1)
    assert mgr.monitor.count("manager.buffer_adjusts") == 1
    with pytest.raises(RuntimeError):
        pool.predict_all([np.zeros(IN_DIM, np.float32)])


def test_manager_adjust_keeps_items_enqueued_during_scoring():
    """Items the Exchange thread enqueues WHILE the manager is re-scoring
    the snapshot must survive the adjust — a blind restore would silently
    drop freshly selected samples (AL data loss)."""
    obuf = OracleInputBuffer()
    tbuf = TrainingDataBuffer(retrain_size=1)
    obuf.put([np.full(2, 0.0), np.full(2, 1.0)])
    scalar_std = np.array([0.9, 0.8])

    def fresh_score(xs):
        # concurrent enqueue mid-scoring (the race window)
        obuf.put([np.full(2, 42.0)])
        return acq.UQResult(np.zeros((len(xs), 1)), scalar_std,
                            scalar_std, scalar_std > 0.1)

    mgr = Manager(obuf, tbuf, [], ManagerConfig(std_threshold=0.1),
                  fresh_score=fresh_score)
    mgr.step(retrain_completions=1)
    left = [float(x[0]) for x in obuf.snapshot()]
    assert left == [0.0, 1.0, 42.0]     # re-scored prefix + fresh suffix


def test_manager_adjust_survives_bounded_buffer_trim():
    """A max_size put-trim during scoring must neither drop the freshly
    enqueued samples nor resurrect the trimmed stale ones — the appended
    suffix is identified by enqueue generation, not list length."""
    obuf = OracleInputBuffer(max_size=3)
    tbuf = TrainingDataBuffer(retrain_size=1)
    obuf.put([np.full(2, 0.0), np.full(2, 1.0), np.full(2, 2.0)])  # full
    scalar_std = np.array([0.9, 0.8, 0.7])

    def fresh_score(xs):
        # concurrent enqueue trims item 0 out (buffer stays at max_size)
        obuf.put([np.full(2, 42.0)])
        assert [float(x[0]) for x in obuf.snapshot()] == [1.0, 2.0, 42.0]
        return acq.UQResult(np.zeros((len(xs), 1)), scalar_std,
                            scalar_std, scalar_std > 0.1)

    mgr = Manager(obuf, tbuf, [], ManagerConfig(std_threshold=0.1),
                  fresh_score=fresh_score)
    mgr.step(retrain_completions=1)
    left = [float(x[0]) for x in obuf.snapshot()]
    # re-scored snapshot [0(.9), 1(.8), 2(.7)] + fresh [42]: overflow
    # evicts the LOWEST-priority re-scored item (2, std .7) — never the
    # most-uncertain head, never the fresh selection
    assert left == [0.0, 1.0, 42.0]


def test_manager_adjust_never_drops_policy_selected_items():
    """Policy consistency: with a custom rule pipeline (e.g. top-fraction),
    items the engine's OWN rules re-selected survive the re-prioritization
    even when their absolute std sits below the manager's drop threshold."""
    obuf = OracleInputBuffer()
    tbuf = TrainingDataBuffer(retrain_size=1)
    obuf.put([np.full(2, float(i)) for i in range(4)])
    # all below the 0.5 drop threshold; a top-fraction policy re-selects
    # the two most uncertain anyway
    scalar_std = np.array([0.30, 0.10, 0.40, 0.20])
    mask = np.zeros(4, bool)
    mask[[2, 0]] = True                         # top-50% by scalar_std

    def fresh_score(xs):
        return acq.UQResult(np.zeros((len(xs), 1)), scalar_std,
                            scalar_std / 2, mask)

    mgr = Manager(obuf, tbuf, [], ManagerConfig(std_threshold=0.5),
                  fresh_score=fresh_score)
    mgr.step(retrain_completions=1)
    left = [int(x[0]) for x in obuf.snapshot()]
    assert left == [2, 0]       # policy picks kept (std-desc), rest dropped


def test_manager_zero_threshold_keeps_any_disagreement():
    obuf = OracleInputBuffer()
    tbuf = TrainingDataBuffer(retrain_size=1)
    obuf.put([np.zeros(2), np.ones(2)])
    scalar_std = np.array([0.3, 0.6])

    def fresh_score(xs):
        return acq.UQResult(np.zeros((len(xs), 1)), scalar_std,
                            scalar_std, scalar_std > 0.0)

    mgr = Manager(obuf, tbuf, [], ManagerConfig(std_threshold=0.0),
                  fresh_score=fresh_score)
    mgr.step(retrain_completions=1)
    assert len(obuf) == 2                          # reordered, none dropped
    assert int(obuf.snapshot()[0][0]) == 1         # highest std first
