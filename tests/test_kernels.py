"""Per-kernel validation: Pallas (interpret=True) and the chunked XLA
schedules against the pure-jnp sequential oracles, swept over shapes,
dtypes, and masking modes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd
from repro.kernels.wkv6 import wkv6


def _rand(key, shape, dtype, lo=None, hi=None):
    if lo is not None:
        return jax.random.uniform(key, shape, jnp.float32, lo, hi).astype(dtype)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,KV,D", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 256, 8, 2, 64),     # GQA 4:1
    (1, 128, 4, 1, 128),    # MQA, head_dim 128
])
@pytest.mark.parametrize("causal,window", [
    (True, None), (True, 64), (False, None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(B, T, H, KV, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (B, T, H, D), dtype)
    k = _rand(ks[1], (B, T, KV, D), dtype)
    v = _rand(ks[2], (B, T, KV, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_decode_kv_len():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, D = 3, 192, 8, 4, 64
    q = _rand(ks[0], (B, 1, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, KV, D), jnp.float32)
    v = _rand(ks[2], (B, S, KV, D), jnp.float32)
    kv_len = jnp.array([50, 192, 1], jnp.int32)
    out = flash_attention(q, k, v, causal=False, kv_len=kv_len, q_offset=191,
                          block_q=1, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False, kv_len=kv_len,
                             q_offset=191)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


def test_flash_attention_sliding_window_decode():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, D, W = 2, 256, 4, 64, 64
    q = _rand(ks[0], (B, 1, H, D), jnp.float32)
    k = _rand(ks[1], (B, S, H, D), jnp.float32)
    v = _rand(ks[2], (B, S, H, D), jnp.float32)
    kv_len = jnp.array([200, 256], jnp.int32)
    out = flash_attention(q, k, v, causal=False, window=W, kv_len=kv_len,
                          q_offset=255, block_q=1, block_k=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False, window=W, kv_len=kv_len,
                             q_offset=255)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


def test_attention_chunked_ref_matches_naive():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, T, H, KV, D = 2, 512, 8, 4, 64
    q = _rand(ks[0], (B, T, H, D), jnp.float32)
    k = _rand(ks[1], (B, T, KV, D), jnp.float32)
    v = _rand(ks[2], (B, T, KV, D), jnp.float32)
    for window in (None, 128):
        got = ref.attention_chunked_ref(q, k, v, causal=True, window=window,
                                        chunk=128)
        want = ref.attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4)


def test_flash_attention_raises_on_untileable():
    q = jnp.zeros((1, 100, 4, 64))
    k = v = jnp.zeros((1, 100, 4, 64))
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,N,chunk", [
    (1, 64, 2, 16, 16),
    (2, 128, 3, 32, 32),
    (1, 96, 1, 64, 32),     # T not a power of two multiple
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_pallas_matches_sequential(B, T, H, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 6)
    r = _rand(ks[0], (B, T, H, N), dtype)
    k = _rand(ks[1], (B, T, H, N), dtype)
    v = _rand(ks[2], (B, T, H, N), dtype)
    w = _rand(ks[3], (B, T, H, N), jnp.float32, lo=0.2, hi=0.999).astype(dtype)
    u = _rand(ks[4], (H, N), jnp.float32)
    s0 = _rand(ks[5], (B, H, N, N), jnp.float32)
    y, S = wkv6(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    y_ref, S_ref = ref.wkv6_ref(r, k, v, w, u, s0)
    atol = 5e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=atol)


def test_wkv6_strong_decay_stable():
    """Strong decay (w -> 0) must not overflow the chunked form."""
    B, T, H, N = 1, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r = _rand(ks[0], (B, T, H, N), jnp.float32)
    k = _rand(ks[1], (B, T, H, N), jnp.float32)
    v = _rand(ks[2], (B, T, H, N), jnp.float32)
    w = jnp.full((B, T, H, N), 1e-4, jnp.float32)
    u = _rand(ks[3], (H, N), jnp.float32)
    y, S = wkv6(r, k, v, w, u, None, chunk=32, interpret=True)
    y_ref, S_ref = ref.wkv6_ref(r, k, v, w, u, None)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-3)


def test_wkv6_state_chaining_equals_full_run():
    """Running two halves with carried state == one full run."""
    B, T, H, N = 2, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    r = _rand(ks[0], (B, T, H, N), jnp.float32)
    k = _rand(ks[1], (B, T, H, N), jnp.float32)
    v = _rand(ks[2], (B, T, H, N), jnp.float32)
    w = _rand(ks[3], (B, T, H, N), jnp.float32, lo=0.3, hi=0.99)
    u = _rand(ks[4], (H, N), jnp.float32)
    y_full, S_full = ref.wkv6_chunked_ref(r, k, v, w, u, None, chunk=32)
    h = T // 2
    y1, S1 = ref.wkv6_chunked_ref(r[:, :h], k[:, :h], v[:, :h], w[:, :h],
                                  u, None, chunk=32)
    y2, S2 = ref.wkv6_chunked_ref(r[:, h:], k[:, h:], v[:, h:], w[:, h:],
                                  u, S1, chunk=32)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(S2), np.asarray(S_full), atol=1e-4)


def test_wkv6_decode_step_matches_scan():
    B, H, N = 2, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    r = _rand(ks[0], (B, 8, H, N), jnp.float32)
    k = _rand(ks[1], (B, 8, H, N), jnp.float32)
    v = _rand(ks[2], (B, 8, H, N), jnp.float32)
    w = _rand(ks[3], (B, 8, H, N), jnp.float32, lo=0.3, hi=0.99)
    u = _rand(ks[4], (H, N), jnp.float32)
    y_ref, _ = ref.wkv6_ref(r, k, v, w, u, None)
    S = jnp.zeros((B, H, N, N))
    ys = []
    for t in range(8):
        y, S = ref.wkv6_decode_ref(r[:, t], k[:, t], v[:, t], w[:, t], u, S)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-4)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_pallas_matches_sequential(B, T, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    x = _rand(ks[0], (B, T, H, P), dtype)
    a = _rand(ks[1], (B, T, H), jnp.float32, lo=0.3, hi=1.0).astype(dtype)
    Bm = _rand(ks[2], (B, T, H, N), dtype)
    Cm = _rand(ks[3], (B, T, H, N), dtype)
    s0 = _rand(ks[4], (B, H, N, P), jnp.float32)
    y, S = ssd(x, a, Bm, Cm, s0, chunk=chunk, interpret=True)
    y_ref, S_ref = ref.ssd_ref(x, a, Bm, Cm, s0)
    atol = 5e-3 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=atol)


def test_ssd_decode_step_matches_scan():
    B, H, P, N = 2, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    x = _rand(ks[0], (B, 8, H, P), jnp.float32)
    a = _rand(ks[1], (B, 8, H), jnp.float32, lo=0.3, hi=1.0)
    Bm = _rand(ks[2], (B, 8, H, N), jnp.float32)
    Cm = _rand(ks[3], (B, 8, H, N), jnp.float32)
    y_ref, _ = ref.ssd_ref(x, a, Bm, Cm, None)
    S = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(8):
        y, S = ref.ssd_decode_ref(x[:, t], a[:, t], Bm[:, t], Cm[:, t], S)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-4)
