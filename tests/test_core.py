"""PAL core unit tests: transport semantics, buffers, selection, committee
packing, weight sync, speedup model — including hypothesis property tests on
the system's invariants."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # optional dep — deterministic fallback keeps tier-1 green
    from _hypothesis_fallback import given, settings, st

from repro.core import committee as cmte
from repro.core import selection as sel
from repro.core import speedup as sp
from repro.core.buffers import (OracleInputBuffer, RollingTrainingBuffer,
                                TrainingDataBuffer)
from repro.core.transport import Channel, Communicator, TransportError
from repro.core.weight_sync import WeightStore

# ---------------------------------------------------------------------------
# transport
# ---------------------------------------------------------------------------


def test_channel_isend_irecv_roundtrip():
    ch = Channel("t")
    req = ch.irecv()
    assert not req.test()
    ch.isend({"x": 1})
    assert req.test()
    assert req.value == {"x": 1}


def test_channel_send_before_recv():
    ch = Channel("t")
    ch.isend(1)
    ch.isend(2)
    assert ch.recv() == 1
    assert ch.recv() == 2


def test_channel_recv_timeout():
    ch = Channel("t")
    with pytest.raises(TimeoutError):
        ch.recv(timeout=0.01)


def test_request_test_mirrors_mpi_capitalization():
    ch = Channel("t")
    req = ch.irecv()
    assert req.Test() is False     # paper code calls req_data.Test()
    ch.isend(None)
    assert req.Test() is True


def test_channel_cross_thread():
    ch = Channel("t")
    out = []

    def consumer():
        out.append(ch.recv(timeout=5))

    th = threading.Thread(target=consumer)
    th.start()
    time.sleep(0.02)
    ch.isend(42)
    th.join()
    assert out == [42]


def test_fixed_size_data_enforced():
    ch = Channel("t", fixed_size=(4,))
    ch.isend(np.zeros(4))
    with pytest.raises(TransportError):
        ch.isend(np.zeros(5))


def test_communicator_gather_scatter_order():
    comm = Communicator()
    srcs = [f"g{i}" for i in range(4)]
    for i, s in enumerate(srcs):
        comm.channel(s, "ctrl").isend(i * 10)
    got = comm.gather(srcs, "ctrl", timeout=1)
    assert got == [0, 10, 20, 30]          # rank-sorted, as the paper requires
    comm.scatter("ctrl", srcs, [i + 1 for i in range(4)])
    for i, s in enumerate(srcs):
        assert comm.channel("ctrl", s).recv(timeout=1) == i + 1
    with pytest.raises(TransportError):
        comm.scatter("ctrl", srcs, [1, 2])


# ---------------------------------------------------------------------------
# buffers
# ---------------------------------------------------------------------------


@given(st.lists(st.integers(), max_size=200),
       st.integers(min_value=1, max_value=50))
@settings(max_examples=50, deadline=None)
def test_training_buffer_releases_exact_blocks(items, retrain_size):
    buf = TrainingDataBuffer(retrain_size)
    for x in items:
        buf.add(x, x)
    released = []
    while buf.ready():
        block = buf.release()
        assert len(block) == retrain_size
        released.extend(block)
    assert len(buf) == len(items) - len(released)
    assert len(buf) < retrain_size


@given(st.lists(st.integers(), min_size=1, max_size=300),
       st.integers(min_value=1, max_value=40))
@settings(max_examples=50, deadline=None)
def test_rolling_buffer_never_exceeds_capacity(xs, cap):
    buf = RollingTrainingBuffer(cap)
    for x in xs:
        buf.extend([np.float64(x)], [np.float64(x)])
        assert len(buf) <= cap
    # newest items survive
    x_arr, _ = buf.arrays()
    want = xs[-min(cap, len(xs)):]
    assert list(x_arr) == [float(w) for w in want]
    assert buf.evicted == max(0, len(xs) - cap)


def test_oracle_buffer_fifo_and_adjust():
    buf = OracleInputBuffer()
    buf.put([1, 2, 3])
    assert buf.pop() == 1
    buf.adjust(lambda items: list(reversed(items)))
    assert buf.pop() == 3
    assert len(buf) == 1


def test_oracle_buffer_bounded_drops_oldest():
    buf = OracleInputBuffer(max_size=3)
    buf.put([1, 2, 3, 4, 5])
    assert buf.snapshot() == [3, 4, 5]
    assert buf.dropped == 2


# ---------------------------------------------------------------------------
# selection (prediction_check & friends)
# ---------------------------------------------------------------------------


def test_prediction_check_selects_above_threshold():
    inputs = [np.array([float(i)]) for i in range(4)]
    # committee of 2: disagree on samples 1 and 3
    preds = np.zeros((2, 4, 2))
    preds[1, 1, 0] = 1.0
    preds[1, 3, 1] = 2.0
    res = sel.prediction_check(inputs, preds, threshold=0.5)
    assert list(res.uncertain_mask) == [False, True, False, True]
    assert len(res.inputs_to_oracle) == 2
    assert (res.inputs_to_oracle[0] == inputs[1]).all()
    # generators receive committee mean
    np.testing.assert_allclose(res.data_to_generators[1],
                               preds[:, 1].mean(axis=0))


@given(st.floats(min_value=0.0, max_value=5.0),
       st.floats(min_value=0.0, max_value=5.0))
@settings(max_examples=30, deadline=None)
def test_prediction_check_threshold_monotonic(t1, t2):
    """Raising the threshold can only shrink the oracle set."""
    rng = np.random.RandomState(0)
    inputs = [rng.randn(3) for _ in range(16)]
    preds = rng.randn(4, 16, 3)
    lo, hi = min(t1, t2), max(t1, t2)
    n_lo = sel.prediction_check(inputs, preds, lo).uncertain_mask.sum()
    n_hi = sel.prediction_check(inputs, preds, hi).uncertain_mask.sum()
    assert n_hi <= n_lo


def test_adjust_input_for_oracle_sorts_and_prunes():
    buf = [np.array([i]) for i in range(3)]
    preds = np.zeros((2, 3, 1))
    preds[1, 0, 0] = 0.1      # small std
    preds[1, 2, 0] = 5.0      # large std
    out = sel.adjust_input_for_oracle(buf, preds, threshold=0.5)
    assert len(out) == 1 and out[0][0] == 2
    out2 = sel.adjust_input_for_oracle(buf, preds, threshold=0.01)
    assert [int(x[0]) for x in out2] == [2, 0]  # sorted by std desc


def test_patience_tracker_restarts_after_budget():
    pt = sel.PatienceTracker(n_generators=2, patience=2)
    m = np.array([True, False])
    assert not pt.step(m).any()
    assert not pt.step(m).any()
    restart = pt.step(m)
    assert list(restart) == [True, False]
    assert pt.counts[0] == 0                  # reset after restart
    assert pt.restarts[0] == 1


def test_diversity_filter_drops_near_duplicates():
    inputs = [np.zeros(2), np.zeros(2) + 0.001, np.ones(2) * 9]
    kept = sel.diversity_filter(inputs, np.array([0, 1, 2]), min_dist=0.1)
    assert list(kept) == [0, 2]


# ---------------------------------------------------------------------------
# committee: packing + UQ
# ---------------------------------------------------------------------------

_tree_strategy = st.fixed_dictionaries({
    "a": st.tuples(st.integers(1, 5), st.integers(1, 5)),
    "b": st.tuples(st.integers(1, 8)),
})


@given(_tree_strategy, st.integers(0, 2 ** 31 - 1))
@settings(max_examples=30, deadline=None)
def test_weight_pack_unpack_roundtrip(shapes, seed):
    rng = np.random.RandomState(seed % 100000)
    tree = {k: jnp.asarray(rng.randn(*shp).astype(np.float32))
            for k, shp in shapes.items()}
    packed = cmte.get_weight(tree)
    assert packed.ndim == 1
    assert packed.size == cmte.get_weight_size(tree)
    out = cmte.update(tree, packed)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(out[k]))


def test_update_rejects_wrong_size():
    tree = {"w": jnp.zeros((2, 2))}
    with pytest.raises(ValueError):
        cmte.update(tree, np.zeros(5, np.float32))


def test_committee_mean_std_ddof1():
    preds = jnp.asarray(np.random.RandomState(0).randn(4, 8, 3))
    mean, std = cmte.mean_std(preds)
    np.testing.assert_allclose(np.asarray(std),
                               np.asarray(preds).std(axis=0, ddof=1),
                               rtol=1e-5)


def test_committee_vmap_equals_member_loop():
    def apply_fn(p, x):
        return x @ p["w"]

    rng = np.random.RandomState(1)
    members = [{"w": jnp.asarray(rng.randn(3, 2).astype(np.float32))}
               for _ in range(4)]
    cparams = cmte.stack_members(members)
    x = jnp.asarray(rng.randn(5, 3).astype(np.float32))
    com = cmte.Committee(apply_fn, cparams, jit=False)
    preds, mean, std = com.predict(x)
    for i, m in enumerate(members):
        np.testing.assert_allclose(np.asarray(preds[i]),
                                   np.asarray(apply_fn(m, x)), rtol=1e-6)


def test_lm_committee_uncertainty_zero_for_identical_members():
    logits = jnp.asarray(np.random.RandomState(0).randn(1, 2, 8, 16))
    clogits = jnp.concatenate([logits, logits], axis=0)
    labels = jnp.zeros((2, 8), jnp.int32)
    mean, std = cmte.lm_committee_uncertainty(clogits, labels)
    np.testing.assert_allclose(np.asarray(std), 0.0, atol=1e-6)


# ---------------------------------------------------------------------------
# weight store
# ---------------------------------------------------------------------------


def test_weight_store_versioning():
    store = WeightStore(2)
    assert store.pull_packed(0) is None
    v1 = store.publish_packed(0, np.arange(4, dtype=np.float32))
    got, v = store.pull_packed(0)
    assert v == v1
    assert store.pull_packed(0, newer_than=v1) is None
    v2 = store.publish_packed(0, np.arange(4, dtype=np.float32) * 2)
    got, v = store.pull_packed(0, newer_than=v1)
    assert v == v2 and got[1] == 2.0


def test_weight_store_pull_all_requires_all_members():
    store = WeightStore(2)
    tree = {"w": jnp.zeros(3)}
    cparams = cmte.stack_members([tree, tree])
    store.publish(0, tree)
    out, v = store.pull_all(cparams)
    assert out is None                     # member 1 never published
    store.publish(1, {"w": jnp.ones(3)})
    out, v = store.pull_all(cparams)
    assert out is not None
    np.testing.assert_allclose(np.asarray(out["w"][1]), 1.0)


# ---------------------------------------------------------------------------
# speedup model (SI S2)
# ---------------------------------------------------------------------------


def test_use_case_1_balanced_dft_gnn_approaches_2():
    w = sp.USE_CASES["dft_gnn"]
    assert sp.speedup(w) == pytest.approx(2.0, abs=0.02)   # Eq. 7


def test_use_case_2_training_bound_approaches_1():
    w = sp.USE_CASES["xtb_reaction"]
    assert sp.speedup(w) == pytest.approx(1.0, abs=0.2)    # Eq. 10
    assert sp.bottleneck(w) == "train"


def test_use_case_3_all_balanced_is_3():
    w = sp.USE_CASES["cfd"]
    assert sp.speedup(w) == pytest.approx(3.0)             # Eq. 13


@given(st.floats(0.01, 1e4), st.floats(0.01, 1e4), st.floats(0.01, 1e4),
       st.integers(1, 64), st.integers(1, 64))
@settings(max_examples=100, deadline=None)
def test_speedup_bounded_between_1_and_3(to, tt, tg, n, p):
    """S = sum/max of three non-negative terms: 1 <= S <= 3 always."""
    if p > n:
        p = n
    w = sp.WorkloadParams(to, tt, tg, n, p)
    s = sp.speedup(w)
    assert 1.0 <= s <= 3.0 + 1e-9


def test_speedup_eq7_formula():
    """Balanced oracle/train with N >= P: S = 1 + P/N (t_gen -> 0)."""
    for n, p in [(16, 16), (32, 8), (64, 16)]:
        w = sp.WorkloadParams(t_oracle=100.0, t_train=(n / p) * 100.0,
                              t_gen=1e-9, n_samples=n, n_workers=p)
        assert sp.speedup(w) == pytest.approx(1.0 + (w.t_train /
                                                     ((n / p) * 100.0)),
                                              rel=1e-6)


def test_workload_rejects_p_greater_than_n():
    with pytest.raises(ValueError):
        sp.WorkloadParams(1, 1, 1, n_samples=2, n_workers=4)


def test_recv_timeout_does_not_eat_next_message():
    """Regression: a timed-out recv must cancel its pending request —
    otherwise the next isend completes a dead request and the message is
    lost (deadlocked the oracle pool on late first dispatch)."""
    ch = Channel("t")
    for _ in range(5):                    # park-and-abandon five times
        with pytest.raises(TimeoutError):
            ch.recv(timeout=0.005)
    ch.isend("job")
    assert ch.recv(timeout=1.0) == "job"  # must still be deliverable
