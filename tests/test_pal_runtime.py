"""PAL end-to-end runtime tests: the full async loop (toy kernels, as in the
paper's SI), fault injection (straggling/dead oracles), elastic resize, and
whole-state checkpoint/restart."""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import PAL, UserGene, UserModel, UserOracle
from repro.core.controller import Manager, ManagerConfig, OracleEndpoint
from repro.core.buffers import OracleInputBuffer, TrainingDataBuffer
from repro.core.fault import ElasticPool, Heartbeat, TaskLedger
from repro.core.transport import Channel


class ToyGene(UserGene):
    def __init__(self, rank, rd, limit=150):
        super().__init__(rank, rd)
        self.counter = 0
        self.limit = limit
        self.rng = np.random.RandomState(rank)
        self.restarts = 0

    def generate_new_data(self, data_to_gene):
        self.counter += 1
        if data_to_gene is None and self.counter > 1:
            self.restarts += 1
        if self.counter > self.limit:
            return True, np.zeros(4, np.float32)
        time.sleep(0.001)
        return False, self.rng.randn(4).astype(np.float32)


class ToyModel(UserModel):
    def __init__(self, rank, rd, dev, mode):
        super().__init__(rank, rd, dev, mode)
        self.w = np.random.RandomState(
            rank + (99 if mode == "train" else 0)).randn(4, 4) * 0.5
        self.x, self.y = [], []
        self.retrain_calls = 0

    def predict(self, list_data):
        return [np.asarray(x) @ self.w for x in list_data]

    def update(self, warr):
        self.w = warr.reshape(4, 4)

    def get_weight(self):
        return self.w.reshape(-1).astype(np.float32)

    def get_weight_size(self):
        return 16

    def add_trainingset(self, dps):
        for i, l in dps:
            self.x.append(i)
            self.y.append(l)

    def retrain(self, req):
        self.retrain_calls += 1
        # a couple of tiny least-squares-ish updates, interruptible
        for _ in range(10):
            if req.test():
                break
            time.sleep(0.002)
        self.w = self.w * 0.99
        return False


class ToyOracle(UserOracle):
    delay = 0.002

    def run_calc(self, inp):
        time.sleep(self.delay)
        return inp, np.sin(2 * inp).astype(np.float32)


def _cfg(tmp, **kw):
    base = dict(result_dir=tmp, gene_process=4, orcl_process=3,
                pred_process=2, ml_process=2, retrain_size=8,
                std_threshold=0.05, patience=3, checkpoint_every=0.0)
    base.update(kw)
    return PALRunConfig(**base)


def test_pal_full_async_loop():
    tmp = tempfile.mkdtemp()
    pal = PAL(_cfg(tmp), make_generator=ToyGene, make_model=ToyModel,
              make_oracle=ToyOracle)
    tok = pal.run(timeout=60)
    rep = pal.report()
    assert tok is not None and "generator" in tok.origin
    assert rep["labeled_total"] > 0
    assert rep["counters"]["train.retrains"] > 0
    assert rep["weight_publishes"] > 0
    assert rep["counters"].get("prediction.weight_refreshes", 0) > 0
    assert rep["counters"].get("runtime.thread_crashes", 0) == 0


def test_pal_trainer_can_stop_workflow():
    class StopTrainer(ToyModel):
        def retrain(self, req):
            return True  # immediate stop criterion

    tmp = tempfile.mkdtemp()
    pal = PAL(_cfg(tmp, gene_process=2), make_generator=lambda r, d:
              ToyGene(r, d, limit=10 ** 9),
              make_model=StopTrainer, make_oracle=ToyOracle)
    tok = pal.run(timeout=30)
    assert tok is not None
    assert "trainer" in tok.origin or tok.origin == "runtime"


def test_pal_checkpoint_and_restore():
    tmp = tempfile.mkdtemp()
    pal = PAL(_cfg(tmp), make_generator=ToyGene, make_model=ToyModel,
              make_oracle=ToyOracle)
    pal.run(timeout=30)
    pal.checkpoint()
    it = pal.exchange.iteration
    assert it > 0

    pal2 = PAL(_cfg(tmp), make_generator=ToyGene, make_model=ToyModel,
               make_oracle=ToyOracle, resume=True)
    assert pal2.exchange.iteration == it
    assert pal2.monitor.count("runtime.restores") == 1


def test_pal_checkpoint_requeues_inflight_oracle_work():
    """Dispatched-but-unlabeled oracle inputs are part of the snapshot: a
    restore re-queues them instead of silently losing selected samples."""
    tmp = tempfile.mkdtemp()
    pal = PAL(_cfg(tmp, orcl_process=0), make_generator=ToyGene,
              make_model=ToyModel, make_oracle=ToyOracle)
    # simulate the manager having dispatched work that never completed:
    # two payloads in flight on the ledger, one still waiting in the buffer
    waiting = np.full(4, 7.0, np.float32)
    inflight_a = np.full(4, 8.0, np.float32)
    inflight_b = np.full(4, 9.0, np.float32)
    pal.oracle_buffer.put([waiting])
    pal.manager.ledger.dispatch(inflight_a, "oracle0")
    pal.manager.ledger.dispatch(inflight_b, "oracle0")
    pal.checkpoint()

    pal2 = PAL(_cfg(tmp, orcl_process=0), make_generator=ToyGene,
               make_model=ToyModel, make_oracle=ToyOracle, resume=True)
    restored = pal2.oracle_buffer.snapshot()
    assert len(restored) == 3
    got = sorted(float(x[0]) for x in restored)
    assert got == [7.0, 8.0, 9.0]
    assert pal2.manager.ledger.inflight_count() == 0   # requeued, not stuck


def test_pal_elastic_oracle_resize():
    tmp = tempfile.mkdtemp()

    class SlowOracle(ToyOracle):
        delay = 0.05

    pal = PAL(_cfg(tmp, orcl_process=1), make_generator=lambda r, d:
              ToyGene(r, d, limit=10 ** 9),
              make_model=ToyModel, make_oracle=SlowOracle)
    pal.start()
    time.sleep(1.0)
    added = pal.add_oracles(3)
    assert pal.oracle_pool.size() == 4
    time.sleep(1.0)
    pal.remove_oracle(added[0])
    assert pal.oracle_pool.size() == 3
    pal.shutdown()
    assert pal.report()["labeled_total"] > 0


# ---------------------------------------------------------------------------
# fault machinery
# ---------------------------------------------------------------------------


def test_task_ledger_timeout_requeues_then_fails():
    led = TaskLedger(timeout=0.02, max_retries=1)
    led.dispatch("payload", "w0")
    time.sleep(0.05)
    expired = led.expired()
    assert len(expired) == 1 and expired[0].retries == 0
    led.dispatch(expired[0].payload, "w1", retries=1)
    time.sleep(0.05)
    assert led.expired() == []            # out of retries -> failed
    assert len(led.failed) == 1


def test_task_ledger_late_result_is_detected():
    led = TaskLedger(timeout=0.01, max_retries=0)
    tid = led.dispatch("p", "w0")
    time.sleep(0.03)
    led.expired()
    assert led.complete(tid) is None      # straggler result after requeue


def test_heartbeat_marks_dead_and_forgets():
    hb = Heartbeat(interval=0.01, max_misses=2)
    hb.beat("w0")
    time.sleep(0.05)
    assert hb.dead_workers() == ["w0"]
    assert hb.is_dead("w0")
    hb.beat("w0")                          # resurrection
    assert not hb.is_dead("w0")


def test_elastic_pool_add_remove():
    seen = []
    stopped = threading.Event()

    def worker(rank, stop):
        seen.append(rank)
        stop.wait(5)
        stopped.set()

    pool = ElasticPool("w", worker)
    ranks = pool.add(2)
    assert pool.size() == 2
    pool.remove(ranks[0])
    assert pool.size() == 1
    pool.shutdown()
    assert pool.size() == 0
    assert stopped.is_set()


def test_manager_requeues_work_from_dead_worker():
    """Integration: a dispatched job on a dead oracle gets requeued and
    completed by a healthy one."""
    obuf = OracleInputBuffer()
    tbuf = TrainingDataBuffer(retrain_size=1)
    mgr = Manager(obuf, tbuf, [Channel("t0")],
                  ManagerConfig(retrain_size=1, oracle_timeout=0.05,
                                max_oracle_retries=2,
                                heartbeat_interval=0.01))
    dead = mgr.register_oracle("dead")
    obuf.put([np.zeros(2)])
    mgr.step()                             # dispatches to `dead`
    assert mgr.ledger.inflight_count() == 1
    time.sleep(0.06)                       # let the deadline expire
    alive = mgr.register_oracle("alive")
    mgr.step()                             # requeue + redispatch
    # job should now be queued on some endpoint; serve it from `alive`
    served = False
    for ep in (alive, dead):
        while ep.jobs.poll():
            tid, payload = ep.jobs.recv()
            if ep is alive:
                ep.results.isend((tid, payload, payload * 2))
                served = True
    assert mgr.ledger.requeued >= 1
    if served:
        mgr.step()
        assert tbuf.total_labeled == 1
