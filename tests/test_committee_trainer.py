"""Fused committee-training subsystem tests (training/committee_trainer.py):

* fused-vs-legacy parity — same data order => the one-dispatch vmapped
  path trains each member numerically close to a sequential per-member
  ``make_train_step`` loop;
* bootstrap decorrelation — members draw DISTINCT minibatches when
  ``bootstrap=True`` and identical ones when ``False``;
* host-mesh (1x1) sharded train step bit-identical to unsharded;
* acceptance: the trainer->engine device weight-refresh path moves ZERO
  packed host bytes (and the WeightStore path is measurably nonzero);
* device replay ring: block appends, wraparound, width validation;
* PAL integration: the runtime collapses trainer threads into the one
  committee-trainer loop, and ``PAL.checkpoint`` carries the FULL
  TrainState (optimizer moments + step) so a resumed run continues
  mid-schedule instead of resetting Adam.
"""
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import CommitteeSpec, PAL, UserGene, UserOracle
from repro.core import committee as cmte
from repro.core.acquisition import FusedEngine
from repro.core.weight_sync import WeightStore
from repro.data.replay import ReplayTrainingBuffer
from repro.training.committee_trainer import (
    CommitteeTrainer, default_train_config,
)
from repro.training.train_step import make_train_state, make_train_step

K, IN_DIM, HIDDEN, OUT_DIM = 4, 6, 16, 3


def _apply(p, x):
    return jnp.tanh(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


def _loss(p, batch):
    pred = _apply(p, batch["x"])
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _members(seed=0, k=K):
    rng = np.random.RandomState(seed)
    return [{
        "w1": jnp.asarray(rng.randn(IN_DIM, HIDDEN).astype(np.float32) * .3),
        "b1": jnp.asarray(rng.randn(HIDDEN).astype(np.float32) * .1),
        "w2": jnp.asarray(rng.randn(HIDDEN, OUT_DIM).astype(np.float32) * .3),
        "b2": jnp.asarray(rng.randn(OUT_DIM).astype(np.float32) * .1),
    } for _ in range(k)]


def _data(n=40, seed=1):
    rng = np.random.RandomState(seed)
    return (rng.randn(n, IN_DIM).astype(np.float32),
            rng.randn(n, OUT_DIM).astype(np.float32))


def _trainer(cparams=None, **kw):
    if cparams is None:
        cparams = cmte.stack_members(_members())
    kw.setdefault("steps", 10)
    kw.setdefault("batch", 8)
    kw.setdefault("lr", 1e-2)
    kw.setdefault("replay_capacity", 64)
    return CommitteeTrainer(_loss, cparams, **kw)


# ---------------------------------------------------------------------------
# parity / decorrelation
# ---------------------------------------------------------------------------


def test_fused_matches_sequential_per_member_training():
    """Same data order (the trainer's own index draws replayed) => the
    one-dispatch vmapped step trains each member numerically close to the
    legacy sequential per-member loop."""
    members = _members()
    xs, ys = _data()
    steps = 12
    tr = _trainer(cmte.stack_members(members), bootstrap=True, seed=5)
    tr.add_blocks(list(zip(xs, ys)))
    idx = [tr.minibatch_indices(t, len(xs)) for t in range(steps)]
    tr.train(steps=steps)

    tcfg = default_train_config(1e-2)
    step = jax.jit(make_train_step(_loss, tcfg))
    for i in range(K):
        st = make_train_state(members[i], tcfg)
        for t in range(steps):
            st, _ = step(st, {"x": jnp.asarray(xs[idx[t][i]]),
                              "y": jnp.asarray(ys[idx[t][i]])})
        for key in ("w1", "b1", "w2", "b2"):
            a = np.asarray(st.params[key])
            b = np.asarray(cmte.member(tr.cparams, i)[key])
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
    # members actually moved
    assert not np.allclose(np.asarray(cmte.member(tr.cparams, 0)["w1"]),
                           np.asarray(members[0]["w1"]))


def test_bootstrap_decorrelates_member_minibatches():
    tr = _trainer(bootstrap=True, seed=2)
    idx = tr.minibatch_indices(0, 40)
    assert idx.shape == (K, tr.batch)
    rows = {tuple(r) for r in idx}
    assert len(rows) == K, "bootstrap members drew identical minibatches"

    tr_off = _trainer(bootstrap=False, seed=2)
    idx_off = tr_off.minibatch_indices(0, 40)
    assert all(np.array_equal(idx_off[0], idx_off[i]) for i in range(K))


def test_bootstrap_members_diverge_same_members_converge_together():
    """Identical member inits: bootstrap draws must decorrelate the
    trained members; bootstrap=False keeps them bit-identical."""
    same = cmte.stack_members([_members(seed=0)[0]] * K)
    xs, ys = _data()
    on = _trainer(same, bootstrap=True, seed=3)
    off = _trainer(same, bootstrap=False, seed=3)
    for t in (on, off):
        t.add_blocks(list(zip(xs, ys)))
        t.train(steps=8)
    w_on = np.asarray(on.cparams["w1"])
    w_off = np.asarray(off.cparams["w1"])
    assert not np.array_equal(w_on[0], w_on[1])          # decorrelated
    assert np.array_equal(w_off[0], w_off[1])            # same data order


# ---------------------------------------------------------------------------
# sharding
# ---------------------------------------------------------------------------


def test_host_mesh_train_step_bit_identical_to_unsharded():
    from repro.launch.mesh import make_host_mesh

    cparams = cmte.stack_members(_members())
    xs, ys = _data()
    plain = _trainer(cparams, seed=7)
    sharded = _trainer(cparams, seed=7, mesh=make_host_mesh())
    for t in (plain, sharded):
        t.add_blocks(list(zip(xs, ys)))
        t.train(steps=9)
    for key in ("w1", "b1", "w2", "b2"):
        assert np.array_equal(np.asarray(plain.cparams[key]),
                              np.asarray(sharded.cparams[key])), key
    # optimizer moments too: the whole TrainState shares the layout
    assert np.array_equal(np.asarray(plain.cstate.opt.mu["w1"]),
                          np.asarray(sharded.cstate.opt.mu["w1"]))


# ---------------------------------------------------------------------------
# weight handoff (acceptance: zero packed host bytes on the device path)
# ---------------------------------------------------------------------------


def test_device_weight_refresh_moves_zero_packed_host_bytes():
    cparams = cmte.stack_members(_members())
    xs, ys = _data()
    tr = _trainer(cparams)
    tr.add_blocks(list(zip(xs, ys)))
    tr.train(steps=5)

    engine = FusedEngine(_apply, cparams, 0.5, impl="xla")
    assert engine.refresh_from_device(tr.snapshot_cparams()) == 1
    assert engine.refresh_host_bytes == 0
    assert engine.device_refreshes == 1
    # the engine actually scores with the refreshed weights
    uq = engine.score([xs[i] for i in range(5)])
    np.testing.assert_allclose(
        uq.mean,
        np.mean([np.asarray(_apply(cmte.member(tr.cparams, i),
                                   jnp.asarray(xs[:5])))
                 for i in range(K)], axis=0),
        atol=1e-5)

    # the WeightStore path, by contrast, is a packed host round trip
    store = WeightStore(K)
    for i in range(K):
        store.publish_packed(i, cmte.get_weight(cmte.member(tr.cparams, i)))
    engine2 = FusedEngine(_apply, cparams, 0.5, impl="xla")
    assert engine2.refresh_from(store) == 1
    assert engine2.refresh_host_bytes > 0


def test_device_refresh_rejects_committee_size_change():
    engine = FusedEngine(_apply, cmte.stack_members(_members()), 0.5,
                         impl="xla")
    with pytest.raises(ValueError, match="committee size"):
        engine.refresh_from_device(cmte.stack_members(_members(k=K + 1)))


# ---------------------------------------------------------------------------
# replay ring
# ---------------------------------------------------------------------------


def test_replay_buffer_append_wraparound_and_validation():
    buf = ReplayTrainingBuffer(10)
    xs, ys = _data(8)
    buf.append(xs, ys)
    xb, yb, size = buf.arrays()
    assert size == 8 and xb.shape == (10, IN_DIM)
    np.testing.assert_array_equal(np.asarray(xb[:8]), xs)

    xs2, ys2 = _data(5, seed=9)
    buf.append(xs2, ys2)                     # wraps: rows 8,9 then 0,1,2
    xb, yb, size = buf.arrays()
    assert size == 10 and len(buf) == 10
    np.testing.assert_array_equal(np.asarray(xb[8:10]), xs2[:2])
    np.testing.assert_array_equal(np.asarray(xb[0:3]), xs2[2:])
    assert buf.total_added == 13

    # oversized block: only the newest `capacity` rows survive
    xs3, ys3 = _data(25, seed=11)
    buf.append(xs3, ys3)
    xb, _, size = buf.arrays()
    assert size == 10
    assert np.asarray(xb).astype(np.float32).shape == (10, IN_DIM)

    with pytest.raises(ValueError, match="row width"):
        buf.append(np.zeros((2, IN_DIM + 1), np.float32),
                   np.zeros((2, OUT_DIM), np.float32))
    with pytest.raises(ValueError, match="row mismatch"):
        buf.append(xs[:3], ys[:2])


def test_replay_buffer_state_roundtrip():
    buf = ReplayTrainingBuffer(6)
    xs, ys = _data(4)
    buf.append(xs, ys)
    sd = buf.state_dict()
    buf2 = ReplayTrainingBuffer(6)
    buf2.load_state_dict(sd)
    xb, yb, size = buf2.arrays()
    assert size == 4 and buf2.total_added == 4
    np.testing.assert_array_equal(np.asarray(xb[:4]), xs)
    # appends continue at the restored cursor
    buf2.append(xs[:3], ys[:3])
    _, _, size = buf2.arrays()
    assert size == 6 and len(buf2) == 6


# ---------------------------------------------------------------------------
# trainer checkpointing
# ---------------------------------------------------------------------------


def test_trainer_state_dict_resumes_mid_schedule():
    cparams = cmte.stack_members(_members())
    xs, ys = _data()
    tr = _trainer(cparams, seed=4)
    tr.add_blocks(list(zip(xs, ys)))
    tr.train(steps=7)
    sd = tr.state_dict()
    # moments are live (nonzero) and the per-member step advanced
    assert np.abs(np.asarray(sd["cstate"].opt.mu["w1"])).sum() > 0
    assert int(np.asarray(sd["cstate"].step)[0]) == 7

    tr2 = _trainer(cparams, seed=4)
    tr2.load_state_dict(sd)
    # continuing both trainers is bit-identical (same RNG cursor, same
    # optimizer state) — the restore did NOT reset Adam
    tr.train(steps=3)
    tr2.train(steps=3)
    assert np.array_equal(np.asarray(tr.cparams["w1"]),
                          np.asarray(tr2.cparams["w1"]))
    # a fresh trainer (reset moments/step) diverges from the resumed one
    tr3 = _trainer(cparams, seed=4)
    tr3.add_blocks(list(zip(xs, ys)))
    tr3.train(steps=3)
    assert not np.array_equal(np.asarray(tr2.cparams["w1"]),
                              np.asarray(tr3.cparams["w1"]))


def test_trainer_skips_mismatched_snapshot():
    tr = _trainer()
    xs, ys = _data()
    tr.add_blocks(list(zip(xs, ys)))
    tr.train(steps=2)
    other = CommitteeTrainer(_loss, cmte.stack_members(_members(k=K + 2)),
                             steps=2, batch=8, replay_capacity=16)
    w_before = np.asarray(other.cparams["w1"])
    other.load_state_dict(tr.state_dict())          # K mismatch: skipped
    assert np.array_equal(np.asarray(other.cparams["w1"]), w_before)


# ---------------------------------------------------------------------------
# PAL runtime integration
# ---------------------------------------------------------------------------


class _Gene(UserGene):
    def __init__(self, rank, rd, limit=300):
        super().__init__(rank, rd)
        self.rng = np.random.RandomState(rank)
        self.n = 0
        self.limit = limit

    def generate_new_data(self, data_to_gene):
        self.n += 1
        if self.n > self.limit:
            return True, np.zeros(IN_DIM, np.float32)
        time.sleep(0.001)
        return False, self.rng.randn(IN_DIM).astype(np.float32)


class _Oracle(UserOracle):
    def run_calc(self, inp):
        y = np.tile(np.sin(2 * inp[:1]), OUT_DIM).astype(np.float32)
        return inp, y


def _pal(tmp, **kw):
    cfg = PALRunConfig(
        result_dir=tmp, gene_process=4, orcl_process=2, pred_process=1,
        ml_process=3, retrain_size=6, std_threshold=0.05, patience=3,
        train_steps=20, train_batch=8, train_lr=1e-2,
        train_replay_capacity=128, **kw)
    return PAL(cfg, make_generator=_Gene, make_oracle=_Oracle,
               committee=CommitteeSpec(_apply, cmte.stack_members(_members())),
               loss_fn=_loss)


def test_pal_fused_training_loop_end_to_end():
    pal = _pal(tempfile.mkdtemp())
    # trainer threads collapsed: no per-member trainer objects, one lane
    assert pal.trainers == [] and len(pal.trainer_channels) == 1
    tok = pal.run(timeout=45)
    rep = pal.report()
    assert tok is not None
    assert rep["labeled_total"] > 0
    assert rep["counters"]["train.retrains"] > 0
    assert rep["train_fused_steps"] > 0
    assert rep["device_weight_refreshes"] > 0
    assert rep["weight_publishes"] == 0          # WeightStore demoted
    assert pal.engine.refresh_host_bytes == 0    # zero-copy handoff
    assert rep["counters"].get("runtime.thread_crashes", 0) == 0


def test_pal_requires_committee_for_loss_fn():
    with pytest.raises(ValueError, match="CommitteeSpec"):
        PAL(PALRunConfig(result_dir=tempfile.mkdtemp()),
            make_generator=_Gene, make_oracle=_Oracle, loss_fn=_loss)


def test_pal_checkpoint_restores_full_train_state():
    """PAL.checkpoint carries the full TrainState: a resumed run continues
    mid-schedule (same Adam moments, same RNG cursor) instead of
    restarting the optimizer."""
    tmp = tempfile.mkdtemp()
    pal = _pal(tmp)
    xs, ys = _data(20)
    pal.committee_trainer.add_blocks(list(zip(xs, ys)))
    pal.committee_trainer.train(steps=9)
    pal.checkpoint()

    pal2 = _pal(tmp)
    # second PAL built fresh THEN restored: proves restore did the work
    assert pal2.committee_trainer.steps_done == 0
    pal2._restore()
    t1, t2 = pal.committee_trainer, pal2.committee_trainer
    assert t2.steps_done == t1.steps_done == 9
    assert np.array_equal(np.asarray(t1.cstate.opt.mu["w1"]),
                          np.asarray(t2.cstate.opt.mu["w1"]))
    assert np.array_equal(np.asarray(t1.cstate.step),
                          np.asarray(t2.cstate.step))
    # restored weights were pushed to the engine device-to-device
    assert pal2.engine.device_refreshes >= 1
    assert pal2.engine.refresh_host_bytes == 0
    # continuing is bit-identical to continuing the original
    t1.train(steps=2)
    t2.train(steps=2)
    assert np.array_equal(np.asarray(t1.cparams["w1"]),
                          np.asarray(t2.cparams["w1"]))
