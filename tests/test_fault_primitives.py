"""Timing-edge and concurrency property tests for the fault primitives
(core/fault.py) plus the Manager's late-straggler dedupe path (ISSUE 6
satellite): Heartbeat max_misses boundary and zero interval, TaskLedger
timeout=0 and retry-exhaustion ordering, ElasticPool add/remove under
concurrent dispatch, and the requeue->both-results-arrive sequence that
used to waste (or could double-count) a perfectly good late label.
"""
import threading
import time

import numpy as np

from repro.core.buffers import OracleInputBuffer, TrainingDataBuffer
from repro.core.controller import (
    Manager, ManagerConfig, OracleTaskFailure, _payload_fp,
)
from repro.core.fault import ElasticPool, Heartbeat, TaskLedger
from repro.core.transport import Channel


# ---------------------------------------------------------------------------
# Heartbeat timing edges
# ---------------------------------------------------------------------------


def test_heartbeat_max_misses_boundary():
    """Death is STRICTLY past interval*max_misses: at (or just under) the
    boundary the worker is still alive; only beyond it is it dead."""
    hb = Heartbeat(interval=0.2, max_misses=2)       # dead after >0.4s
    hb.beat("w0")
    time.sleep(0.05)
    assert hb.dead_workers() == [] and not hb.is_dead("w0")
    time.sleep(0.45)                                 # well past the boundary
    assert hb.dead_workers() == ["w0"]
    assert hb.dead_workers() == []                   # reported once, stays dead
    assert hb.is_dead("w0")


def test_heartbeat_zero_interval_marks_dead_immediately():
    """interval=0: any elapsed time at all exceeds 0*max_misses — the next
    sweep declares the worker dead (degenerate config must not divide or
    hang, just behave as 'always expired')."""
    hb = Heartbeat(interval=0.0, max_misses=3)
    hb.beat("w0")
    time.sleep(0.001)
    assert hb.dead_workers() == ["w0"]
    hb.beat("w0")                                    # resurrection still works
    assert not hb.is_dead("w0")


def test_heartbeat_forget_removes_all_state():
    hb = Heartbeat(interval=0.0)
    hb.beat("w0")
    time.sleep(0.001)
    assert hb.dead_workers() == ["w0"]
    hb.forget("w0")
    assert not hb.is_dead("w0")
    assert hb.dead_workers() == []                   # no resurrected ghost


# ---------------------------------------------------------------------------
# TaskLedger timing edges
# ---------------------------------------------------------------------------


def test_task_ledger_zero_timeout_expires_on_first_sweep():
    led = TaskLedger(timeout=0.0, max_retries=1)
    tid = led.dispatch("p", "w0")
    time.sleep(0.001)
    exp = led.expired()
    assert [t.task_id for t in exp] == [tid]
    assert led.inflight_count() == 0
    assert led.complete(tid) is None                 # straggler detected


def test_task_ledger_retry_exhaustion_ordering():
    """Tasks cycle requeue->redispatch until retries are spent, then land in
    ``failed`` — in expiry order, never both requeued and failed."""
    led = TaskLedger(timeout=0.0, max_retries=1)
    led.dispatch("a", "w0")
    led.dispatch("b", "w0")
    time.sleep(0.001)
    first = led.expired()
    assert sorted(t.payload for t in first) == ["a", "b"]
    assert led.failed == [] and led.requeued == 2
    for t in first:                                  # last allowed attempt
        led.dispatch(t.payload, "w1", retries=t.retries + 1)
    time.sleep(0.001)
    assert led.expired() == []                       # exhausted -> failed
    assert sorted(t.payload for t in led.failed) == ["a", "b"]
    assert all(t.retries == 1 for t in led.failed)
    assert led.requeued == 2                         # failure isn't a requeue


def test_task_ledger_fail_records_reported_failures():
    led = TaskLedger(timeout=10.0, max_retries=0)
    tid = led.dispatch("p", "w0")
    t = led.complete(tid)
    led.fail(t)
    assert led.failed == [t]
    assert led.inflight_count() == 0


# ---------------------------------------------------------------------------
# ElasticPool under concurrent resize
# ---------------------------------------------------------------------------


def test_elastic_pool_concurrent_add_remove():
    """Racing add/remove/shrink from multiple threads never wedges the pool,
    loses a stop event, or leaves threads running after shutdown."""
    started, stopped = [], []
    lock = threading.Lock()

    def worker(rank, stop):
        with lock:
            started.append(rank)
        stop.wait(10)
        with lock:
            stopped.append(rank)

    pool = ElasticPool("w", worker)

    def adder():
        for _ in range(5):
            pool.add(2)

    def remover():
        for _ in range(8):
            ranks = pool.ranks()
            if ranks:
                pool.remove(ranks[0], join=False)
            time.sleep(0.001)

    threads = [threading.Thread(target=adder) for _ in range(2)] + \
              [threading.Thread(target=remover) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert pool.size() == len(pool.ranks())
    pool.shutdown(timeout=10)
    assert pool.size() == 0
    deadline = time.time() + 5
    while len(stopped) < len(started) and time.time() < deadline:
        time.sleep(0.01)
    assert sorted(stopped) == sorted(started)        # every worker exited


# ---------------------------------------------------------------------------
# Manager late-straggler dedupe (satellite: duplicate-label path)
# ---------------------------------------------------------------------------


def _mgr(timeout=0.03):
    obuf = OracleInputBuffer()
    tbuf = TrainingDataBuffer(retrain_size=100)
    mgr = Manager(obuf, tbuf, [Channel("t0")],
                  ManagerConfig(retrain_size=100, oracle_timeout=timeout,
                                max_oracle_retries=2,
                                heartbeat_interval=10.0))
    return mgr, obuf, tbuf


def test_dedupe_twin_delivers_first_then_straggler_dropped():
    """timeout -> requeue -> twin labels it -> the ORIGINAL result finally
    arrives: exactly one training row, straggler counted as duplicate."""
    mgr, obuf, tbuf = _mgr()
    slow = mgr.register_oracle("slow")
    x = np.full(3, 1.5, np.float32)
    obuf.put([x])
    mgr.step()                                        # dispatch tid0
    tid0, p0 = slow.jobs.recv()                       # worker starts... slowly
    time.sleep(0.05)                                  # expire the deadline
    mgr.step()                                        # requeue + redispatch
    tid1, p1 = slow.jobs.recv()
    assert tid1 != tid0
    slow.results.isend((tid1, p1, p1 * 2.0))          # twin finishes FIRST
    mgr._collect_results()
    assert tbuf.total_labeled == 1
    slow.results.isend((tid0, p0, p0 * 2.0))          # straggler arrives last
    mgr._collect_results()
    assert tbuf.total_labeled == 1                    # no duplicate row
    assert mgr.monitor.count("oracle.duplicate_results") == 1
    assert mgr.monitor.count("manager.late_results_used") == 0


def test_dedupe_straggler_label_used_and_queued_twin_cancelled():
    """timeout -> requeued into the buffer (no free worker) -> the original
    result arrives: its label is USED and the waiting twin is removed, so
    the oracle never recomputes work it already has."""
    mgr, obuf, tbuf = _mgr()
    slow = mgr.register_oracle("slow")
    x = np.full(3, 2.5, np.float32)
    obuf.put([x])
    mgr.step()                                        # dispatched to slow
    tid = slow.jobs.recv()[0]
    time.sleep(0.05)
    # expire; `slow` is the only endpoint and is freed, so the requeue
    # redispatches to it -- pre-occupy it so the twin stays buffered
    slow.busy_task = -1
    mgr.step()
    assert len(obuf) == 1                             # twin waits in buffer
    slow.busy_task = None
    slow.results.isend((tid, x, x * 2.0))             # straggler arrives
    mgr._collect_results()
    assert tbuf.total_labeled == 1                    # late label used
    assert mgr.monitor.count("manager.late_results_used") == 1
    assert len(obuf) == 0                             # twin cancelled
    assert mgr.monitor.count("oracle.duplicate_results") == 0


def test_dedupe_straggler_first_then_inflight_twin_dropped():
    """Straggler arrives while the twin is ALREADY dispatched: the late
    label is used and the twin's eventual result is dropped as a
    duplicate — one training row either way."""
    mgr, obuf, tbuf = _mgr()
    a = mgr.register_oracle("a")
    b = mgr.register_oracle("b")
    x = np.full(3, 3.5, np.float32)
    obuf.put([x])
    mgr.step()
    owner0 = a if a.busy_task is not None else b
    tid0 = owner0.jobs.recv()[0]
    time.sleep(0.05)
    mgr.step()                                        # requeue+redispatch twin
    owner1 = a if a.busy_task is not None else b
    tid1, payload1 = owner1.jobs.recv()
    # straggler first...
    owner0.results.isend((tid0, x, x * 2.0))
    mgr._collect_results()
    assert tbuf.total_labeled == 1
    assert mgr.monitor.count("manager.late_results_used") == 1
    # ...then the in-flight twin completes: dropped
    owner1.results.isend((tid1, payload1, payload1 * 2.0))
    mgr._collect_results()
    assert tbuf.total_labeled == 1
    assert mgr.monitor.count("oracle.duplicate_results") == 1


def test_task_failure_sentinel_redispatches_then_gives_up():
    """OracleTaskFailure results consume ledger retries and finally land in
    ``ledger.failed`` — never in the training buffer."""
    mgr, obuf, tbuf = _mgr(timeout=10.0)
    ep = mgr.register_oracle("w0")
    x = np.full(3, 4.5, np.float32)
    obuf.put([x])
    for expected_retries in range(mgr.ledger.max_retries + 1):
        mgr.step()
        tid, payload = ep.jobs.recv()
        ep.results.isend((tid, payload, OracleTaskFailure("boom")))
        mgr._collect_results()
    assert tbuf.total_labeled == 0
    assert len(mgr.ledger.failed) == 1
    assert mgr.monitor.count("oracle.task_gave_up") == 1
    assert mgr.monitor.count("oracle.task_failures_reported") == 3
    assert len(obuf) == 0                             # not requeued forever


def test_nonfinite_labels_never_reach_training_buffer():
    mgr, obuf, tbuf = _mgr(timeout=10.0)
    ep = mgr.register_oracle("w0")
    x = np.full(3, 5.5, np.float32)
    obuf.put([x])
    mgr.step()
    tid, payload = ep.jobs.recv()
    bad = np.full(3, np.nan, np.float32)
    ep.results.isend((tid, payload, bad))
    mgr._collect_results()
    assert tbuf.total_labeled == 0
    assert mgr.monitor.count("oracle.nonfinite_labels") == 1
    mgr.step()                                        # redispatched
    tid2, payload2 = ep.jobs.recv()
    ep.results.isend((tid2, payload2, payload2 * 2.0))
    mgr._collect_results()
    assert tbuf.total_labeled == 1                    # finite retry admitted


def test_payload_fingerprint_distinguishes_dtype_and_shape():
    a = np.zeros(4, np.float32)
    assert _payload_fp(a) == _payload_fp(a.copy())
    assert _payload_fp(a) != _payload_fp(a.astype(np.float64))
    assert _payload_fp(a) != _payload_fp(a.reshape(2, 2))
    assert _payload_fp(a) != _payload_fp(np.ones(4, np.float32))
