"""Supervised fault-tolerant runtime under deterministic chaos (ISSUE 6).

* ``ChaosInjector``/``FaultPlan``: deterministic per-(site, rank) firing,
  exactly-once events, label corruption, the transport hook.
* ``Supervisor``: crashed-loop restart with backoff, escalation to a
  StopToken only past the crash budget, supervise=False fail-stop parity.
* PAL integration (legacy toy kernels): transient oracle faults absorbed
  by in-place task retries, oracle/trainer crash -> restart, NaN labels
  rejected and relabeled, the full acceptance FaultPlan surviving
  end-to-end without a StopToken.
* PAL integration (fused committee): the acceptance plan incl. a
  NaN-weights member — the poisoned member is quarantined (degraded-K
  UQ), scoring stays ONE dispatch per shape bucket, and the run still
  ends on the generator's own stop criterion.
* Autosave: checkpoint_every_iters cadence, and restore falling back
  past a corrupted (kill-during-write) newest snapshot.
"""
import glob
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import CommitteeSpec, PAL
from repro.core import committee as cmte
from repro.core.chaos import (
    ChaosCrash, ChaosFault, ChaosInjector, FaultEvent, FaultPlan,
)
from repro.core.supervisor import FailurePolicy, Supervisor
from repro.core.transport import Channel, install_chaos, uninstall_chaos

from test_committee_trainer import (
    K as CK, _apply, _loss, _members, _Gene as FusedGene, _Oracle as FusedOracle,
)
from test_pal_runtime import ToyGene, ToyModel, ToyOracle


# ---------------------------------------------------------------------------
# injector semantics
# ---------------------------------------------------------------------------


def test_injector_fires_deterministically_and_exactly_once():
    plan = FaultPlan(events=(
        FaultEvent("oracle.task", 2, "raise", rank="oracle0"),
        FaultEvent("oracle.task", 2, "raise", rank="oracle1"),
        FaultEvent("oracle.loop", 3, "crash"),
    ))
    for _ in range(2):                       # same plan => same sequence
        inj = ChaosInjector(plan)
        fired = []
        for i in range(4):
            for rank in ("oracle0", "oracle1"):
                try:
                    inj.check("oracle.task", rank=rank)
                except ChaosFault:
                    fired.append((rank, i))
        for i in range(5):
            try:
                inj.check("oracle.loop", rank="oracle0")
            except ChaosCrash:
                fired.append(("loop", i))
        assert fired == [("oracle0", 1), ("oracle1", 1), ("loop", 2)]
        assert len(inj.fired) == 3
        assert inj.summary() == [
            "oracle.task:oracle0:raise@2",
            "oracle.task:oracle1:raise@2",
            "oracle.loop:oracle0:crash@3",
        ]


def test_injector_counters_survive_restarts():
    """'nth call' counts over the campaign: a restarted loop continues its
    predecessor's count instead of resetting (so one plan cannot fire the
    same event once per incarnation)."""
    inj = ChaosInjector(FaultPlan(events=(
        FaultEvent("oracle.loop", 3, "crash", rank="w0"),)))
    inj.check("oracle.loop", rank="w0")      # incarnation 1: calls 1..2
    inj.check("oracle.loop", rank="w0")
    with pytest.raises(ChaosCrash):          # incarnation 2 first call = 3rd
        inj.check("oracle.loop", rank="w0")
    for _ in range(5):
        inj.check("oracle.loop", rank="w0")  # never fires again


def test_injector_nan_label_and_take():
    inj = ChaosInjector(FaultPlan(events=(
        FaultEvent("oracle.label", 2, "nan_label"),
        FaultEvent("trainer.nan_member", 1, "nan_member", arg=2.0),
    )))
    lab = np.ones(3, np.float32)
    assert np.isfinite(inj.corrupt_label(lab)).all()     # 1st call: clean
    bad = inj.corrupt_label(lab)                         # 2nd call: corrupted
    assert np.isnan(bad).all()
    assert np.isfinite(lab).all()                        # original untouched
    ev = inj.take("trainer.nan_member")
    assert ev is not None and int(ev.arg) == 2
    assert inj.take("trainer.nan_member") is None        # consumed


def test_injector_delay_sleeps():
    inj = ChaosInjector(FaultPlan(events=(
        FaultEvent("exchange.loop", 1, "delay", arg=0.05),)))
    t0 = time.perf_counter()
    inj.check("exchange.loop")
    assert time.perf_counter() - t0 >= 0.04


def test_transport_send_chaos_site():
    inj = ChaosInjector(FaultPlan(events=(
        FaultEvent("transport.send", 2, "raise", rank="jobs:w0"),)))
    install_chaos(inj)
    try:
        ch = Channel("jobs:w0")
        other = Channel("jobs:w1")
        ch.isend(1)
        other.isend(1)                       # different rank: not counted
        with pytest.raises(ChaosFault):
            ch.isend(2)
        ch.isend(3)                          # consumed: sends flow again
    finally:
        uninstall_chaos()
    assert Channel("jobs:w0").isend(4) is not None   # hook removed


# ---------------------------------------------------------------------------
# supervisor semantics
# ---------------------------------------------------------------------------


class _Mon:
    def __init__(self):
        self.c = {}

    def incr(self, k, n=1):
        self.c[k] = self.c.get(k, 0) + n


def _supervisor(max_crashes=3, **kw):
    mon = _Mon()
    stops = []
    sup = Supervisor(mon, lambda n, r: stops.append((n, r)),
                     threading.Event(),
                     policies={"default": FailurePolicy(
                         max_crashes=max_crashes,
                         restart_backoff_s=0.001, **kw)})
    return sup, mon, stops


def test_supervisor_restarts_crashed_loop_in_place():
    sup, mon, stops = _supervisor()
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"boom {calls['n']}")

    sup.run("w0", "oracle", flaky)
    assert calls["n"] == 3 and stops == []
    assert mon.c["runtime.thread_crashes"] == 2
    assert mon.c["runtime.thread_restarts"] == 2
    assert sup.total_restarts() == 2
    assert sup.last_fault.thread == "w0"
    assert "boom 2" in sup.last_fault.error


def test_supervisor_escalates_past_crash_budget():
    sup, mon, stops = _supervisor(max_crashes=2)
    calls = {"n": 0}

    def doomed():
        calls["n"] += 1
        raise RuntimeError("dead")

    sup.run("w1", "oracle", doomed)
    assert calls["n"] == 2                   # budget spent, no 3rd attempt
    assert stops and stops[0][0] == "w1"
    assert "max_crashes=2" in stops[0][1]
    assert mon.c["supervisor.escalations"] == 1
    assert mon.c["supervisor.crashes.oracle"] == 2


def test_supervisor_max_crashes_one_is_fail_stop():
    sup, mon, stops = _supervisor(max_crashes=1)
    sup.run("w2", "oracle", lambda: (_ for _ in ()).throw(ValueError("x")))
    assert len(stops) == 1
    assert mon.c.get("runtime.thread_restarts", 0) == 0


def test_supervisor_on_crash_and_should_stop():
    sup, mon, stops = _supervisor()
    cleaned = []
    private = threading.Event()

    def crash_then_signal():
        if not cleaned:
            raise RuntimeError("first")
        private.set()                        # second incarnation: stop loop
        raise RuntimeError("second")

    sup.run("w3", "oracle", crash_then_signal,
            on_crash=lambda e: cleaned.append(repr(e)),
            should_stop=private.is_set)
    assert cleaned == ["RuntimeError('first')", "RuntimeError('second')"]
    assert stops == []                       # stopped, not escalated


def test_backoff_delay_grows_and_caps():
    sup, _, _ = _supervisor()
    pol = FailurePolicy(task_backoff_s=0.1, backoff_factor=2.0,
                        backoff_max_s=0.5, jitter=0.0)
    delays = [sup.backoff_delay(pol, a) for a in range(5)]
    assert delays == pytest.approx([0.1, 0.2, 0.4, 0.5, 0.5])


# ---------------------------------------------------------------------------
# PAL integration (legacy toy kernels — no jax on the hot path)
# ---------------------------------------------------------------------------


def _pal(tmp, chaos=None, limit=150, resume=False, **kw):
    base = dict(result_dir=tmp, gene_process=4, orcl_process=3,
                pred_process=2, ml_process=2, retrain_size=8,
                std_threshold=0.05, patience=3,
                loop_restart_backoff_s=0.01, oracle_task_backoff_s=0.002)
    base.update(kw)
    return PAL(PALRunConfig(**base),
               make_generator=lambda r, d: ToyGene(r, d, limit=limit),
               make_model=ToyModel, make_oracle=ToyOracle,
               chaos=chaos, resume=resume)


def test_transient_oracle_faults_retry_in_place():
    """raise-kind faults at oracle.task are absorbed by per-task retries:
    no task failure reaches the Manager, no thread crashes, the run
    completes on the generator's own stop criterion."""
    plan = FaultPlan(events=(
        FaultEvent("oracle.task", 1, "raise", rank="oracle0"),
        FaultEvent("oracle.task", 2, "raise", rank="oracle1"),
    ))
    pal = _pal(tempfile.mkdtemp(), chaos=plan)
    tok = pal.run(timeout=60)
    rep = pal.report()
    assert "generator" in tok.origin
    c = rep["counters"]
    assert c.get("oracle.task_retries", 0) == 2
    assert c.get("oracle.task_failures_reported", 0) == 0
    assert c.get("runtime.thread_crashes", 0) == 0
    assert rep["labeled_total"] > 0


def test_oracle_crash_restarts_worker_and_run_survives():
    plan = FaultPlan(events=(
        FaultEvent("oracle.loop", 4, "crash", rank="oracle1"),))
    pal = _pal(tempfile.mkdtemp(), chaos=plan)
    tok = pal.run(timeout=60)
    rep = pal.report()
    assert "generator" in tok.origin                 # crash absorbed
    assert rep["thread_restarts"] == 1
    assert rep["last_fault"]["thread"] == "oracle1"
    assert rep["last_fault"]["loop_class"] == "oracle"
    assert "ChaosCrash" in rep["last_fault"]["error"]
    assert rep["labeled_total"] > 0
    assert rep["counters"].get("supervisor.escalations", 0) == 0


def test_trainer_crash_restarts_and_training_continues():
    plan = FaultPlan(events=(FaultEvent("trainer.loop", 1, "crash"),))
    pal = _pal(tempfile.mkdtemp(), chaos=plan)
    tok = pal.run(timeout=60)
    rep = pal.report()
    assert "generator" in tok.origin
    assert rep["thread_restarts"] >= 1
    assert rep["counters"]["train.retrains"] > 0     # trained after restart


def test_supervise_false_reproduces_fail_stop():
    plan = FaultPlan(events=(
        FaultEvent("oracle.loop", 2, "crash", rank="oracle0"),))
    pal = _pal(tempfile.mkdtemp(), chaos=plan, supervise=False,
               limit=10 ** 9)
    tok = pal.run(timeout=60)
    rep = pal.report()
    assert tok.origin == "oracle0"                   # first crash stops all
    assert rep["thread_restarts"] == 0
    assert rep["counters"]["supervisor.escalations"] == 1


def test_escalation_after_repeated_crashes():
    plan = FaultPlan(events=tuple(
        FaultEvent("oracle.loop", n, "crash", rank="oracle0")
        for n in (1, 2, 3)))
    pal = _pal(tempfile.mkdtemp(), chaos=plan, loop_max_crashes=3,
               limit=10 ** 9)
    tok = pal.run(timeout=60)
    rep = pal.report()
    assert tok.origin == "oracle0"
    assert "max_crashes=3" in tok.reason
    assert rep["counters"]["supervisor.escalations"] == 1
    assert rep["thread_restarts"] == 2               # restarts 1 and 2 only


def test_nan_labels_rejected_and_relabeled():
    plan = FaultPlan(events=(FaultEvent("oracle.label", 2, "nan_label"),))
    pal = _pal(tempfile.mkdtemp(), chaos=plan)
    tok = pal.run(timeout=60)
    rep = pal.report()
    assert "generator" in tok.origin
    assert rep["counters"].get("oracle.nonfinite_labels", 0) == 1
    # nothing non-finite is sitting in the training buffer
    for inp, lab in pal.train_buffer.snapshot():
        assert np.isfinite(np.asarray(lab)).all()
    assert rep["labeled_total"] > 0


def test_acceptance_plan_completes_without_stop_token():
    """The ISSUE-6 acceptance sequence on the legacy toy runtime: 3
    transient oracle failures + 1 oracle crash + 1 trainer crash, all
    absorbed — the run ends on the generator stop criterion with healthy
    labeled throughput and zero escalations."""
    pal = _pal(tempfile.mkdtemp(), chaos=FaultPlan.acceptance())
    tok = pal.run(timeout=90)
    rep = pal.report()
    assert "generator" in tok.origin, tok
    assert rep["counters"].get("supervisor.escalations", 0) == 0
    assert rep["thread_restarts"] == 2               # oracle + trainer
    fired = rep["chaos_fired"]
    assert sum(":raise@" in f for f in fired) == 3
    assert sum(":crash@" in f for f in fired) == 2
    assert rep["labeled_total"] > 0


# ---------------------------------------------------------------------------
# PAL integration (fused committee: quarantine + single-dispatch acceptance)
# ---------------------------------------------------------------------------


def test_fused_acceptance_quarantines_member_in_one_dispatch():
    """The full acceptance plan against the fused-committee runtime: the
    nan_member event poisons member 1 mid-campaign.  The run must (a)
    finish on the generator stop criterion, (b) score every subsequent
    round with the poisoned member quarantined (degraded K-1 committee),
    (c) keep scoring in ONE fused dispatch per shape bucket — no
    quarantine-induced retraces — and (d) fire all six planned events."""
    class _SlowGene(FusedGene):
        # stretch the campaign past the first train round's jit compile so
        # the trainer reaches round 2 (the scheduled crash) and round 3
        # (post-restart training) before the generators exhaust
        def generate_new_data(self, data_to_gene):
            stop, x = super().generate_new_data(data_to_gene)
            time.sleep(0.005)
            return stop, x

    tmp = tempfile.mkdtemp()
    cfg = PALRunConfig(
        result_dir=tmp, gene_process=4, orcl_process=2, pred_process=1,
        ml_process=3, retrain_size=6, std_threshold=0.05, patience=3,
        train_steps=20, train_batch=8, train_lr=1e-2,
        train_replay_capacity=128,
        loop_restart_backoff_s=0.01, oracle_task_backoff_s=0.002)
    pal = PAL(cfg, make_generator=lambda r, d: _SlowGene(r, d, limit=600),
              make_oracle=FusedOracle,
              committee=CommitteeSpec(_apply, cmte.stack_members(_members())),
              loss_fn=_loss, chaos=FaultPlan.acceptance(member=1))
    tok = pal.run(timeout=120)
    rep = pal.report()
    assert "generator" in tok.origin, tok
    assert rep["counters"].get("supervisor.escalations", 0) == 0
    assert len(rep["chaos_fired"]) == 6              # incl. nan_member
    assert rep["counters"]["train.members_poisoned"] == 1
    assert rep["counters"].get("train.member_rollbacks", 0) >= 1
    # degraded-K quarantine: the poisoned member never counts again
    assert rep["uq_finite_members_min"] == CK - 1
    assert rep["uq_quarantine_rounds"] > 0
    # acceptance: quarantined scoring stayed ONE fused dispatch per bucket
    assert pal.engine.trace_counts, "fused engine never dispatched"
    assert all(v == 1 for v in pal.engine.trace_counts.values()), \
        pal.engine.trace_counts
    assert rep["labeled_total"] > 0


# ---------------------------------------------------------------------------
# autosave + crash-kill-restore
# ---------------------------------------------------------------------------


def test_autosave_every_iters():
    tmp = tempfile.mkdtemp()
    pal = _pal(tmp, checkpoint_every_iters=10)
    tok = pal.run(timeout=60)
    assert "generator" in tok.origin
    assert pal.checkpointer.saves >= 2               # periodic, not one-shot
    assert glob.glob(os.path.join(tmp, "al_state_*.pkl"))
    # a fresh runtime resumes from the autosaved state
    pal2 = _pal(tmp, resume=True)
    assert pal2.exchange.iteration > 0
    assert pal2.monitor.count("runtime.restores") == 1


def test_kill_during_autosave_restores_latest_intact_snapshot():
    """A kill mid-checkpoint can leave a truncated newest snapshot (or a
    stray writer tmp file).  Restore must fall back to the newest INTACT
    snapshot and continue mid-schedule from it — never die, never start
    from scratch."""
    tmp = tempfile.mkdtemp()
    pal = _pal(tmp)
    pal.exchange.iteration = 40
    pal.checkpoint()                                 # intact snapshot @40
    pal.exchange.iteration = 50
    path_newest = pal.checkpoint()                   # snapshot @50 ...
    with open(path_newest, "r+b") as fh:             # ... truncated by a kill
        fh.truncate(max(os.path.getsize(path_newest) // 3, 1))
    # a stray half-written tmp file from the killed writer is ignored too
    with open(os.path.join(tmp, ".alckpt_dead"), "wb") as fh:
        fh.write(b"\x00garbage")

    pal2 = _pal(tmp, resume=True)
    assert pal2.checkpointer.corrupt_skipped == 1
    assert pal2.exchange.iteration == 40             # mid-schedule, intact
    assert pal2.monitor.count("runtime.restores") == 1


def test_restore_skips_all_corrupt_snapshots_without_dying():
    tmp = tempfile.mkdtemp()
    for step in (1, 2):
        with open(os.path.join(tmp, f"al_state_{step:08d}.pkl"),
                  "wb") as fh:
            fh.write(b"not a pickle")
    pal = _pal(tmp, resume=True)                     # no crash, no restore
    assert pal.checkpointer.corrupt_skipped == 2
    assert pal.monitor.count("runtime.restores") == 0
    assert pal.exchange.iteration == 0
