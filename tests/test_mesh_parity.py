"""Multi-device bit-identity: every fused path on a REAL 8-device mesh.

Runs only when the process already has >= 8 devices (the CI ``mesh`` job
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; locally:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/test_mesh_parity.py

).  The contract under test: sharding is a LAYOUT decision, not a
numerics decision — ``FusedEngine.score`` / ``score_after`` (exploration
fleet), the ``CommitteeTrainer`` step, and the ``ServingQueue`` dispatch
must produce bit-identical results on the (8 data x 1 model) scale-out
mesh, including stateful-rule state, checkpoint round-trips of sharded
state, and the device-resident fleet carry.

Known exception (asserted, with tolerance): on the (1 x 8) COMMITTEE-axis
mesh the trainer's params drift at the ~1 ULP level — XLA fuses the
grad+Adam chain differently under SPMD partitioning (FMA/accumulation
order), which no sharding constraint can pin.  Scoring on that mesh is
still bit-identical.
"""
from __future__ import annotations

import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.pal_potential import PALRunConfig
from repro.core import acquisition as acq
from repro.core.budget import rules_from_config
from repro.core.committee import stack_members
from repro.launch.mesh import make_scaleout_mesh

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

K, D, HID = 8, 6, 16
THRESHOLD = 0.35


def _init_member(seed):
    r = np.random.RandomState(seed)
    return {"w1": jnp.asarray(r.randn(D, HID).astype(np.float32) * 0.3),
            "w2": jnp.asarray(r.randn(HID, D).astype(np.float32) * 0.3)}


def _apply(p, x):
    return jnp.tanh(x @ p["w1"]) @ p["w2"]


@pytest.fixture(scope="module")
def cparams():
    return stack_members([_init_member(i) for i in range(K)])


def _engine(cparams, mesh, with_rules=False):
    rules = None
    if with_rules:
        cfg = PALRunConfig(std_threshold=THRESHOLD, oracle_budget=0.3,
                           reweight_buckets=32)
        rules = rules_from_config(cfg)
    return acq.FusedEngine(_apply, cparams, THRESHOLD, rules=rules,
                           impl="xla", mesh=mesh)


def _uq_equal(a, b):
    return all(np.array_equal(np.asarray(getattr(a, f)),
                              np.asarray(getattr(b, f)))
               for f in ("mean", "scalar_std", "component_std", "mask"))


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


@pytest.mark.parametrize("shape", [(8, 1), (1, 8)], ids=["data8", "model8"])
def test_score_bitidentical_with_stateful_rules(cparams, shape):
    """4 advancing rounds: outputs AND BudgetRule/RollingReweightRule
    state stay bit-identical to the unsharded engine on both mesh
    orientations."""
    e0 = _engine(cparams, None, with_rules=True)
    e8 = _engine(cparams, make_scaleout_mesh(*shape), with_rules=True)
    rng = np.random.RandomState(1)
    for _ in range(4):
        xs = rng.randn(61, D).astype(np.float32)
        assert _uq_equal(e0.score(list(xs)), e8.score(list(xs)))
    assert _tree_equal(e0.state_dict(), e8.state_dict())


def test_score_ndarray_fastpath_matches_list(cparams):
    e8 = _engine(cparams, make_scaleout_mesh(8, 1))
    rng = np.random.RandomState(2)
    x = rng.randn(33, D).astype(np.float32)
    assert _uq_equal(e8.score(x, advance=False),
                     e8.score(list(x), advance=False))


def test_rule_state_checkpoint_roundtrip_on_mesh(cparams):
    """state_dict taken from a mesh engine restores onto a fresh mesh
    engine (replicated placement) and scoring continues bit-identically."""
    mesh = make_scaleout_mesh(8, 1)
    rng = np.random.RandomState(3)
    e8 = _engine(cparams, mesh, with_rules=True)
    for _ in range(3):
        e8.score(list(rng.randn(21, D).astype(np.float32)))
    e8b = _engine(cparams, mesh, with_rules=True)
    e8b.load_state_dict(e8.state_dict())
    xs = rng.randn(19, D).astype(np.float32)
    assert _uq_equal(e8.score(list(xs)), e8b.score(list(xs)))
    assert _tree_equal(e8.state_dict(), e8b.state_dict())


def test_zero_extra_host_bytes_on_mesh(cparams):
    """The mesh engine must move exactly the bytes the unsharded engine
    moves: input up, (mean, sstd, cstd, mask) down — resharding happens
    device-side, never via a host bounce."""
    e0 = _engine(cparams, None)
    e8 = _engine(cparams, make_scaleout_mesh(8, 1))
    rng = np.random.RandomState(4)
    for n in (16, 33, 64):
        e0.score(rng.randn(n, D).astype(np.float32), advance=False)
    rng = np.random.RandomState(4)
    for n in (16, 33, 64):
        e8.score(rng.randn(n, D).astype(np.float32), advance=False)
    assert e8.bytes_to_device == e0.bytes_to_device
    assert e8.bytes_to_host == e0.bytes_to_host


def test_fleet_score_after_and_carry_parity(cparams):
    """Device-resident fleet: 4 fused advance+score+select steps plus the
    carry checkpoint round-trip, all bit-identical on the mesh."""
    from repro.exploration.fleet import FleetConfig, WalkerFleet

    mesh = make_scaleout_mesh(8, 1)
    fc = FleetConfig(sampler="langevin", dt=0.002, noise=0.01, clip=20.0,
                     friction=0.1, patience=3, seed=7)
    x0 = np.random.RandomState(5).randn(24, D).astype(np.float32)
    fl0 = WalkerFleet(_engine(cparams, None), x0, fc)
    fl8 = WalkerFleet(_engine(cparams, mesh), x0, fc)
    for _ in range(4):
        o0, o8 = fl0.step(), fl8.step()
        assert o0.n_selected == o8.n_selected
        assert np.array_equal(o0.selected, o8.selected)
        assert np.array_equal(np.asarray(o0.mean), np.asarray(o8.mean))
    c0, c8 = fl0.state_dict(), fl8.state_dict()
    assert all(np.array_equal(c0[k], c8[k]) for k in c0)

    # carry restore re-places onto the mesh and continues bit-identically
    fl8b = WalkerFleet(_engine(cparams, mesh), x0, fc)
    fl8b.load_state_dict(c8)
    oa, ob = fl0.step(), fl8b.step()
    assert np.array_equal(np.asarray(oa.mean), np.asarray(ob.mean))


def _make_trainer(cparams, mesh, steps=3):
    from repro.training.committee_trainer import CommitteeTrainer

    def loss_fn(params, batch):
        pred = _apply(params, batch["x"])
        loss = jnp.mean((pred - batch["y"]) ** 2)
        return loss, {"loss": loss}

    rng = np.random.RandomState(6)
    xs = rng.randn(64, D).astype(np.float32)
    ys = rng.randn(64, D).astype(np.float32)
    tr = CommitteeTrainer(loss_fn, cparams, steps=steps, batch=16, lr=1e-3,
                          bootstrap=True, replay_capacity=128, mesh=mesh,
                          seed=3)
    tr.add_blocks(list(zip(xs, ys)))
    return tr


def test_trainer_bitidentical_on_data_axis_mesh(cparams):
    """Losses, params, AND optimizer moments after 3 fused steps on the
    (8, 1) mesh match the unsharded trainer bit for bit; a sharded
    TrainState checkpoint restores onto a fresh mesh trainer and the next
    round stays bit-identical too."""
    mesh = make_scaleout_mesh(8, 1)
    t0, t8 = _make_trainer(cparams, None), _make_trainer(cparams, mesh)
    m0, m8 = t0.train(), t8.train()
    assert np.array_equal(m0["loss"], m8["loss"])
    assert _tree_equal(jax.tree.map(np.asarray, t0.snapshot_cparams()),
                       jax.tree.map(np.asarray, t8.snapshot_cparams()))

    t8b = _make_trainer(cparams, mesh)
    t8b.load_state_dict(t8.state_dict())
    m0b, m8b = t0.train(), t8b.train()
    assert np.array_equal(m0b["loss"], m8b["loss"])
    assert _tree_equal(jax.tree.map(np.asarray, t0.snapshot_cparams()),
                       jax.tree.map(np.asarray, t8b.snapshot_cparams()))


def test_trainer_model_axis_ulp_bounded(cparams):
    """Committee-axis (1, 8) mesh: XLA fuses grad+Adam differently under
    SPMD partitioning, so params may drift by ~1 ULP per step (fp32).
    Pin the bound tightly — a real resharding bug shows up orders of
    magnitude above it."""
    t0 = _make_trainer(cparams, None)
    tm = _make_trainer(cparams, make_scaleout_mesh(1, 8))
    m0, mm = t0.train(), tm.train()
    np.testing.assert_allclose(np.asarray(mm["loss"]),
                               np.asarray(m0["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(t0.snapshot_cparams()),
                    jax.tree.leaves(tm.snapshot_cparams())):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-5, atol=1e-6)


def test_serving_queue_parity(cparams):
    from repro.serving.engine import CommitteeServer
    from repro.serving.queue import QueueConfig, ServingQueue

    qc = QueueConfig(max_batch=16, max_wait_ms=20.0)
    rng = np.random.RandomState(8)
    reqs = [rng.randn(3, D).astype(np.float32) for _ in range(8)]
    with ServingQueue(CommitteeServer(_engine(cparams, None)), qc) as q0, \
            ServingQueue(CommitteeServer(
                _engine(cparams, make_scaleout_mesh(8, 1))), qc) as q8:
        f0 = [q0.submit(list(r)) for r in reqs]
        f8 = [q8.submit(list(r)) for r in reqs]
        for a, b in zip(f0, f8):
            ua, ub = a.result(timeout=60), b.result(timeout=60)
            assert np.array_equal(np.asarray(ua[0]), np.asarray(ub[0]))


def test_k3_committee_on_8way_mesh_warns_and_matches(caplog):
    """A K=3 committee over the 8-way model axis cannot shard the
    committee dim: the layout must degrade LOUDLY (warn_fallbacks names
    the chosen layout) and still score bit-identically."""
    cp3 = stack_members([_init_member(i) for i in range(3)])
    with caplog.at_level(logging.WARNING, logger="repro.sharding.rules"):
        e3 = acq.FusedEngine(_apply, cp3, THRESHOLD, impl="xla",
                             mesh=make_scaleout_mesh(1, 8))
    assert any("sharding fallback" in r.getMessage()
               for r in caplog.records), caplog.records
    e0 = acq.FusedEngine(_apply, cp3, THRESHOLD, impl="xla", mesh=None)
    xs = np.random.RandomState(9).randn(32, D).astype(np.float32)
    assert _uq_equal(e0.score(xs, advance=False),
                     e3.score(xs, advance=False))


def test_resolve_mesh_grid_form():
    cfg = PALRunConfig(uq_mesh="8x1")
    mesh = acq.resolve_mesh(cfg)
    assert dict(mesh.shape) == {"data": 8, "model": 1}
    cfg = PALRunConfig(uq_mesh="2x4")
    assert dict(acq.resolve_mesh(cfg).shape) == {"data": 2, "model": 4}
    with pytest.raises(ValueError):
        acq.resolve_mesh(PALRunConfig(uq_mesh="3z"))
