"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single device; only the dry-run subprocesses get 512.

Backend-matrix knob: ``REPRO_FORCE_UQ_IMPL=xla|pallas|pallas_interpret``
reroutes every config-driven fused engine (``uq_impl='auto'`` +
CommitteeSpec) through the named kernel implementation — CI runs tier-1
once per backend so a kernel-only regression can't hide behind the 'auto'
default.  Tests that pin ``uq_impl`` explicitly (backend-parity tests) and
legacy-path tests are left alone: forcing a fused impl onto a
committee-less config would change what those tests test.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig

_FORCE_IMPL = os.environ.get("REPRO_FORCE_UQ_IMPL", "")
if _FORCE_IMPL:
    from repro.core import acquisition as _acq

    _orig_make_engine = _acq.make_engine

    def _forced_make_engine(run_cfg, **kw):
        if (dataclasses.is_dataclass(run_cfg)
                and getattr(run_cfg, "uq_impl", "auto") == "auto"
                and not _acq.wants_legacy(run_cfg, kw.get("committee"),
                                          kw.get("force_legacy", False))):
            run_cfg = dataclasses.replace(run_cfg, uq_impl=_FORCE_IMPL)
        return _orig_make_engine(run_cfg, **kw)

    _acq.make_engine = _forced_make_engine


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def tiny_config(family: str, **kw) -> ModelConfig:
    base = dict(
        name=f"tiny-{family}", family=family, num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
        dtype="float32", param_dtype="float32", remat="none")
    if family == "moe":
        base.update(moe_num_experts=8, moe_top_k=2, moe_num_shared_experts=2,
                    moe_shared_d_ff=256, moe_group_size=16,
                    moe_capacity_factor=8.0)
    if family == "rwkv6":
        base.update(num_heads=4, num_kv_heads=4, rwkv_head_dim=16,
                    rwkv_lora_rank=8, rwkv_decay_lora_rank=8)
    if family == "hybrid":
        base.update(num_layers=8, attn_layer_period=8, attn_layer_offset=4,
                    moe_num_experts=4, moe_top_k=2, moe_layer_period=2,
                    moe_layer_offset=1, mamba_head_dim=16, mamba_d_state=8,
                    moe_group_size=16, moe_capacity_factor=8.0)
    if family == "encdec":
        base.update(encoder_layers=2, encoder_seq=24, rope_theta=0.0,
                    act="gelu")
    if family == "vlm":
        base.update(vision_tokens=4)
    base.update(kw)
    return ModelConfig(**base)
