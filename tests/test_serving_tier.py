"""Multi-tenant serving tier tests (ISSUE 9).

* **Fairness**: deficit-round-robin microbatch composition under a
  skewed backlog — a flooding tenant is bounded to its share, small
  tenants finish early, nobody starves (deterministic: the dispatcher is
  stalled while the backlog builds, then every composed batch is
  inspected).
* **Rate limiting**: per-client token buckets with an injected clock —
  shedding is a deterministic function of (submitted rows, virtual
  time), isolated per client, typed ``RateLimited``.
* **Answer cache**: a cache hit is BIT-IDENTICAL to a fresh dispatch;
  uncertain rows are never cached; partial hits dispatch fresh (bypass);
  every weight refresh invalidates.
* **Adaptive deadline**: ``LatencyController`` converges onto the p99
  target from both over- and under-shoot on a synthetic plant (within
  the 25% acceptance band), and the live queue steers
  ``effective_wait_ms`` in the right direction from both sides.
* **Observability**: ``health()`` is one consistent snapshot with
  per-client counters; ``PAL.report()`` derives every serve_queue_* key
  from it; the supervisor snapshot carries the queue as a component.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import acquisition as acq
from repro.core import budget as bud
from repro.core import committee as cmte
from repro.serving import (
    CircuitOpen, CommitteeServer, LSHAnswerCache, QueueConfig,
    QueueOverloaded, RateLimited, ServingQueue, ServingRejected,
)

import jax.numpy as jnp

K, IN_DIM, OUT_DIM = 5, 6, 3


def _committee(seed=0):
    rng = np.random.RandomState(seed)
    members = [{"w": jnp.asarray(rng.randn(IN_DIM, OUT_DIM)
                                 .astype(np.float32) * 0.5)}
               for _ in range(K)]
    return members, cmte.stack_members(members), (lambda p, x: x @ p["w"])


def _server(threshold=0.4, seed=0, **kw):
    _, cparams, apply_fn = _committee(seed)
    eng = acq.FusedEngine(apply_fn, cparams, threshold, impl="xla")
    return CommitteeServer(eng, None, **kw), eng


def _rows(n, seed=1, scale=1.0):
    rng = np.random.RandomState(seed)
    return [(rng.randn(IN_DIM) * scale).astype(np.float32)
            for _ in range(n)]


class _StubServer:
    """Deterministic server: records every microbatch's client ids
    (encoded in row[0]) and can stall so a backlog builds up."""

    def __init__(self):
        self.batches = []                 # list of lists of client ids
        self.stall = None                 # threading.Event to wait on
        self.started = threading.Event()  # set when a dispatch arrives

    def predict(self, rows):
        self.started.set()
        if self.stall is not None:
            self.stall.wait(10)
            self.stall = None             # stall only the first dispatch
        self.batches.append([int(r[0]) for r in rows])
        n = len(rows)
        mean = np.zeros((n, OUT_DIM), np.float32)
        z = np.zeros(n, np.float32)
        return mean, acq.UQResult(mean, z, z.copy(), np.zeros(n, bool),
                                  np.full(n, K, np.int32))


def _tagged_row(client_id):
    r = np.zeros(IN_DIM, np.float32)
    r[0] = client_id
    return r


# ---------------------------------------------------------------------------
# typed rejection hierarchy
# ---------------------------------------------------------------------------


def test_rejection_hierarchy():
    assert issubclass(QueueOverloaded, ServingRejected)
    assert issubclass(CircuitOpen, ServingRejected)
    assert issubclass(RateLimited, ServingRejected)
    assert issubclass(ServingRejected, RuntimeError)


# ---------------------------------------------------------------------------
# fairness: deficit round-robin under a skewed backlog
# ---------------------------------------------------------------------------


def test_drr_bounds_flooding_tenant_to_its_share():
    """One tenant floods 64 requests before 7 small tenants submit 8
    each.  FIFO would serve the flood first (small tenants finish after
    batch 8); DRR gives every backlogged tenant its share of each
    microbatch, so the small tenants all finish by batch ~5 while the
    hog still gets its share — nobody starves."""
    srv = _StubServer()
    srv.stall = threading.Event()
    q = ServingQueue(srv, QueueConfig(max_batch=16, max_wait_ms=2.0))
    try:
        # primer occupies the dispatcher so the backlog builds atomically
        primer = q.submit([_tagged_row(0)], client="hog")
        assert srv.started.wait(10)
        futs = [q.submit([_tagged_row(0)], client="hog")
                for _ in range(64)]
        for c in range(1, 8):
            futs += [q.submit([_tagged_row(c)], client=f"t{c}")
                     for _ in range(8)]
        srv.stall.set()
        primer.result(timeout=10)
        for f in futs:
            f.result(timeout=30)
    finally:
        q.close(timeout=10)
    batches = srv.batches[1:]             # drop the primer batch
    # while all 8 tenants are backlogged every batch carries each
    # tenant's share (quantum = 16 rows / 8 tenants = 2)
    for b in batches[:4]:
        counts = {c: b.count(c) for c in range(8)}
        assert all(counts[c] == 2 for c in range(8)), counts
    # small tenants are fully served by batch 4; under FIFO the flood's
    # 64 rows would have consumed the first 4 batches outright
    served_small = sum(b.count(c) for b in batches[:4] for c in range(1, 8))
    assert served_small == 7 * 8
    # and the flooding tenant was never starved either
    assert all(b.count(0) >= 1 for b in batches[:4])
    # fairness bound over the contended window: min/max served >= 0.5
    per_client = [sum(b.count(c) for b in batches[:4]) for c in range(8)]
    assert min(per_client) / max(per_client) >= 0.5
    h = q.health()
    assert h["clients"]["hog"]["served"] == 65
    assert all(h["clients"][f"t{c}"]["served"] == 8 for c in range(1, 8))


def test_drr_single_client_degenerates_to_fifo():
    """All traffic under one (default) client tag is plain FIFO — the
    PR-4 ordering guarantee is unchanged."""
    server, eng = _server()
    rows = _rows(12, seed=2)
    direct = eng.score(rows, advance=False)
    with ServingQueue(server, QueueConfig(max_batch=12,
                                          max_wait_ms=200.0)) as q:
        outs = [f.result(timeout=10)
                for f in [q.submit([r]) for r in rows]]
    assert q.dispatches == 1
    for i, (mean, uq) in enumerate(outs):
        np.testing.assert_array_equal(mean[0], direct.mean[i])
        np.testing.assert_array_equal(uq.mask[0], direct.mask[i])


def test_drr_oversized_request_still_dispatched_alone():
    srv = _StubServer()
    with ServingQueue(srv, QueueConfig(max_batch=4, max_wait_ms=20.0)) as q:
        mean, uq = q.predict([_tagged_row(9) for _ in range(11)],
                             client="big")
    assert mean.shape == (11, OUT_DIM) and len(uq.mask) == 11
    assert q.dispatches == 1


# ---------------------------------------------------------------------------
# per-client token-bucket rate limiting (deterministic via injected clock)
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _limited_queue(clock, rate=10.0, burst=5.0, **kw):
    srv = _StubServer()
    q = ServingQueue(srv, QueueConfig(max_batch=64, max_wait_ms=1.0,
                                      rate_limit=rate, rate_burst=burst,
                                      **kw),
                     clock=clock)
    return q, srv


def test_rate_limit_sheds_deterministically():
    clock = _FakeClock()
    q, _ = _limited_queue(clock)
    try:
        futs = [q.submit([_tagged_row(0)], client="a") for _ in range(5)]
        # burst of 5 spent at t=0: the 6th is shed, typed
        with pytest.raises(RateLimited):
            q.submit([_tagged_row(0)], client="a")
        # refill is exactly rate * elapsed virtual time
        clock.t = 0.1                     # 10 rows/s * 0.1s = 1 token
        futs.append(q.submit([_tagged_row(0)], client="a"))
        with pytest.raises(RateLimited):
            q.submit([_tagged_row(0)], client="a")
        # a multi-row request costs its row count
        clock.t = 0.4                     # +3 tokens
        with pytest.raises(RateLimited):
            q.submit([_tagged_row(0)] * 4, client="a")
        futs.append(q.submit([_tagged_row(0)] * 3, client="a"))
        for f in futs:
            f.result(timeout=10)
        h = q.health()
        assert h["rate_limited"] == 3
        assert h["clients"]["a"]["shed"] == 3
        assert h["clients"]["a"]["served"] == 7
    finally:
        q.close(timeout=10)


def test_rate_limit_is_per_client():
    clock = _FakeClock()
    q, _ = _limited_queue(clock)
    try:
        for _ in range(5):
            q.submit([_tagged_row(0)], client="a")
        with pytest.raises(RateLimited):
            q.submit([_tagged_row(0)], client="a")
        # client b has its own untouched bucket
        fut = q.submit([_tagged_row(1)], client="b")
        fut.result(timeout=10)
        h = q.health()
        assert h["clients"]["b"]["shed"] == 0
        assert h["clients"]["a"]["shed"] == 1
    finally:
        q.close(timeout=10)


def test_rate_limit_disabled_by_default():
    srv = _StubServer()
    with ServingQueue(srv, QueueConfig(max_batch=64, max_wait_ms=1.0)) as q:
        futs = [q.submit([_tagged_row(0)], client="a") for _ in range(200)]
        for f in futs:
            f.result(timeout=10)
    assert q.health()["rate_limited"] == 0


# ---------------------------------------------------------------------------
# LSH answer cache
# ---------------------------------------------------------------------------


def _cached_queue(std_max=100.0, tol=0.0, threshold=1e9, **kw):
    server, eng = _server(threshold=threshold)
    cache = LSHAnswerCache(256, std_max=std_max, tol=tol)
    q = ServingQueue(server, QueueConfig(max_batch=16, max_wait_ms=2.0,
                                         **kw),
                     cache=cache)
    return q, server, eng, cache


def test_cache_hit_bit_identical_to_fresh_dispatch():
    q, server, eng, cache = _cached_queue()
    try:
        rows = _rows(4, seed=30)
        fresh_mean, fresh_uq = q.predict(rows)
        d0 = q.dispatches
        hit_mean, hit_uq = q.predict(rows)          # full cache hit
        assert q.dispatches == d0                   # no device dispatch
        np.testing.assert_array_equal(hit_mean, fresh_mean)
        np.testing.assert_array_equal(hit_uq.scalar_std, fresh_uq.scalar_std)
        np.testing.assert_array_equal(hit_uq.component_std,
                                      fresh_uq.component_std)
        np.testing.assert_array_equal(hit_uq.mask, fresh_uq.mask)
        np.testing.assert_array_equal(hit_uq.finite_members,
                                      fresh_uq.finite_members)
        s = cache.stats()
        assert s["hits"] == 4 and s["insertions"] == 4
        assert q.health()["cache_hit_requests"] == 1
    finally:
        q.close(timeout=10)


def test_cache_invalidated_on_weight_refresh():
    q, server, eng, cache = _cached_queue()
    try:
        rows = _rows(3, seed=31)
        mean_old, _ = q.predict(rows)
        assert q.predict(rows)[0] is not None and q.dispatches == 1
        # a device-resident weight refresh moves the generation
        new_params = jnp.asarray(np.asarray(eng.cparams["w"]) * 2.0)
        eng.refresh_from_device({"w": new_params})
        mean_new, _ = q.predict(rows)               # MUST re-dispatch
        assert q.dispatches == 2
        assert cache.stats()["invalidations"] >= 1
        assert not np.array_equal(mean_new, mean_old)
        np.testing.assert_allclose(mean_new, mean_old * 2.0, rtol=1e-6)
    finally:
        q.close(timeout=10)


def test_cache_never_serves_uncertain_rows():
    # threshold 0 -> every row is rule-selected (mask=True) -> never cached
    q, server, eng, cache = _cached_queue(threshold=0.0)
    try:
        rows = _rows(3, seed=32, scale=2.0)
        q.predict(rows)
        q.predict(rows)
        assert q.dispatches == 2                    # both hit the device
        assert cache.stats()["insertions"] == 0
    finally:
        q.close(timeout=10)


def test_cache_partial_hit_dispatches_whole_request():
    q, server, eng, cache = _cached_queue()
    try:
        rows = _rows(2, seed=33)
        q.predict(rows)                             # seeds the cache
        mixed = [rows[0], _rows(1, seed=34)[0]]     # one hit + one miss
        q.predict(mixed)
        assert q.dispatches == 2                    # request went fresh
        s = cache.stats()
        assert s["bypass"] == 1                     # the unusable hit
    finally:
        q.close(timeout=10)


def test_cache_opt_out_counts_bypass():
    q, server, eng, cache = _cached_queue()
    try:
        rows = _rows(2, seed=35)
        q.predict(rows)
        q.submit(rows, use_cache=False).result(timeout=10)
        assert q.dispatches == 2
        assert cache.stats()["bypass"] == 2
    finally:
        q.close(timeout=10)


def test_cache_std_gate_and_lru_depth():
    cache = LSHAnswerCache(8, std_max=0.5, depth=2)
    n = 6
    rows = _rows(n, seed=36)
    mean = np.arange(n * OUT_DIM, dtype=np.float32).reshape(n, OUT_DIM)
    sstd = np.array([0.1, 0.9, 0.2, 0.1, 0.1, 0.1], np.float32)
    mask = np.array([False, False, True, False, False, False])
    uq = acq.UQResult(mean, sstd, np.zeros((n, OUT_DIM), np.float32),
                      mask, np.full(n, K, np.int32))
    cache.fill(rows, uq, (0, 0))
    # row 1 (std too high) and row 2 (rule-selected) were skipped
    assert cache.stats()["insertions"] == 4
    got = cache.lookup(rows)
    assert got[1] is None and got[2] is None
    for i in (0, 3, 4, 5):
        if got[i] is not None:            # depth may have evicted some
            np.testing.assert_array_equal(got[i].mean, mean[i])
    assert len(cache) <= 8 * 2            # n_buckets * depth bound


def test_cache_served_while_circuit_open():
    """Cached confident answers keep flowing while the breaker is open —
    the device is what is broken, not the cache."""
    q, server, eng, cache = _cached_queue(breaker_failures=1,
                                          breaker_reset_s=60.0)
    try:
        rows = _rows(2, seed=37)
        q.predict(rows)                             # seeds the cache
        server.predict = lambda r: (_ for _ in ()).throw(
            RuntimeError("device down"))
        with pytest.raises(RuntimeError, match="device down"):
            q.predict(_rows(1, seed=38))            # opens the breaker
        assert q.health()["breaker_state"] == "open"
        with pytest.raises(CircuitOpen):
            q.predict(_rows(1, seed=39))
        mean, uq = q.predict(rows)                  # full hit: still served
        assert mean.shape == (2, OUT_DIM)
    finally:
        q.close(timeout=10)


# ---------------------------------------------------------------------------
# adaptive deadline: LatencyController convergence + live wiring
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("init_ms", [40.0, 0.1],
                         ids=["overshoot", "undershoot"])
def test_latency_controller_converges_within_25pct(init_ms):
    """Closed loop on a synthetic plant p99(wait) = floor + wait: from a
    40 ms overshoot AND a 0.1 ms undershoot the controller pulls p99 to
    the 6 ms target within the 25% acceptance band, and stays there."""
    lc = bud.LatencyController(target_ms=6.0, wait_min_ms=0.05,
                               wait_max_ms=50.0)
    st = lc.init_state(init_ms)
    floor = 1.0
    p99s = []
    for _ in range(40):
        wait = lc.wait_ms(st)
        p99 = floor + wait                # plant: deadline-dominated p99
        st = lc.update(st, p99)
        p99s.append(p99)
    tail = p99s[-10:]
    assert all(abs(p - 6.0) / 6.0 <= 0.25 for p in tail), tail
    # and the steered deadline respected its authority bounds throughout
    assert 0.05 <= lc.wait_ms(st) <= 50.0


def test_latency_controller_respects_bounds():
    lc = bud.LatencyController(target_ms=1e9, wait_min_ms=0.5,
                               wait_max_ms=4.0)
    st = lc.init_state(1.0)
    for _ in range(60):                   # p99 far below target: wait grows
        st = lc.update(st, 0.001)
    assert lc.wait_ms(st) == pytest.approx(4.0)
    lc2 = bud.LatencyController(target_ms=1e-6, wait_min_ms=0.5,
                                wait_max_ms=4.0)
    st2 = lc2.init_state(1.0)
    for _ in range(60):                   # p99 far above target: wait shrinks
        st2 = lc2.update(st2, 1e3)
    assert lc2.wait_ms(st2) == pytest.approx(0.5)


@pytest.mark.parametrize("init_ms,expect", [(30.0, "down"), (0.05, "up")],
                         ids=["overshoot", "undershoot"])
def test_queue_adapts_effective_wait(init_ms, expect):
    """Live queue: with a p99 target, the effective deadline moves in the
    correct direction from both sides of the target."""
    srv = _StubServer()
    q = ServingQueue(srv, QueueConfig(
        max_batch=256, max_wait_ms=init_ms, latency_target_ms=8.0,
        wait_min_ms=0.05, wait_max_ms=50.0, latency_window=8))
    try:
        assert q.health()["effective_wait_ms"] == pytest.approx(
            np.clip(init_ms, 0.05, 50.0))
        for i in range(48):               # sequential: latency ~ deadline
            q.predict([_tagged_row(0)])
        h = q.health()
        assert h["p99_ms"] is not None
        if expect == "down":
            assert h["effective_wait_ms"] < init_ms
        else:
            assert h["effective_wait_ms"] > init_ms
    finally:
        q.close(timeout=10)


# ---------------------------------------------------------------------------
# observability: atomic health snapshot + supervisor component
# ---------------------------------------------------------------------------


def test_health_snapshot_has_all_keys():
    srv = _StubServer()
    with ServingQueue(srv, QueueConfig(max_batch=8, max_wait_ms=1.0)) as q:
        q.predict([_tagged_row(0)], client="a")
        h = q.health()
    for key in ("breaker_state", "consecutive_failures", "breaker_opens",
                "dispatch_failures", "shed_requests", "rate_limited",
                "cache_hit_requests", "pending_rows", "dispatches",
                "batched_requests", "effective_wait_ms", "p99_ms",
                "clients"):
        assert key in h, key
    assert h["clients"]["a"] == {"served": 1, "shed": 0, "cache_hits": 0}


def test_supervisor_reports_registered_component_health():
    from repro.core.supervisor import Supervisor

    sup = Supervisor(None, lambda n, r: None, threading.Event())
    sup.register_health("serve_queue", lambda: {"breaker_state": "closed"})
    sup.register_health("broken", lambda: 1 / 0)
    snap = sup.snapshot()
    assert snap["components"]["serve_queue"]["breaker_state"] == "closed"
    assert "error" in snap["components"]["broken"]   # probe errors contained


def test_pal_wires_tier_knobs_and_reports_consistently():
    import tempfile

    from repro.configs.pal_potential import PALRunConfig
    from repro.core import PAL, UserGene, UserModel, UserOracle

    class _Gene(UserGene):
        def __init__(self, rank, rd):
            super().__init__(rank, rd)
            self.rng = np.random.RandomState(rank)

        def generate_new_data(self, data_to_gene):
            return False, self.rng.randn(IN_DIM).astype(np.float32)

    class _Model(UserModel):
        def predict(self, xs):
            return [np.zeros(OUT_DIM) for _ in xs]

        def update(self, warr):
            pass

        def get_weight(self):
            return np.zeros(IN_DIM * OUT_DIM, np.float32)

        def get_weight_size(self):
            return IN_DIM * OUT_DIM

        def add_trainingset(self, dps):
            pass

        def retrain(self, req):
            return False

    class _Oracle(UserOracle):
        def run_calc(self, inp):
            return inp, np.zeros(OUT_DIM, np.float32)

    _, cparams, apply_fn = _committee(seed=16)
    cfg = PALRunConfig(
        result_dir=tempfile.mkdtemp(), gene_process=2, orcl_process=0,
        pred_process=1, ml_process=1, std_threshold=1e9,
        serve_uq=True, serve_max_batch=8,
        serve_rate_limit=1e6, serve_rate_burst=1e6,
        serve_latency_target_ms=5.0, serve_wait_min_ms=0.1,
        serve_wait_max_ms=20.0, serve_latency_window=16,
        serve_cache_buckets=128, serve_cache_std_max=100.0)
    pal = PAL(cfg, make_generator=_Gene, make_model=_Model,
              make_oracle=_Oracle,
              committee=acq.CommitteeSpec(apply_fn, cparams))
    try:
        qcfg = pal.serve_queue.cfg
        assert qcfg.rate_limit == 1e6 and qcfg.latency_target_ms == 5.0
        assert qcfg.wait_min_ms == 0.1 and qcfg.wait_max_ms == 20.0
        assert pal.serve_queue.cache is not None
        assert pal.serve_queue.cache.std_max == 100.0
        rows = _rows(4, seed=60)
        pal.serve_queue.submit(rows, client="tenant-a").result(timeout=10)
        pal.serve_queue.submit(rows, client="tenant-a").result(timeout=10)
        rep = pal.report()
        qh = rep["serve_queue_health"]
        # report()'s dispatch keys come from the SAME atomic snapshot
        assert rep["serve_queue_dispatches"] == qh["dispatches"]
        assert rep["serve_queue_batched_requests"] == qh["batched_requests"]
        assert qh["clients"]["tenant-a"]["served"] == 2
        assert qh["clients"]["tenant-a"]["cache_hits"] == 1
        assert qh["cache"]["hits"] == 4
        # the supervisor snapshot carries the queue as a component, and
        # report() exposes the whole snapshot
        assert (pal.supervisor.snapshot()["components"]["serve_queue"]
                ["breaker_state"] == "closed")
        assert (rep["supervisor"]["components"]["serve_queue"]
                ["breaker_state"] == "closed")
    finally:
        pal.shutdown()
